//! Matrix–vector multiplication kernel for the dense layer (paper §VI-C):
//! "shared-memory-based tiling is superfluous for a 1-D vector", so small
//! dense problems get their own simpler kernel instead of the GEMM
//! kernel. Batched over samples because the coordinator feeds
//! mini-batches — and once a mini-batch is large enough the problem *is*
//! a GEMM, so every dense entry point falls back to the tiled
//! cache-blocked [`gemm_auto`] above [`DENSE_GEMM_MIN_MACS`].
//!
//! All inner loops run on the batched [`MulBackend`] panel ops: row dots
//! through `dot_panel`, the weight-gradient rank-1 update through
//! `fma_row` (strategy dispatch and the broadcast operand's decomposition
//! hoisted out of the per-element loop). Both regimes follow the
//! crate-wide accumulation contract (one running FP32 accumulator,
//! ascending contraction order, `mul(activation, weight)` operand order),
//! so the matvec path and the GEMM fallback are bit-identical to each
//! other and to the scalar per-element reference — see
//! `tests/batched_vs_scalar.rs`.

use super::gemm::gemm_auto;
use super::{transpose_into, with_scratch, MulBackend, MulKernel};

/// MAC count above which the dense kernels route to the tiled GEMM
/// instead of per-row matvec dots (the packing overhead amortizes and
/// the 2D-parallel tiling engages).
pub const DENSE_GEMM_MIN_MACS: usize = 1 << 16;

/// `y[o] = sum_i x[i] * w[o, i]` — one sample. `w` is row-major
/// `[out, in]`. Products are `mul(x, w)` (activation first), matching the
/// GEMM fallback's operand order.
pub fn matvec(mul: &MulKernel, w: &[f32], x: &[f32], y: &mut [f32]) {
    let n_in = x.len();
    let n_out = y.len();
    assert_eq!(w.len(), n_in * n_out, "W shape");
    for (o, y_val) in y.iter_mut().enumerate() {
        *y_val = mul.dot_panel(x, &w[o * n_in..(o + 1) * n_in]);
    }
}

/// Batched forward: `y[b, o] = sum_i x[b, i] * w[i, o]` with `w` stored
/// `[in, out]` (the L2 JAX convention). Large batches go straight to the
/// tiled GEMM (`x` is already the GEMM `A`, `w` the GEMM `B`); small ones
/// transpose `w` once so the inner loop is the contiguous [`matvec`].
pub fn dense_forward(
    mul: &MulKernel,
    x: &[f32],
    w: &[f32],
    y: &mut [f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
) {
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(w.len(), n_in * n_out);
    assert_eq!(y.len(), batch * n_out);
    if batch * n_in * n_out >= DENSE_GEMM_MIN_MACS {
        gemm_auto(mul, x, w, y, batch, n_in, n_out);
        return;
    }
    // transpose to [out, in] for unit-stride dots (the "memory coalescing"
    // concern of the paper, CPU edition); the scratch is recycled across
    // calls so steady-state forward passes stay allocation-free
    with_scratch(w.len(), |wt| {
        transpose_into(w, n_in, n_out, wt);
        for b in 0..batch {
            matvec(mul, wt, &x[b * n_in..(b + 1) * n_in], &mut y[b * n_out..(b + 1) * n_out]);
        }
    });
}

/// Dense weight gradient: `dw[i, o] = sum_b x[b, i] * dy[b, o]`
/// (paper §VI-C.1: outer product accumulated over the batch). Large
/// problems transpose `x` once and run the tiled GEMM `dw = x^T dy`; the
/// ascending-batch accumulation and `mul(x, dy)` operand order match the
/// `fma_row` path bit for bit.
pub fn dense_weight_grad(
    mul: &MulKernel,
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
) {
    assert_eq!(x.len(), batch * n_in);
    assert_eq!(dy.len(), batch * n_out);
    assert_eq!(dw.len(), n_in * n_out);
    if batch * n_in * n_out >= DENSE_GEMM_MIN_MACS {
        // scratch, not a fresh Vec: this runs on every training step
        with_scratch(x.len(), |xt| {
            transpose_into(x, batch, n_in, xt);
            gemm_auto(mul, xt, dy, dw, n_in, batch, n_out);
        });
        return;
    }
    dw.fill(0.0);
    for b in 0..batch {
        let xb = &x[b * n_in..(b + 1) * n_in];
        let dyb = &dy[b * n_out..(b + 1) * n_out];
        for i in 0..n_in {
            let row = &mut dw[i * n_out..(i + 1) * n_out];
            mul.fma_row(row, xb[i], dyb);
        }
    }
}

/// Dense input gradient: `dx[b, i] = sum_o dy[b, o] * w[i, o]`
/// (paper §VI-C.2: the transposition is implicit in the indexing). Large
/// problems transpose `w` once and run the tiled GEMM `dx = dy w^T`;
/// products are `mul(dy, w)` in ascending-`o` order in both regimes.
pub fn dense_input_grad(
    mul: &MulKernel,
    dy: &[f32],
    w: &[f32],
    dx: &mut [f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
) {
    assert_eq!(dy.len(), batch * n_out);
    assert_eq!(w.len(), n_in * n_out);
    assert_eq!(dx.len(), batch * n_in);
    if batch * n_in * n_out >= DENSE_GEMM_MIN_MACS {
        // scratch, not a fresh Vec: this runs on every training step
        with_scratch(w.len(), |wt| {
            transpose_into(w, n_in, n_out, wt);
            gemm_auto(mul, dy, wt, dx, batch, n_out, n_in);
        });
        return;
    }
    for b in 0..batch {
        let dyb = &dy[b * n_out..(b + 1) * n_out];
        let dxb = &mut dx[b * n_in..(b + 1) * n_in];
        for (i, dx_val) in dxb.iter_mut().enumerate() {
            *dx_val = mul.dot_panel(dyb, &w[i * n_out..(i + 1) * n_out]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn matvec_example_from_paper_fig9() {
        // o = W x with W = [[w11 w12 w13], [w21 w22 w23]]
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = [1.0, 1.0, 2.0];
        let mut y = [0.0f32; 2];
        matvec(&MulKernel::Native, &w, &x, &mut y);
        assert_eq!(y, [1.0 + 2.0 + 6.0, 4.0 + 5.0 + 12.0]);
    }

    #[test]
    fn forward_grad_consistency() {
        // numerical gradient check of dense_forward against the two
        // gradient kernels (native multiplier)
        let mut rng = Pcg32::seeded(41);
        let (batch, n_in, n_out) = (3, 5, 4);
        let x: Vec<f32> = (0..batch * n_in).map(|_| rng.range(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.range(-1.0, 1.0)).collect();
        let dy: Vec<f32> = (0..batch * n_out).map(|_| rng.range(-1.0, 1.0)).collect();
        // loss = sum(y * dy); analytic grads:
        let mut dw = vec![0.0f32; n_in * n_out];
        dense_weight_grad(&MulKernel::Native, &x, &dy, &mut dw, batch, n_in, n_out);
        let mut dx = vec![0.0f32; batch * n_in];
        dense_input_grad(&MulKernel::Native, &dy, &w, &mut dx, batch, n_in, n_out);

        let loss = |w: &[f32], x: &[f32]| -> f32 {
            let mut y = vec![0.0f32; batch * n_out];
            dense_forward(&MulKernel::Native, x, w, &mut y, batch, n_in, n_out);
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        for i in 0..n_in * n_out {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let num = (loss(&wp, &x) - loss(&wm, &x)) / (2.0 * eps);
            assert!((num - dw[i]).abs() < 1e-2, "dw[{i}]: {num} vs {}", dw[i]);
        }
        for i in 0..batch * n_in {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&w, &xp) - loss(&w, &xm)) / (2.0 * eps);
            assert!((num - dx[i]).abs() < 1e-2, "dx[{i}]: {num} vs {}", dx[i]);
        }
    }

    #[test]
    fn dense_forward_matches_gemm_bitwise() {
        // the matvec regime shares the GEMM's operand order and
        // accumulation order, so even this small-batch shape (below the
        // GEMM-fallback threshold) matches the tiled kernel bit for bit
        let mut rng = Pcg32::seeded(42);
        let (batch, n_in, n_out) = (4, 7, 6);
        let x: Vec<f32> = (0..batch * n_in).map(|_| rng.range(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut y = vec![0.0f32; batch * n_out];
        dense_forward(&MulKernel::Native, &x, &w, &mut y, batch, n_in, n_out);
        let mut y_gemm = vec![0.0f32; batch * n_out];
        crate::kernels::gemm::gemm(&MulKernel::Native, &x, &w, &mut y_gemm, batch, n_in, n_out);
        for i in 0..y.len() {
            assert_eq!(y[i].to_bits(), y_gemm[i].to_bits(), "idx {i}");
        }
    }
}
