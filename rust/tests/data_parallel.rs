//! Data-parallel determinism suite: the test net that proves the
//! fixed-order gradient reduction tree bit-exact.
//!
//! The invariant under test (`coordinator::data_parallel`): the number of
//! workers changes *throughput only* — the loss/accuracy curve and every
//! parameter bit are identical for any worker count, for native, direct,
//! and LUT multipliers alike, because the minibatch decomposition is fixed
//! by the shard size and the leaf gradients meet in a reduction tree whose
//! shape is a function of the leaf index only. On top of that:
//!
//! * a one-leaf DP step is bitwise a plain `train_step` (they share every
//!   float op);
//! * gradient accumulation with aligned leaf boundaries is bitwise the
//!   monolithic large-batch step — including for the batchnorm resnet,
//!   whose batch statistics are per-leaf and therefore identical when the
//!   leaves are;
//! * changing the *decomposition* (shard size) of a batchnorm model is
//!   legitimately changing the model — the documented teeth of the
//!   batch-stats caveat;
//! * sharded checkpoints resume bit-identically, across worker counts;
//! * a replica panicking mid-step fail-stops with a typed error and no
//!   torn parameter update, and the thread pool survives.

use approxtrain::coordinator::backend::{CpuModel, MulSpec};
use approxtrain::coordinator::data_parallel::{DpConfig, DpTrainer, TrainReplica};
use approxtrain::coordinator::pruning::magnitude_mask;
use approxtrain::mult::ApproxMul;
use approxtrain::nn::cpu_lenet::Lenet300;
use approxtrain::nn::cpu_resnet::{CpuResnet, Depth};
use approxtrain::tensor::Tensor;
use approxtrain::util::rng::Pcg32;

const N_IN: usize = 36;
const CLASSES: usize = 10;
const LR: f32 = 0.05;

/// Deterministic synthetic batch stream (the checkpoint_resume idiom).
fn batches(steps: usize, batch: usize, seed: u64) -> Vec<(Vec<f32>, Vec<u32>)> {
    let mut rng = Pcg32::seeded(seed);
    (0..steps)
        .map(|_| {
            let images: Vec<f32> = (0..batch * N_IN).map(|_| rng.range(-1.0, 1.0)).collect();
            let labels: Vec<u32> = (0..batch).map(|_| rng.below(CLASSES as u32)).collect();
            (images, labels)
        })
        .collect()
}

/// Small-Lenet300 trainer; replicas are built explicitly so tests control
/// the model size (the named `DpTrainer::new` path takes the full 28x28
/// net).
fn lenet_trainer(workers: usize, shard: usize, spec: &MulSpec, seed: u64) -> DpTrainer {
    let replicas: Vec<TrainReplica> = (0..workers)
        .map(|_| TrainReplica {
            model: CpuModel::Lenet300(Lenet300::init(N_IN, CLASSES, seed)),
            mul: spec.clone(),
        })
        .collect();
    DpTrainer::from_replicas(replicas, DpConfig { workers, shard, lr: LR }).unwrap()
}

fn resnet_trainer(workers: usize, shard: usize, seed: u64) -> DpTrainer {
    let replicas: Vec<TrainReplica> = (0..workers)
        .map(|_| TrainReplica {
            model: CpuModel::Resnet(CpuResnet::init(Depth::R18, (8, 8, 3), 4, 4, seed)),
            mul: MulSpec::Native,
        })
        .collect();
    DpTrainer::from_replicas(replicas, DpConfig { workers, shard, lr: LR }).unwrap()
}

/// Run `steps` over `data`, returning the curve as bits plus final params
/// as bits.
fn run_curve(tr: &mut DpTrainer, data: &[(Vec<f32>, Vec<u32>)]) -> (Vec<(u32, u32)>, Vec<u32>) {
    let curve = data
        .iter()
        .map(|(images, labels)| {
            let s = tr.step(images, labels).unwrap();
            (s.loss.to_bits(), s.acc.to_bits())
        })
        .collect();
    (curve, tr.flat_params().iter().map(|v| v.to_bits()).collect())
}

fn assert_bits_eq(a: &[u32], b: &[u32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x, y, "{what}: bit mismatch at element {i}");
    }
}

// ---------------------------------------------------------------------------
// N-worker bit-identity, all multiplier strategies
// ---------------------------------------------------------------------------

/// The headline invariant: for N in {2, 4, 5, 7} (including N greater
/// than the leaf count — batch 12 / shard 4 = 3 leaves), training is
/// bit-identical to N = 1, for native, direct, and LUT multipliers.
#[test]
fn n_worker_training_is_bit_identical_to_one_worker() {
    let data = batches(5, 12, 4242);
    for mode in ["native", "direct:afm16", "lut:afm16"] {
        let spec = MulSpec::parse(mode).unwrap();
        let (ref_curve, ref_params) = run_curve(&mut lenet_trainer(1, 4, &spec, 77), &data);
        // the curve must actually train (not be degenerate zeros)
        assert!(ref_curve.iter().any(|&(l, _)| f32::from_bits(l) > 0.0), "{mode}: flat curve");
        for workers in [2usize, 4, 5, 7] {
            let (curve, params) = run_curve(&mut lenet_trainer(workers, 4, &spec, 77), &data);
            assert_eq!(
                curve, ref_curve,
                "{mode}: loss/acc curve diverged at workers={workers}"
            );
            assert_bits_eq(&ref_params, &params, &format!("{mode} workers={workers} params"));
        }
    }
}

/// Sparse fine-tuning rides the same invariant: with a pruning mask
/// installed (`DpTrainer::set_mask`, re-applied after every optimizer
/// step), N-worker and 1-worker training produce bit-identical curves
/// and parameters — the mask is applied once to the post-reduction
/// parameter vector and broadcast, after the point where replicas are
/// already identical — and the pruned entries stay exactly +0.0 bits
/// through every step (the precondition the zero-skipping GEMM drain's
/// occupancy scan relies on).
#[test]
fn sparse_training_with_masks_is_bit_identical_across_worker_counts() {
    let data = batches(4, 12, 888);
    for mode in ["native", "lut:afm16"] {
        let spec = MulSpec::parse(mode).unwrap();
        // prune 60% of the initial weights by magnitude
        let mask = magnitude_mask(&lenet_trainer(1, 4, &spec, 31).flat_params(), 0.6);
        let run = |workers: usize| {
            let mut tr = lenet_trainer(workers, 4, &spec, 31);
            tr.set_mask(Some(mask.clone())).unwrap();
            let out = run_curve(&mut tr, &data);
            for (i, (&bits, &k)) in out.1.iter().zip(&mask.keep).enumerate() {
                if !k {
                    assert_eq!(
                        bits,
                        0.0f32.to_bits(),
                        "{mode} workers={workers}: pruned param {i} revived"
                    );
                }
            }
            out
        };
        let (ref_curve, ref_params) = run(1);
        assert!(ref_curve.iter().any(|&(l, _)| f32::from_bits(l) > 0.0), "{mode}: flat curve");
        for workers in [2usize, 5] {
            let (curve, params) = run(workers);
            assert_eq!(curve, ref_curve, "{mode}: sparse curve diverged at workers={workers}");
            assert_bits_eq(&ref_params, &params, &format!("{mode} sparse workers={workers}"));
        }
    }
}

/// The batchnorm resnet is *also* worker-count-invariant: its batch
/// statistics are per-leaf, and the leaves don't depend on N.
#[test]
fn resnet_batch_stats_are_worker_count_invariant() {
    let mut rng = Pcg32::seeded(5151);
    let (batch, elems) = (6usize, 8 * 8 * 3);
    let data: Vec<(Vec<f32>, Vec<u32>)> = (0..2)
        .map(|_| {
            let images: Vec<f32> = (0..batch * elems).map(|_| rng.range(-1.0, 1.0)).collect();
            let labels: Vec<u32> = (0..batch).map(|_| rng.below(4)).collect();
            (images, labels)
        })
        .collect();
    let (ref_curve, ref_params) = run_curve(&mut resnet_trainer(1, 2, 6), &data);
    for workers in [2usize, 3] {
        let (curve, params) = run_curve(&mut resnet_trainer(workers, 2, 6), &data);
        assert_eq!(curve, ref_curve, "resnet curve diverged at workers={workers}");
        assert_bits_eq(&ref_params, &params, &format!("resnet workers={workers} params"));
    }
}

// ---------------------------------------------------------------------------
// DP reduces to the plain single-replica path
// ---------------------------------------------------------------------------

/// A one-worker, one-leaf (shard >= batch) DP step is bitwise a plain
/// `train_step` loop: same float ops, same `* (1/b)` loss head — DP is a
/// strict generalization, not a parallel approximation of training.
#[test]
fn single_leaf_dp_step_is_bitwise_a_plain_train_step() {
    let (batch, steps, seed) = (10usize, 4usize, 909u64);
    let data = batches(steps, batch, 11);
    let spec = MulSpec::parse("lut:afm16").unwrap();

    let mut plain = Lenet300::init(N_IN, CLASSES, seed);
    let mul = spec.kernel();
    let plain_curve: Vec<(u32, u32)> = data
        .iter()
        .map(|(images, labels)| {
            let x = Tensor::from_vec(&[batch, N_IN], images.clone());
            let (loss, acc) = plain.train_step(&mul, &x, labels, LR);
            (loss.to_bits(), acc.to_bits())
        })
        .collect();

    let mut tr = lenet_trainer(1, batch, &spec, seed);
    let (dp_curve, dp_params) = run_curve(&mut tr, &data);
    assert_eq!(dp_curve, plain_curve, "DP loss/acc head diverged from train_step");
    let plain_params: Vec<u32> = plain.flat_params().iter().map(|v| v.to_bits()).collect();
    assert_bits_eq(&plain_params, &dp_params, "params vs plain train_step");
}

// ---------------------------------------------------------------------------
// Gradient accumulation
// ---------------------------------------------------------------------------

/// k micro-batches through `step_accum` are bitwise one monolithic
/// concatenated-batch `step` when leaf boundaries align (shard divides the
/// micro-batch size): both cut into the *same* leaf list and reduce
/// through the *same* tree with the *same* effective-batch divisor.
#[test]
fn aligned_gradient_accumulation_is_bitwise_the_monolithic_batch() {
    let (micro, k, shard, seed) = (8usize, 3usize, 4usize, 303u64);
    let data = batches(3, micro * k, 2024); // each step is one big batch
    let spec = MulSpec::parse("direct:afm16").unwrap();

    let mut mono = lenet_trainer(2, shard, &spec, seed);
    let mut accum = lenet_trainer(3, shard, &spec, seed); // worker count free to differ
    for (images, labels) in &data {
        let a = mono.step(images, labels).unwrap();
        let micros: Vec<(&[f32], &[u32])> = (0..k)
            .map(|i| {
                (&images[i * micro * N_IN..(i + 1) * micro * N_IN], &labels[i * micro..(i + 1) * micro])
            })
            .collect();
        let b = accum.step_accum(&micros).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "accumulated loss diverged");
        assert_eq!(a.acc.to_bits(), b.acc.to_bits(), "accumulated accuracy diverged");
        assert_eq!(a.leaves, b.leaves, "leaf decompositions differ");
    }
    let mono_params: Vec<u32> = mono.flat_params().iter().map(|v| v.to_bits()).collect();
    let accum_params: Vec<u32> = accum.flat_params().iter().map(|v| v.to_bits()).collect();
    assert_bits_eq(&mono_params, &accum_params, "accumulated params");
}

/// Aligned accumulation holds for the batchnorm resnet too — identical
/// leaves mean identical batch statistics. The teeth: with *misaligned*
/// decompositions (one 8-row leaf vs two 4-row leaves) the resnet
/// legitimately diverges, because its batch statistics normalize over
/// each `grad_step` call's rows — a different shard size is a different
/// BN model, not a numerical bug. That is exactly why `DpConfig::shard`
/// is a standalone config knob and never derived from the worker count.
#[test]
fn resnet_accumulation_aligned_matches_and_misaligned_diverges() {
    let mut rng = Pcg32::seeded(616);
    let (batch, elems, seed) = (8usize, 8 * 8 * 3, 13u64);
    let images: Vec<f32> = (0..batch * elems).map(|_| rng.range(-1.0, 1.0)).collect();
    let labels: Vec<u32> = (0..batch).map(|_| rng.below(4)).collect();
    let micros: Vec<(&[f32], &[u32])> =
        (0..2).map(|i| (&images[i * 4 * elems..(i + 1) * 4 * elems], &labels[i * 4..(i + 1) * 4])).collect();

    // aligned: shard 4 on both sides -> same 4-row leaves -> bitwise equal
    let mut mono = resnet_trainer(1, 4, seed);
    let mut accum = resnet_trainer(2, 4, seed);
    mono.step(&images, &labels).unwrap();
    accum.step_accum(&micros).unwrap();
    let mono_params: Vec<u32> = mono.flat_params().iter().map(|v| v.to_bits()).collect();
    let accum_params: Vec<u32> = accum.flat_params().iter().map(|v| v.to_bits()).collect();
    assert_bits_eq(&mono_params, &accum_params, "aligned resnet accumulation");

    // misaligned: one 8-row leaf (BN over 8 rows) vs two 4-row leaves
    // (BN over 4 rows) must disagree somewhere — the documented caveat
    let mut wide = resnet_trainer(1, batch, seed);
    wide.step(&images, &labels).unwrap();
    let wide_params: Vec<u32> = wide.flat_params().iter().map(|v| v.to_bits()).collect();
    assert!(
        wide_params.iter().zip(&accum_params).any(|(a, b)| a != b),
        "8-row-leaf and 4-row-leaf batchnorm produced identical bits — the \
         batch-stats caveat documented in coordinator::data_parallel no \
         longer bites (did BN switch to running statistics?)"
    );
}

// ---------------------------------------------------------------------------
// Sharded checkpoint resume
// ---------------------------------------------------------------------------

/// Training interrupted by a sharded save / reload — into a trainer with
/// a *different worker count* and different init seed — finishes on
/// bit-identical weights and curve tail versus the uninterrupted run.
/// Checkpoint sharding is a storage choice; worker count is a throughput
/// choice; neither touches the bits.
#[test]
fn sharded_checkpoint_resume_is_bit_identical_across_worker_counts() {
    let data = batches(6, 12, 777);
    let split = 3;
    let spec = MulSpec::parse("lut:afm16").unwrap();

    let (full_curve, full_params) = run_curve(&mut lenet_trainer(2, 4, &spec, 55), &data);

    let mut first = lenet_trainer(2, 4, &spec, 55);
    let (head_curve, _) = run_curve(&mut first, &data[..split]);
    assert_eq!(head_curve, &full_curve[..split], "pre-split curves should already agree");
    let dir = std::env::temp_dir().join("approxtrain_dp_resume_test");
    let _ = std::fs::remove_dir_all(&dir);
    first.save_sharded(&dir, 3).unwrap();
    drop(first);

    // resume into a differently-initialized trainer with 4 workers
    let mut resumed = lenet_trainer(4, 4, &spec, 55 + 999);
    resumed.load_sharded(&dir).unwrap();
    let (tail_curve, tail_params) = run_curve(&mut resumed, &data[split..]);
    assert_eq!(tail_curve, &full_curve[split..], "post-resume curve diverged");
    assert_bits_eq(&full_params, &tail_params, "post-resume params");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Fail-stop robustness
// ---------------------------------------------------------------------------

/// A multiplier that always panics — stands in for any mid-step replica
/// failure. Built into replicas *explicitly* (never via `MulSpec::clone`,
/// which resolves `direct:` names through the registry).
struct PanicMul;

impl ApproxMul for PanicMul {
    fn name(&self) -> &str {
        "panic16"
    }
    fn mantissa_bits(&self) -> u32 {
        8
    }
    fn mul(&self, _a: f32, _b: f32) -> f32 {
        panic!("injected replica failure")
    }
    fn mantissa_product(&self, _ma: u32, _mb: u32) -> (u32, u32) {
        panic!("injected replica failure")
    }
}

/// A replica panicking mid-step must fail-stop: a typed error carrying the
/// panic context, parameters bit-untouched on every replica (no torn
/// update — `grad_step` is compute-only), no deadlock, and the shared
/// thread pool still serves subsequent healthy trainers.
#[test]
fn replica_panic_fails_stop_without_torn_update() {
    let data = batches(1, 8, 99);
    let (images, labels) = &data[0];

    let replicas = vec![
        TrainReplica {
            model: CpuModel::Lenet300(Lenet300::init(N_IN, CLASSES, 3)),
            mul: MulSpec::Native,
        },
        TrainReplica {
            model: CpuModel::Lenet300(Lenet300::init(N_IN, CLASSES, 3)),
            mul: MulSpec::Direct(Box::new(PanicMul)),
        },
    ];
    // batch 8 / shard 4 = 2 leaves over 2 workers: the PanicMul replica
    // is guaranteed a leaf
    let mut tr =
        DpTrainer::from_replicas(replicas, DpConfig { workers: 2, shard: 4, lr: LR }).unwrap();
    let before: Vec<u32> = tr.flat_params().iter().map(|v| v.to_bits()).collect();

    let err = tr.step(images, labels).unwrap_err().to_string();
    assert!(err.contains("panicked mid-step"), "untyped error: {err}");
    assert!(err.contains("injected replica failure"), "panic context lost: {err}");

    let after: Vec<u32> = tr.flat_params().iter().map(|v| v.to_bits()).collect();
    assert_bits_eq(&before, &after, "params after failed step");

    // the global pool survived the panic: a healthy trainer still steps,
    // and still bit-matches a single-worker twin
    let spec = MulSpec::Native;
    let (curve2, params2) = run_curve(&mut lenet_trainer(2, 4, &spec, 3), &data);
    let (curve1, params1) = run_curve(&mut lenet_trainer(1, 4, &spec, 3), &data);
    assert_eq!(curve1, curve2, "post-panic pool produced a divergent curve");
    assert_bits_eq(&params1, &params2, "post-panic params");
}
