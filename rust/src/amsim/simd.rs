//! AVX2 specializations of the AMSim panel kernels — Algorithm 2 with
//! 8 lanes of sign/exponent/mantissa decomposition and a `vpgatherdd`
//! LUT-row gather per step (the CPU analogue of the paper's §IV GPU LUT
//! gather).
//!
//! Every function here is bit-identical to the scalar body it
//! specializes, by construction:
//!
//! * lanes run **across independent accumulator chains** (the `nr`
//!   columns of a micro-tile, the `acc[j]` of a rank-1 update) or, for
//!   the single-chain dot, only across the *product* computation — the
//!   adds of any one chain stay strictly serial in ascending contraction
//!   order (see [`crate::util::simd`] for why this is load-bearing);
//! * the per-lane product assembly is exact integer arithmetic (masks,
//!   shifts, adds, compares, one gather), so a lane computes precisely
//!   the scalar [`super::AmSim::mul_bits`] result — including unsigned
//!   `+0.0` on flush-to-zero and `sign | EXP_MASK` on post-carry
//!   overflow;
//! * all memory accesses are unaligned loads/stores (`loadu`/`storeu`),
//!   so packed panels at odd offsets are handled without alignment luck
//!   (ci.sh runs an explicit odd-offset smoke).
//!
//! # Safety
//!
//! Callers (the dispatchers in [`super::AmSim`]) must guarantee AVX2 is
//! available — enforced by clamping every requested [`crate::util::simd::
//! SimdLevel`] to the machine — and that the LUT invariant
//! `lut.len() == 1 << (2*m)` holds, re-asserted *hard* at panel entry so
//! an out-of-range gather index can never become UB here. Within that
//! contract the gather index `(amnt << m) | bmnt` is `2m`-bit by
//! construction and always in bounds.

use core::arch::x86_64::*;

use crate::mult::fpbits::{EXP_BIAS, EXP_MASK, MANT_BITS, MANT_MASK, SIGN_MASK};

use super::{AmSim, MR_MAX};

/// FP32 lanes per AVX2 vector. The micro-kernel's vector arm engages on
/// column chunks of this width; narrower strips fall through to the
/// scalar tail.
pub const LANES: usize = 8;

/// Per-lane operand decomposition (Algorithm 2 lines 7-8, 11-12):
/// `(mantissa >> shift, biased exponent, sign bit)` for 8 packed FP32
/// bit patterns. `shift` is the runtime `23 - m` count in a `__m128i`.
///
/// # Safety
/// AVX2 must be available — reached only from the `target_feature`
/// arms below; the intrinsics here touch no memory.
#[inline(always)]
unsafe fn decompose(bits: __m256i, shift: __m128i) -> (__m256i, __m256i, __m256i) {
    let mnt = _mm256_srl_epi32(_mm256_and_si256(bits, _mm256_set1_epi32(MANT_MASK as i32)), shift);
    let exp = _mm256_srli_epi32::<{ MANT_BITS as i32 }>(_mm256_and_si256(
        bits,
        _mm256_set1_epi32(EXP_MASK as i32),
    ));
    let sign = _mm256_and_si256(bits, _mm256_set1_epi32(SIGN_MASK as i32));
    (mnt, exp, sign)
}

/// Assemble 8 Algorithm-2 products: gather the LUT entries at `idx`,
/// combine with the hoisted exponents/signs, apply flush-to-zero and
/// post-carry overflow saturation per lane. Returns the products as FP32
/// lanes ready for one ordered `add_ps` into independent accumulator
/// chains.
///
/// Lane-for-lane this is exactly [`AmSim::mul_bits`]: the flush mask
/// (`ea == 0 || eb == 0 || exp <= 0`) is applied *last* so it wins over
/// the overflow blend, mirroring the scalar early-return order.
///
/// # Safety
/// AVX2 must be available, and every `idx` lane must be in bounds for
/// the LUT (`idx < 2^(2m)`, hard-asserted at panel entry) — the gather
/// reads `lut[idx]` unchecked.
#[inline(always)]
unsafe fn assemble(
    lut: *const i32,
    idx: __m256i,
    a_exp: __m256i,
    b_exp: __m256i,
    sign: __m256i,
) -> __m256 {
    // SAFETY (in-bounds gather): idx lanes are (amnt << m) | bmnt with
    // both halves m-bit, so idx < 2^(2m) == lut.len() — the invariant
    // hard-asserted at panel entry.
    let entry = _mm256_i32gather_epi32::<4>(lut, idx);
    let zero = _mm256_setzero_si256();
    let exp = _mm256_add_epi32(_mm256_add_epi32(a_exp, b_exp), _mm256_set1_epi32(-EXP_BIAS));
    let flush = _mm256_or_si256(
        _mm256_or_si256(_mm256_cmpeq_epi32(a_exp, zero), _mm256_cmpeq_epi32(b_exp, zero)),
        _mm256_cmpgt_epi32(_mm256_set1_epi32(1), exp),
    );
    let carry = _mm256_and_si256(
        _mm256_srli_epi32::<{ MANT_BITS as i32 }>(entry),
        _mm256_set1_epi32(1),
    );
    let exp2 = _mm256_add_epi32(exp, carry);
    let inf = _mm256_cmpgt_epi32(exp2, _mm256_set1_epi32(254));
    // normal assembly; lanes headed for flush/inf hold garbage here and
    // are overwritten by the blend/andnot below
    let norm = _mm256_or_si256(
        _mm256_or_si256(sign, _mm256_slli_epi32::<{ MANT_BITS as i32 }>(exp2)),
        _mm256_and_si256(entry, _mm256_set1_epi32(MANT_MASK as i32)),
    );
    let infv = _mm256_or_si256(sign, _mm256_set1_epi32(EXP_MASK as i32));
    let res = _mm256_blendv_epi8(norm, infv, inf);
    _mm256_castsi256_ps(_mm256_andnot_si256(flush, res))
}

/// AVX2 arm of [`AmSim::mul_microtile`]: lanes across the `nr` column
/// chains, `mr` accumulator vectors hoisted across the whole `kk` loop,
/// the `A` operand decomposed once per `(kk, r)` and broadcast. Columns
/// past the last full 8-wide chunk drain through the scalar gather in
/// the same ascending-`kk` order (independent chains, so the column
/// split cannot change any chain's add sequence).
///
/// # Safety
/// AVX2 must be available at runtime (the detected/forced `SimdLevel`
/// dispatch guarantees it), `lut.len() == 1 << (2 * m)` with
/// `shift == 23 - m`, and the slices must satisfy
/// `acc.len() >= mr * nr`, `a.len() >= mr * k_len`,
/// `b.len() >= k_len * nr`: the unaligned loads/stores and the LUT
/// gather are unchecked offsets inside those bounds.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn lut_microtile_avx2(
    lut: &[u32],
    m: u32,
    shift: u32,
    acc: &mut [f32],
    a: &[f32],
    b: &[f32],
    mr: usize,
    nr: usize,
    k_len: usize,
) {
    let lut_ptr = lut.as_ptr() as *const i32;
    let shiftv = _mm_cvtsi32_si128(shift as i32);
    let full = nr - nr % LANES;
    let mut c0 = 0;
    while c0 < full {
        let mut accv = [_mm256_setzero_ps(); MR_MAX];
        for (r, av) in accv.iter_mut().enumerate().take(mr) {
            *av = _mm256_loadu_ps(acc.as_ptr().add(r * nr + c0));
        }
        for kk in 0..k_len {
            let bbits = _mm256_loadu_si256(b.as_ptr().add(kk * nr + c0) as *const __m256i);
            let (b_mnt, b_exp, b_sign) = decompose(bbits, shiftv);
            for (r, av) in accv.iter_mut().enumerate().take(mr) {
                let abits = a[r * k_len + kk].to_bits();
                let arow = ((abits & MANT_MASK) >> shift) << m;
                let a_exp = _mm256_set1_epi32(((abits & EXP_MASK) >> MANT_BITS) as i32);
                let idx = _mm256_or_si256(_mm256_set1_epi32(arow as i32), b_mnt);
                let sign =
                    _mm256_xor_si256(_mm256_set1_epi32((abits & SIGN_MASK) as i32), b_sign);
                let prod = assemble(lut_ptr, idx, a_exp, b_exp, sign);
                *av = _mm256_add_ps(*av, prod);
            }
        }
        for (r, av) in accv.iter().enumerate().take(mr) {
            _mm256_storeu_ps(acc.as_mut_ptr().add(r * nr + c0), *av);
        }
        c0 += LANES;
    }
    if full < nr {
        for kk in 0..k_len {
            for r in 0..mr {
                let ab = a[r * k_len + kk].to_bits();
                for c in full..nr {
                    let p = AmSim::gather(lut, m, shift, ab, b[kk * nr + c].to_bits());
                    acc[r * nr + c] += f32::from_bits(p);
                }
            }
        }
    }
}

/// AVX2 arm of [`AmSim::fma_row`]: lanes across the `acc[j]` chains, the
/// broadcast operand decomposed once. A zero/subnormal `x` needs no
/// special case — its zero exponent raises the flush mask in every lane,
/// so each chain receives the same `+0.0` add the scalar path applies.
///
/// # Safety
/// AVX2 must be available, `lut.len() == 1 << (2 * m)` with
/// `shift == 23 - m`, and `row.len() >= acc.len()`: the 8-wide loads
/// read `row[i..i + 8]` / `acc[i..i + 8]` unchecked.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn lut_fma_row_avx2(
    lut: &[u32],
    m: u32,
    shift: u32,
    acc: &mut [f32],
    x: f32,
    row: &[f32],
) {
    let n = acc.len();
    let lut_ptr = lut.as_ptr() as *const i32;
    let shiftv = _mm_cvtsi32_si128(shift as i32);
    let xb = x.to_bits();
    let a_row = _mm256_set1_epi32((((xb & MANT_MASK) >> shift) << m) as i32);
    let a_exp = _mm256_set1_epi32(((xb & EXP_MASK) >> MANT_BITS) as i32);
    let a_sign = _mm256_set1_epi32((xb & SIGN_MASK) as i32);
    let mut i = 0;
    while i + LANES <= n {
        let bbits = _mm256_loadu_si256(row.as_ptr().add(i) as *const __m256i);
        let (b_mnt, b_exp, b_sign) = decompose(bbits, shiftv);
        let idx = _mm256_or_si256(a_row, b_mnt);
        let sign = _mm256_xor_si256(a_sign, b_sign);
        let prod = assemble(lut_ptr, idx, a_exp, b_exp, sign);
        let av = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(av, prod));
        i += LANES;
    }
    while i < n {
        acc[i] += f32::from_bits(AmSim::gather(lut, m, shift, xb, row[i].to_bits()));
        i += 1;
    }
}

/// AVX2 arm of [`AmSim::dot_acc`]. A dot is a **single** accumulator
/// chain, so only the product computation (decompose + gather +
/// assemble, all exact integer ops) is vectorized; the 8 products are
/// spilled to a lane buffer and added strictly in ascending index order
/// — the only order the blocking-independence contract allows.
///
/// # Safety
/// AVX2 must be available, `lut.len() == 1 << (2 * m)` with
/// `shift == 23 - m`, and `b.len() >= a.len()`: the paired 8-wide
/// loads read both slices at the same offsets unchecked.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn lut_dot_acc_avx2(
    lut: &[u32],
    m: u32,
    shift: u32,
    init: f32,
    a: &[f32],
    b: &[f32],
) -> f32 {
    let n = a.len();
    let lut_ptr = lut.as_ptr() as *const i32;
    let shiftv = _mm_cvtsi32_si128(shift as i32);
    let mv = _mm_cvtsi32_si128(m as i32);
    let mut acc = init;
    let mut lanes = [0.0f32; LANES];
    let mut i = 0;
    while i + LANES <= n {
        let abits = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let bbits = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let (a_mnt, a_exp, a_sign) = decompose(abits, shiftv);
        let (b_mnt, b_exp, b_sign) = decompose(bbits, shiftv);
        let idx = _mm256_or_si256(_mm256_sll_epi32(a_mnt, mv), b_mnt);
        let sign = _mm256_xor_si256(a_sign, b_sign);
        let prod = assemble(lut_ptr, idx, a_exp, b_exp, sign);
        _mm256_storeu_ps(lanes.as_mut_ptr(), prod);
        for &p in &lanes {
            acc += p;
        }
        i += LANES;
    }
    while i < n {
        acc += f32::from_bits(AmSim::gather(lut, m, shift, a[i].to_bits(), b[i].to_bits()));
        i += 1;
    }
    acc
}

/// AVX2 arm of [`AmSim::mul_slice`]: purely elementwise, one vector of
/// products per 8 outputs.
///
/// # Safety
/// AVX2 must be available, `lut.len() == 1 << (2 * m)` with
/// `shift == 23 - m`, and `a`, `b`, `out` must be the same length
/// (callers assert): loads and stores are unchecked at shared offsets.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn lut_mul_slice_avx2(
    lut: &[u32],
    m: u32,
    shift: u32,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
) {
    let n = out.len();
    let lut_ptr = lut.as_ptr() as *const i32;
    let shiftv = _mm_cvtsi32_si128(shift as i32);
    let mv = _mm_cvtsi32_si128(m as i32);
    let mut i = 0;
    while i + LANES <= n {
        let abits = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let bbits = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let (a_mnt, a_exp, a_sign) = decompose(abits, shiftv);
        let (b_mnt, b_exp, b_sign) = decompose(bbits, shiftv);
        let idx = _mm256_or_si256(_mm256_sll_epi32(a_mnt, mv), b_mnt);
        let sign = _mm256_xor_si256(a_sign, b_sign);
        let prod = assemble(lut_ptr, idx, a_exp, b_exp, sign);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), prod);
        i += LANES;
    }
    while i < n {
        out[i] =
            f32::from_bits(AmSim::gather(lut, m, shift, a[i].to_bits(), b[i].to_bits()));
        i += 1;
    }
}
