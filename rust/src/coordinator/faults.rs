//! Deterministic fault-injection harness for the networked serving tier.
//!
//! Robustness claims are only worth what their tests can *force*: this
//! module is the injection registry the `serve_net` suite scripts. A
//! [`FaultPlan`] is shared (cheap [`Clone`], `Arc` inner) between a test
//! and the server it spawned; the server consults it at two
//! deterministic points —
//!
//! * **per lane, per batch** ([`FaultPlan::before_batch`]): an armed
//!   *delay* sleeps the lane (modelling a slow replica — used to force
//!   queue pressure and in-queue deadline expiry without racing the
//!   scheduler), an armed *kill* makes the lane return an error mid-batch
//!   after it has already popped requests (the fail-stop path must still
//!   answer every one of them);
//! * **per request, at admission** ([`FaultPlan::on_admission`]): an
//!   armed admission delay burns the request's deadline budget inside the
//!   server, forcing the admission-time deadline check.
//!
//! Faults are keyed on (tenant, lane) and batch *indices*, never wall
//! time, so every scripted scenario is reproducible. Counters record what
//! actually fired, letting tests assert the fault happened rather than
//! silently passing when it did not.
//!
//! The bottom half holds client-side fault helpers: raw-socket writers
//! that send truncated, oversized, or garbage frames — the peer
//! misbehavior half of the injection matrix.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::wire::{self, FrameKind, WireError};

type LaneKey = (String, usize);

#[derive(Default)]
struct FaultState {
    /// (tenant, lane) → kill the lane on its batch index >= n
    kill_after: Mutex<BTreeMap<LaneKey, u64>>,
    /// (tenant, lane) → sleep this long before every batch
    lane_delay: Mutex<BTreeMap<LaneKey, Duration>>,
    /// tenant → sleep this long inside admission (burns deadline budget)
    admission_delay: Mutex<BTreeMap<String, Duration>>,
    kills_fired: AtomicU64,
    delays_applied: AtomicU64,
    admission_delays_applied: AtomicU64,
}

/// Shared, scriptable fault registry. `FaultPlan::default()` (or
/// [`FaultPlan::none`]) injects nothing and is what production callers
/// pass.
#[derive(Clone, Default)]
pub struct FaultPlan {
    inner: Arc<FaultState>,
}

impl FaultPlan {
    /// An empty plan: every hook is a no-op.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Arm a lane kill: the lane errors out (mid-batch, after popping
    /// requests) on its `after_batches`-th batch (0 = the first).
    pub fn kill_lane(&self, tenant: &str, lane: usize, after_batches: u64) {
        self.inner
            .kill_after
            .lock()
            .unwrap()
            .insert((tenant.to_string(), lane), after_batches);
    }

    /// Arm a per-batch lane delay (a slow replica).
    pub fn delay_lane(&self, tenant: &str, lane: usize, delay: Duration) {
        self.inner.lane_delay.lock().unwrap().insert((tenant.to_string(), lane), delay);
    }

    /// Disarm a lane delay.
    pub fn clear_lane_delay(&self, tenant: &str, lane: usize) {
        self.inner.lane_delay.lock().unwrap().remove(&(tenant.to_string(), lane));
    }

    /// Arm an admission delay for a tenant: every request sleeps this
    /// long between arrival and the admission deadline check.
    pub fn delay_admission(&self, tenant: &str, delay: Duration) {
        self.inner.admission_delay.lock().unwrap().insert(tenant.to_string(), delay);
    }

    /// Disarm the admission delay.
    pub fn clear_admission_delay(&self, tenant: &str) {
        self.inner.admission_delay.lock().unwrap().remove(tenant);
    }

    /// Server hook: called by a lane after popping a batch, before any
    /// compute. Applies an armed delay, then an armed kill (as a typed
    /// error the lane propagates into its fail-stop path).
    pub(crate) fn before_batch(&self, tenant: &str, lane: usize, batch_index: u64) -> Result<()> {
        let delay =
            self.inner.lane_delay.lock().unwrap().get(&(tenant.to_string(), lane)).copied();
        if let Some(d) = delay {
            self.inner.delays_applied.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(d);
        }
        let kill =
            self.inner.kill_after.lock().unwrap().get(&(tenant.to_string(), lane)).copied();
        if let Some(after) = kill {
            if batch_index >= after {
                self.inner.kills_fired.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!(
                    "injected fault: tenant {tenant:?} lane {lane} killed at batch {batch_index}"
                );
            }
        }
        Ok(())
    }

    /// Server hook: called inside admission, after the arrival timestamp
    /// is taken and before the deadline check.
    pub(crate) fn on_admission(&self, tenant: &str) {
        let delay = self.inner.admission_delay.lock().unwrap().get(tenant).copied();
        if let Some(d) = delay {
            self.inner.admission_delays_applied.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(d);
        }
    }

    /// How many armed kills actually fired.
    pub fn kills_fired(&self) -> u64 {
        self.inner.kills_fired.load(Ordering::Relaxed)
    }

    /// How many lane-batch delays were applied.
    pub fn delays_applied(&self) -> u64 {
        self.inner.delays_applied.load(Ordering::Relaxed)
    }

    /// How many admission delays were applied.
    pub fn admission_delays_applied(&self) -> u64 {
        self.inner.admission_delays_applied.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Client-side fault helpers (peer misbehavior)
// ---------------------------------------------------------------------------

/// Connect, write `bytes` raw, then close immediately without reading —
/// a peer that dies (or lies) mid-conversation.
pub fn send_raw_and_close(addr: SocketAddr, bytes: &[u8]) -> std::io::Result<()> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(bytes)?;
    s.flush()?;
    s.shutdown(Shutdown::Both)?;
    Ok(())
}

/// Write only the first `keep` bytes of `frame` then close: a mid-frame
/// disconnect.
pub fn send_truncated(addr: SocketAddr, frame: &[u8], keep: usize) -> std::io::Result<()> {
    send_raw_and_close(addr, &frame[..keep.min(frame.len())])
}

/// A frame header declaring a `declared`-byte body that never follows —
/// probes that the server validates the length before allocating.
pub fn oversized_header(declared: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(wire::HEADER_LEN);
    out.extend_from_slice(&wire::MAGIC);
    out.push(1); // request kind
    out.extend_from_slice(&declared.to_le_bytes());
    out
}

/// Connect, write `bytes` raw, then block for one response frame — used
/// by tests asserting that garbage in gets a *typed* `BadRequest` frame
/// out (followed by a close), not a hang or a panic.
pub fn send_raw_and_read_reply(
    addr: SocketAddr,
    bytes: &[u8],
) -> Result<(FrameKind, Vec<u8>), WireError> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(10)))?;
    s.write_all(bytes)?;
    s.flush()?;
    let _ = s.shutdown(Shutdown::Write);
    wire::read_frame(&mut s)
}

/// Drain and discard whatever the peer sends until EOF (bounded by the
/// stream's read timeout); used after hostile writes where the reply
/// content does not matter.
pub fn drain_to_eof(s: &mut TcpStream) -> std::io::Result<usize> {
    let mut total = 0usize;
    let mut buf = [0u8; 4096];
    loop {
        match s.read(&mut buf) {
            Ok(0) => return Ok(total),
            Ok(n) => total += n,
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_fires_only_at_threshold_and_counts() {
        let plan = FaultPlan::none();
        plan.kill_lane("t0", 1, 2);
        // other lane / other tenant untouched
        assert!(plan.before_batch("t0", 0, 5).is_ok());
        assert!(plan.before_batch("other", 1, 5).is_ok());
        // armed lane survives batches 0 and 1, dies on 2
        assert!(plan.before_batch("t0", 1, 0).is_ok());
        assert!(plan.before_batch("t0", 1, 1).is_ok());
        assert_eq!(plan.kills_fired(), 0);
        let err = plan.before_batch("t0", 1, 2).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert_eq!(plan.kills_fired(), 1);
    }

    #[test]
    fn delays_count_and_clear() {
        let plan = FaultPlan::none();
        plan.delay_lane("t0", 0, Duration::from_millis(1));
        assert!(plan.before_batch("t0", 0, 0).is_ok());
        assert_eq!(plan.delays_applied(), 1);
        plan.clear_lane_delay("t0", 0);
        assert!(plan.before_batch("t0", 0, 1).is_ok());
        assert_eq!(plan.delays_applied(), 1, "cleared delay no longer applies");
        plan.delay_admission("t0", Duration::from_millis(1));
        plan.on_admission("t0");
        plan.on_admission("other");
        assert_eq!(plan.admission_delays_applied(), 1);
        plan.clear_admission_delay("t0");
        plan.on_admission("t0");
        assert_eq!(plan.admission_delays_applied(), 1);
    }

    #[test]
    fn clones_share_state() {
        let plan = FaultPlan::none();
        let other = plan.clone();
        other.kill_lane("t", 0, 0);
        assert!(plan.before_batch("t", 0, 0).is_err());
        assert_eq!(plan.kills_fired(), other.kills_fired());
    }

    #[test]
    fn oversized_header_shape() {
        let h = oversized_header(u32::MAX);
        assert_eq!(h.len(), wire::HEADER_LEN);
        let hdr: [u8; wire::HEADER_LEN] = h.try_into().unwrap();
        assert!(matches!(wire::decode_header(&hdr), Err(WireError::Oversized { .. })));
    }
}
