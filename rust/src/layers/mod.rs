//! Pure-Rust reference layers: the ATxC ("CPU direct simulation") execution
//! path of the paper's Tables V/VI and the numeric oracle that the compiled
//! artifacts are validated against (mirroring the paper's own methodology,
//! §VI footnote 2: "The CPU implementation was used for validating our GPU
//! implementation").
//!
//! Each layer is built from the kernels in [`crate::kernels`], with every
//! multiplication routed through a [`MulKernel`](crate::kernels::MulKernel).
pub mod activations;
pub mod amconv2d;
pub mod amdense;
pub mod batchnorm;
pub mod softmax;
