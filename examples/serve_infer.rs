//! Serving example: batched approximate-multiplier inference behind a
//! router/batcher, reporting latency percentiles and throughput — the
//! deployment shape of ApproxTrain's inference support.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_infer
//! ```

use std::path::Path;
use std::time::{Duration, Instant};

use approxtrain::coordinator::server::with_server;
use approxtrain::data::synth::{mnist_like, SynthSpec};
use approxtrain::lut::MantissaLut;
use approxtrain::nn::init::init_params;
use approxtrain::runtime::artifact::Role;
use approxtrain::runtime::executor::Engine;
use approxtrain::util::json::Json;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let mut engine = Engine::new(dir)?;
    let art = engine
        .manifest()
        .find("lenet300", "fwd", "lut")
        .expect("lenet300 lut fwd artifact (run `make artifacts`)")
        .clone();
    engine.prepare(&art.name)?; // compile before serving
    let raw = Json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)?;
    let params = init_params(&art, 42, &raw)?;
    let lut = MantissaLut::load(&dir.join("luts/afm16.lut")).map_err(|e| anyhow::anyhow!("{e}"))?;
    let x_spec = &art.inputs[art.input_indices(Role::Input)[0]];
    let batch = x_spec.shape[0];
    let image_elems = x_spec.elements() / batch;
    let classes = art.outputs[0].shape[1];

    let n_requests = 256;
    let n_clients = 8;
    let ds = mnist_like(&SynthSpec { n: n_requests, ..SynthSpec::mnist_like_default() });
    println!("serving lenet300 (AFM16 via AMSim LUT), batch {batch}, {n_clients} clients, {n_requests} requests");

    let t0 = Instant::now();
    let name = art.name.clone();
    let stats = with_server(
        engine,
        &name,
        params,
        Some(lut.entries),
        batch,
        image_elems,
        classes,
        Duration::from_millis(4),
        |client| {
            std::thread::scope(|s| {
                for t in 0..n_clients {
                    let client = client.clone();
                    let ds = &ds;
                    s.spawn(move || {
                        for i in (t..n_requests).step_by(n_clients) {
                            client.infer(ds.image(i).to_vec()).expect("inference");
                        }
                    });
                }
            });
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();
    println!("served {} requests in {} batches over {:.2}s", stats.requests, stats.batches, wall);
    println!("throughput: {:.0} req/s", stats.requests as f64 / wall);
    println!(
        "latency: p50 {:.1} ms | p90 {:.1} ms | p99 {:.1} ms (mean {:.1} ms, max {:.1} ms)",
        stats.latency_percentile_s(50.0) * 1e3,
        stats.latency_percentile_s(90.0) * 1e3,
        stats.latency_percentile_s(99.0) * 1e3,
        stats.mean_latency_s() * 1e3,
        stats.max_latency_s() * 1e3
    );
    println!("mean batch fill: {:.1}/{}", stats.mean_fill(), batch);
    Ok(())
}
