//! Statistics helpers used by the benchmark harness and experiment reports
//! (the paper reports geometric means of speed ratios, §IX).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean; panics on non-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Error metrics between a reference and an approximation — used to
/// characterize multiplier models (mean relative error distance, bias).
pub struct ErrStats {
    /// mean relative error distance (MRED)
    pub mred: f64,
    /// mean (signed) relative error — the "bias" the AFM design minimizes
    pub bias: f64,
    /// max relative error
    pub max_re: f64,
}

pub fn relative_error_stats(exact: &[f64], approx: &[f64]) -> ErrStats {
    assert_eq!(exact.len(), approx.len());
    let mut sum_abs = 0.0;
    let mut sum_signed = 0.0;
    let mut max_re: f64 = 0.0;
    let mut n = 0usize;
    for (&e, &a) in exact.iter().zip(approx) {
        if e == 0.0 {
            continue;
        }
        let re = (a - e) / e;
        sum_abs += re.abs();
        sum_signed += re;
        max_re = max_re.max(re.abs());
        n += 1;
    }
    let n = n.max(1) as f64;
    ErrStats { mred: sum_abs / n, bias: sum_signed / n, max_re }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_geomean() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn stddev_basics() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
        assert!((stddev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn err_stats() {
        let exact = [1.0, 2.0, 4.0];
        let approx = [1.1, 1.9, 4.0];
        let s = relative_error_stats(&exact, &approx);
        assert!(s.mred > 0.0 && s.mred < 0.1);
        assert!(s.max_re <= 0.1 + 1e-12);
    }
}
