//! Pooling kernels. The paper notes pooling layers involve no
//! multiplications (§III-A, Table I) — they run exactly in every
//! configuration; they exist here so the CPU (ATxC) path can execute
//! complete LeNet/ResNet models.

/// 2x2 max-pool, stride 2, NHWC. Returns `(output, argmax_indices)`;
/// the indices feed the backward pass.
pub fn maxpool2x2(
    input: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<f32>, Vec<usize>) {
    assert_eq!(input.len(), batch * h * w * c);
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2x2 needs even spatial dims");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; batch * oh * ow * c];
    let mut arg = vec![0usize; out.len()];
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let idx =
                                ((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch;
                            if input[idx] > best {
                                best = input[idx];
                                best_idx = idx;
                            }
                        }
                    }
                    let o = ((b * oh + oy) * ow + ox) * c + ch;
                    out[o] = best;
                    arg[o] = best_idx;
                }
            }
        }
    }
    (out, arg)
}

/// Backward of [`maxpool2x2`]: routes each output gradient to its argmax.
pub fn maxpool2x2_backward(dy: &[f32], argmax: &[usize], input_len: usize) -> Vec<f32> {
    assert_eq!(dy.len(), argmax.len());
    let mut dx = vec![0.0f32; input_len];
    for (g, &idx) in dy.iter().zip(argmax) {
        dx[idx] += g;
    }
    dx
}

/// Global average pool over the spatial dims: `[b, h, w, c] -> [b, c]`.
pub fn global_avgpool(input: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    assert_eq!(input.len(), batch * h * w * c);
    let mut out = vec![0.0f32; batch * c];
    let inv = 1.0 / (h * w) as f32;
    for b in 0..batch {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    out[b * c + ch] += input[((b * h + y) * w + x) * c + ch];
                }
            }
        }
    }
    for v in &mut out {
        *v *= inv;
    }
    out
}

/// Backward of [`global_avgpool`].
pub fn global_avgpool_backward(
    dy: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    assert_eq!(dy.len(), batch * c);
    let inv = 1.0 / (h * w) as f32;
    let mut dx = vec![0.0f32; batch * h * w * c];
    for b in 0..batch {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    dx[((b * h + y) * w + x) * c + ch] = dy[b * c + ch] * inv;
                }
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_picks_max_and_routes_grad() {
        // single 2x2 image, one channel
        let input = vec![1.0, 4.0, 2.0, 3.0];
        let (out, arg) = maxpool2x2(&input, 1, 2, 2, 1);
        assert_eq!(out, vec![4.0]);
        assert_eq!(arg, vec![1]);
        let dx = maxpool2x2_backward(&[5.0], &arg, 4);
        assert_eq!(dx, vec![0.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn avgpool_and_backward() {
        let input = vec![1.0, 2.0, 3.0, 4.0]; // 1x2x2x1
        let out = global_avgpool(&input, 1, 2, 2, 1);
        assert_eq!(out, vec![2.5]);
        let dx = global_avgpool_backward(&out, 1, 2, 2, 1);
        assert_eq!(dx, vec![0.625; 4]);
    }

    #[test]
    fn maxpool_channels_independent() {
        // 2x2, 2 channels interleaved
        let input = vec![
            1.0, 40.0, //
            2.0, 30.0, //
            3.0, 20.0, //
            4.0, 10.0,
        ];
        let (out, _) = maxpool2x2(&input, 1, 2, 2, 2);
        assert_eq!(out, vec![4.0, 40.0]);
    }
}
