//! PJRT runtime — loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto`: jax >= 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `/opt/xla-example/README.md`).
pub mod artifact;
pub mod executor;

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client wrapper. One per process; executables are compiled from
/// HLO text files and cached by the [`executor::Engine`].
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this environment; on a real
    /// TPU deployment this would be the TPU plugin and the same artifacts,
    /// minus interpret-mode lowering, would run on hardware).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text file into an executable.
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Convert an f32 slice + shape to an [`xla::Literal`].
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Convert a u32 slice to a rank-1 literal (the LUT operand).
pub fn literal_u32(data: &[u32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Convert an i32 slice + shape to a literal (labels).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}
