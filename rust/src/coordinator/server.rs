//! Batching inference server — the deployment-shaped consumer of the
//! inference path (ApproxTrain "also supports inference using approximate
//! multipliers", §I).
//!
//! Architecture (vLLM-router-like, scaled to this crate): client threads
//! submit single requests to a queue; a batcher thread collects up to
//! `batch` requests (padding with zero rows when the timeout fires), runs
//! the forward artifact once, and distributes per-request results. The
//! tokio crate is not available offline, so the event loop is
//! std::sync::mpsc + threads — same topology.

use std::sync::mpsc::{self, Receiver, Sender};

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::nn::metrics::accuracy_from_logits;
use crate::runtime::executor::{Engine, Value};
use crate::util::rng::Pcg32;
use crate::util::stats as ustats;

/// One inference request: an image and a oneshot-style reply channel.
/// (fields used by the serve loop)
pub struct Request {
    image: Vec<f32>,
    reply: Sender<Reply>,
    submitted: Instant,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// how many real requests shared the batch (for metrics)
    pub batch_fill: usize,
}

/// Server handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    image_elems: usize,
}

impl Client {
    /// Blocking inference call.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        assert_eq!(image.len(), self.image_elems, "image size");
        let (reply_tx, reply_rx) = mpsc::channel();
        let submitted = Instant::now();
        self.tx
            .send(Request { image, reply: reply_tx, submitted })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))
    }
}

/// Default sample capacity of the latency [`Reservoir`]: 4096 `f64`s =
/// 32 KiB, enough for stable tail percentiles, constant forever.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Bounded uniform reservoir sample (Vitter's Algorithm R): after any
/// number of `push`es it holds a uniform random sample of at most `cap`
/// of the values seen, so a long-lived server keeps O(1) stats memory
/// while percentiles stay representative. Deterministically seeded — two
/// servers fed the same stream report the same percentiles.
#[derive(Clone, Debug)]
pub struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    cap: usize,
    rng: Pcg32,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir { samples: Vec::new(), seen: 0, cap, rng: Pcg32::new(0x5EED, 0x4E5) }
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
            return;
        }
        // replace a random slot with probability cap/seen (Algorithm R)
        let j = if self.seen <= u32::MAX as u64 {
            self.rng.below(self.seen as u32) as u64
        } else {
            self.rng.next_u64() % self.seen
        };
        if (j as usize) < self.cap {
            self.samples[j as usize] = v;
        }
    }

    /// Total values offered (not the retained sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The retained sample (≤ cap values, unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Percentile over the retained sample; exact until `cap` values have
    /// been seen, an unbiased estimate after. 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            ustats::percentile(&self.samples, p)
        }
    }
}

/// Server statistics — O(1) memory regardless of lifetime: latencies go
/// into a bounded [`Reservoir`] plus exact streaming sum/max accumulators,
/// batch fills into a streaming sum. (Earlier revisions pushed one `f64`
/// per request forever.)
#[derive(Clone, Debug)]
pub struct Stats {
    pub requests: usize,
    pub batches: usize,
    /// bounded latency sample, seconds (percentile queries)
    pub latencies: Reservoir,
    latency_sum_s: f64,
    latency_max_s: f64,
    fill_sum: u64,
}

impl Default for Stats {
    fn default() -> Stats {
        Stats {
            requests: 0,
            batches: 0,
            latencies: Reservoir::new(LATENCY_RESERVOIR_CAP),
            latency_sum_s: 0.0,
            latency_max_s: 0.0,
            fill_sum: 0,
        }
    }
}

impl Stats {
    fn record_request(&mut self, latency_s: f64) {
        self.requests += 1;
        self.latencies.push(latency_s);
        self.latency_sum_s += latency_s;
        self.latency_max_s = self.latency_max_s.max(latency_s);
    }

    fn record_batch(&mut self, fill: usize) {
        self.batches += 1;
        self.fill_sum += fill as u64;
    }

    /// Latency percentile in seconds (reservoir estimate; exact for the
    /// first [`LATENCY_RESERVOIR_CAP`] requests).
    pub fn latency_percentile_s(&self, p: f64) -> f64 {
        self.latencies.percentile(p)
    }

    /// Exact mean latency in seconds (streaming, not reservoir-based).
    pub fn mean_latency_s(&self) -> f64 {
        self.latency_sum_s / (self.requests.max(1)) as f64
    }

    /// Exact maximum latency in seconds.
    pub fn max_latency_s(&self) -> f64 {
        self.latency_max_s
    }

    /// Exact mean batch fill (real requests per executed batch).
    pub fn mean_fill(&self) -> f64 {
        self.fill_sum as f64 / (self.batches.max(1)) as f64
    }
}

/// Run the batching server loop until the request channel closes.
/// `fwd_artifact` must be a forward artifact; `fixed_inputs` are the
/// params (+ optional LUT) in positional order around the image input.
pub fn serve(
    engine: &mut Engine,
    fwd_artifact: &str,
    params: Vec<Value>,
    lut: Option<Vec<u32>>,
    rx: Receiver<Request>,
    batch: usize,
    image_elems: usize,
    classes: usize,
    max_wait: Duration,
) -> Result<Stats> {
    // Warm the persistent kernel worker pool before the serving loop so
    // first-request latency never includes thread spawning, and
    // pre-allocate the per-lane pack buffers of the tiled GEMM (best
    // effort); all batched CPU kernel work behind the forward pass shares
    // this pool across batches.
    crate::kernels::gemm::warm_tiled();
    let mut stats = Stats::default();
    loop {
        // collect up to `batch` requests, waiting at most max_wait after
        // the first arrives (the paper-world "dynamic batching" policy)
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all clients done
        };
        let deadline = Instant::now() + max_wait;
        let mut pending = vec![first];
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // assemble the fixed-shape batch (zero padding for empty slots)
        let fill = pending.len();
        let mut images = vec![0.0f32; batch * image_elems];
        for (i, r) in pending.iter().enumerate() {
            images[i * image_elems..(i + 1) * image_elems].copy_from_slice(&r.image);
        }
        let mut inputs = params.clone();
        inputs.push(Value::F32(images));
        if let Some(l) = &lut {
            inputs.push(Value::U32(l.clone()));
        }
        let out = engine.run(fwd_artifact, &inputs)?;
        let logits = out[0].as_f32()?;
        for (i, r) in pending.into_iter().enumerate() {
            let latency = r.submitted.elapsed();
            stats.record_request(latency.as_secs_f64());
            let _ = r.reply.send(Reply {
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency,
                batch_fill: fill,
            });
        }
        stats.record_batch(fill);
    }
    Ok(stats)
}

/// Convenience: run the batcher/executor loop on the *current* thread (the
/// PJRT client is not `Send`) while the `load` closure drives traffic from
/// a spawned thread. When `load` returns and drops its `Client`, the
/// request channel closes and the server loop exits.
pub fn with_server<F>(
    mut engine: Engine,
    fwd_artifact: &str,
    params: Vec<Value>,
    lut: Option<Vec<u32>>,
    batch: usize,
    image_elems: usize,
    classes: usize,
    max_wait: Duration,
    load: F,
) -> Result<Stats>
where
    F: FnOnce(Client) + Send,
{
    let (tx, rx) = mpsc::channel::<Request>();
    let client = Client { tx, image_elems };
    std::thread::scope(|s| -> Result<Stats> {
        let loader = s.spawn(move || load(client));
        let stats =
            serve(&mut engine, fwd_artifact, params, lut, rx, batch, image_elems, classes, max_wait)?;
        loader.join().expect("load thread panicked");
        Ok(stats)
    })
}

/// Classify a reply against a label (test helper + example metric).
pub fn reply_correct(reply: &Reply, label: u32) -> bool {
    accuracy_from_logits(&reply.logits, &[label], reply.logits.len()) > 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_is_bounded_and_exact_below_cap() {
        let mut r = Reservoir::new(8);
        for i in 0..5 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 5);
        assert_eq!(r.samples().len(), 5);
        // below cap the sample is the full stream: percentiles are exact
        assert_eq!(r.percentile(0.0), 0.0);
        assert_eq!(r.percentile(100.0), 4.0);
        assert_eq!(r.percentile(50.0), 2.0);
        for i in 5..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 10_000);
        assert_eq!(r.samples().len(), 8, "memory stays bounded at cap");
        for &s in r.samples() {
            assert!((0.0..10_000.0).contains(&s));
        }
    }

    #[test]
    fn reservoir_sample_stays_representative() {
        // push a stream whose median is ~500; the reservoir median of a
        // 256-sample reservoir must land in the right region
        let mut r = Reservoir::new(256);
        for i in 0..100_000u64 {
            r.push((i % 1000) as f64);
        }
        let p50 = r.percentile(50.0);
        assert!((300.0..700.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn stats_memory_is_constant_and_report_fields_exact() {
        let n = LATENCY_RESERVOIR_CAP * 3;
        let mut s = Stats::default();
        let mut sum = 0.0f64;
        for i in 0..n {
            let v = 0.001 * (i % 100) as f64;
            s.record_request(v);
            sum += v;
            if i % 4 == 3 {
                s.record_batch(4);
            }
        }
        assert_eq!(s.requests, n);
        assert_eq!(s.latencies.samples().len(), LATENCY_RESERVOIR_CAP);
        // streaming fields are exact regardless of the reservoir
        assert_eq!(s.batches, n / 4);
        assert_eq!(s.mean_fill(), 4.0);
        assert!((s.max_latency_s() - 0.099).abs() < 1e-12);
        assert!((s.mean_latency_s() - sum / n as f64).abs() < 1e-12);
        // empty stats report zeros, not NaN/panic
        let empty = Stats::default();
        assert_eq!(empty.latency_percentile_s(99.0), 0.0);
        assert_eq!(empty.mean_latency_s(), 0.0);
        assert_eq!(empty.mean_fill(), 0.0);
    }
}
