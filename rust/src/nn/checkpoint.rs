//! Checkpoints: named f32 tensors in a simple binary container (magic +
//! count + per-tensor name/shape/payload + crc). Used for the cross-format
//! experiment (Table IV: train with one multiplier, evaluate with another)
//! and the pruning flow (Fig 11: load a pre-trained model, prune, retrain).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::lut::format::crc32;

pub const MAGIC: &[u8; 8] = b"ATCKPT\x01\0";

/// An ordered set of named tensors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn insert(&mut self, name: &str, shape: &[usize], data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.tensors.insert(name.to_string(), (shape.to_vec(), data));
    }

    pub fn get(&self, name: &str) -> Option<&(Vec<usize>, Vec<f32>)> {
        self.tensors.get(name)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        body.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, (shape, data)) in &self.tensors {
            let nb = name.as_bytes();
            body.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            body.extend_from_slice(nb);
            body.extend_from_slice(&(shape.len() as u32).to_le_bytes());
            for &d in shape {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            body.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for &v in data {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut out = Vec::with_capacity(body.len() + 16);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse a checkpoint. Every declared length is validated against the
    /// bytes actually present **before** any allocation, so corrupt,
    /// truncated, or hostile inputs get a typed error — never a panic or
    /// an attacker-sized allocation.
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 12 || &data[0..8] != MAGIC {
            bail!("not a checkpoint file");
        }
        let want_crc = u32::from_le_bytes(data[8..12].try_into().unwrap());
        let body = &data[12..];
        if crc32(body) != want_crc {
            bail!("checkpoint payload corrupt");
        }
        let mut pos = 0usize;
        let need = |pos: usize, n: usize, what: &str| -> Result<()> {
            match pos.checked_add(n) {
                Some(end) if end <= body.len() => Ok(()),
                _ => bail!("truncated checkpoint ({what})"),
            }
        };
        let rd_u32 = |pos: &mut usize| -> Result<u32> {
            need(*pos, 4, "u32 field")?;
            let v = u32::from_le_bytes(body[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let rd_u64 = |pos: &mut usize| -> Result<u64> {
            need(*pos, 8, "u64 field")?;
            let v = u64::from_le_bytes(body[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };
        let count = rd_u32(&mut pos)?;
        let mut ckpt = Checkpoint::default();
        for _ in 0..count {
            let nlen = rd_u32(&mut pos)? as usize;
            need(pos, nlen, "tensor name")?;
            let name = std::str::from_utf8(&body[pos..pos + nlen])
                .context("bad tensor name")?
                .to_string();
            pos += nlen;
            let rank = rd_u32(&mut pos)? as usize;
            // a rank-r shape needs 8r bytes: validate before with_capacity
            need(pos, rank.checked_mul(8).context("rank overflows")?, "shape")?;
            let mut shape = Vec::with_capacity(rank);
            let mut elems: usize = 1;
            for _ in 0..rank {
                let d = rd_u64(&mut pos)? as usize;
                elems = elems.checked_mul(d).with_context(|| format!("shape of {name} overflows"))?;
                shape.push(d);
            }
            let n = rd_u64(&mut pos)? as usize;
            if n != elems {
                bail!("tensor {name}: shape {shape:?} holds {elems} elements, payload declares {n}");
            }
            let payload = n.checked_mul(4).with_context(|| format!("tensor {name} too large"))?;
            need(pos, payload, "tensor payload")?;
            let data: Vec<f32> = body[pos..pos + payload]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += payload;
            if ckpt.tensors.insert(name.clone(), (shape, data)).is_some() {
                bail!("duplicate tensor {name}");
            }
        }
        if pos != body.len() {
            bail!("checkpoint has {} trailing bytes", body.len() - pos);
        }
        Ok(ckpt)
    }

    /// Atomic save: write to a temp file in the destination directory,
    /// fsync, then rename over `path` (+ best-effort directory fsync). A
    /// crash mid-write can never leave a torn checkpoint at `path` — the
    /// old file survives intact until the rename publishes the new one.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        write_atomic(path, &self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?
            .read_to_end(&mut data)?;
        Self::from_bytes(&data).with_context(|| format!("parsing checkpoint {}", path.display()))
    }
}

/// Write `bytes` to `path` atomically: temp file in the same directory →
/// `sync_all` → `rename` → best-effort directory fsync. On any error the
/// temp file is removed and `path` is untouched.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let file_name = path.file_name().and_then(|n| n.to_str()).unwrap_or("file");
    let tmp = dir.join(format!(".{file_name}.tmp.{}", std::process::id()));
    let result = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        // data must be durable BEFORE the rename publishes the name
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        // make the rename itself durable where the platform allows it
        if let Ok(d) = std::fs::File::open(&dir) {
            let _ = d.sync_all();
        }
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::default();
        c.insert("fc1/w", &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        c.insert("fc1/b", &[3], vec![0.1, 0.2, 0.3]);
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.get("fc1/w").unwrap().0, vec![2, 3]);
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::default();
        c.insert("w", &[2], vec![1.0, 2.0]);
        let mut bytes = c.to_bytes();
        let n = bytes.len();
        bytes[n - 2] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
        assert!(Checkpoint::from_bytes(b"junk").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut c = Checkpoint::default();
        c.insert("x", &[1], vec![42.0]);
        let path = std::env::temp_dir().join("approxtrain_ckpt_test/a.ckpt");
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
    }

    /// Wrap a raw body in the container framing with a *valid* CRC — the
    /// hostile-input tests must get past the checksum to reach the parser.
    fn wrap(body: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(body.len() + 12);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&crc32(body).to_le_bytes());
        out.extend_from_slice(body);
        out
    }

    #[test]
    fn truncation_at_every_byte_is_a_typed_error() {
        let mut c = Checkpoint::default();
        c.insert("fc1/w", &[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        c.insert("b", &[2], vec![-1.0, 0.5]);
        let bytes = c.to_bytes();
        for keep in 0..bytes.len() {
            assert!(
                Checkpoint::from_bytes(&bytes[..keep]).is_err(),
                "prefix of {keep} bytes must be rejected"
            );
        }
        assert!(Checkpoint::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn hostile_declared_sizes_rejected_before_allocating() {
        // name length far past the end of the body
        let mut body = vec![];
        body.extend_from_slice(&1u32.to_le_bytes()); // count
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // nlen
        assert!(Checkpoint::from_bytes(&wrap(&body)).is_err());
        // absurd rank: must fail the bounds check before with_capacity
        let mut body = vec![];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'w');
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // rank
        assert!(Checkpoint::from_bytes(&wrap(&body)).is_err());
        // element count whose byte size dwarfs the file
        let mut body = vec![];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'w');
        body.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        body.extend_from_slice(&(1u64 << 40).to_le_bytes()); // dim
        body.extend_from_slice(&(1u64 << 40).to_le_bytes()); // n
        assert!(Checkpoint::from_bytes(&wrap(&body)).is_err());
        // shape-product vs payload-count mismatch
        let mut body = vec![];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'w');
        body.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        body.extend_from_slice(&3u64.to_le_bytes()); // shape [3]
        body.extend_from_slice(&2u64.to_le_bytes()); // but n = 2
        body.extend_from_slice(&[0u8; 8]); // the 2 f32s
        assert!(Checkpoint::from_bytes(&wrap(&body)).is_err());
    }

    #[test]
    fn trailing_bytes_and_duplicates_rejected() {
        let mut c = Checkpoint::default();
        c.insert("w", &[1], vec![1.0]);
        let bytes = c.to_bytes();
        let mut body = bytes[12..].to_vec();
        body.push(0xAA);
        assert!(Checkpoint::from_bytes(&wrap(&body)).is_err(), "trailing garbage");
        // two tensors with the same name
        let one = &bytes[12 + 4..]; // strip count, keep the tensor record
        let mut body = vec![];
        body.extend_from_slice(&2u32.to_le_bytes());
        body.extend_from_slice(one);
        body.extend_from_slice(one);
        assert!(Checkpoint::from_bytes(&wrap(&body)).is_err(), "duplicate tensor name");
    }

    #[test]
    fn torn_write_never_leaves_a_loadable_corpse() {
        let dir = std::env::temp_dir().join("approxtrain_ckpt_torn");
        let path = dir.join("model.ckpt");
        let mut v1 = Checkpoint::default();
        v1.insert("w", &[2], vec![1.0, 2.0]);
        v1.save(&path).unwrap();
        // a non-atomic writer dying mid-write would leave a prefix: the
        // loader must reject it rather than resurrect half a model
        let full = v2_bytes();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "torn file must not parse");
        // the atomic path recovers: a fresh save publishes a whole file,
        // and a stale temp corpse from a dead writer is just ignored
        std::fs::write(dir.join(".model.ckpt.tmp.999"), b"corpse").unwrap();
        let mut v2 = Checkpoint::default();
        v2.insert("w", &[2], vec![3.0, 4.0]);
        v2.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), v2);
    }

    fn v2_bytes() -> Vec<u8> {
        let mut v2 = Checkpoint::default();
        v2.insert("w", &[2], vec![3.0, 4.0]);
        v2.to_bytes()
    }

    #[test]
    fn write_atomic_failure_leaves_target_untouched() {
        let dir = std::env::temp_dir().join("approxtrain_ckpt_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("keep.ckpt");
        std::fs::write(&path, b"original").unwrap();
        // destination is a directory → rename fails → original intact
        let blocked = dir.join("blocked.ckpt");
        let _ = std::fs::remove_dir_all(&blocked);
        std::fs::create_dir_all(&blocked).unwrap();
        assert!(write_atomic(&blocked, b"new").is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"original");
        // and no temp corpse survives the failure
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files must be cleaned up: {leftovers:?}");
    }
}
