"""LUT generation: structure, carry recovery, binary format."""

import io
import zlib

import numpy as np
import pytest

from compile import lutgen, mults


@pytest.mark.parametrize("name", ["bfloat16", "afm16", "mit16", "realm16",
                                  "trunc16", "comp16"])
def test_lut_matches_mantissa_product(name):
    """Black-box probing (Alg 1) must recover mantissa_product exactly."""
    m = mults.by_name(name)
    lut = lutgen.generate(m)
    assert lut.shape == (1 << (2 * m.m),)
    k = np.arange(1 << m.m, dtype=np.uint32)
    kk, jj = np.meshgrid(k, k, indexing="ij")
    carry, mant = m.mantissa_product((kk << np.uint32(23 - m.m)).ravel(),
                                     (jj << np.uint32(23 - m.m)).ravel())
    want = (carry << np.uint32(23)) | mant
    assert np.array_equal(lut, want)


def test_lut_size_matches_paper():
    # paper: m=7 -> 2^7 * 2^7 * 4 bytes = 65.53 kB
    lut = lutgen.generate(mults.by_name("bfloat16"))
    assert lut.nbytes == 65536


def test_binary_format_roundtrip():
    m = mults.by_name("afm16")
    lut = lutgen.generate(m)
    blob = lutgen.to_bytes(m.name, m.m, lut)
    assert blob[:8] == lutgen.MAGIC
    mm = int.from_bytes(blob[8:12], "little")
    nlen = int.from_bytes(blob[12:16], "little")
    assert mm == 7
    assert blob[16:16 + nlen].decode() == "afm16"
    payload = blob[16 + nlen:-4]
    assert np.array_equal(np.frombuffer(payload, "<u4"), lut)
    crc = int.from_bytes(blob[-4:], "little")
    assert crc == (zlib.crc32(payload) & 0xFFFFFFFF)


def test_wide_mantissa_rejected():
    with pytest.raises(AssertionError):
        lutgen.generate(mults.by_name("afm32"))


def test_entries_have_valid_structure():
    for name in mults.LUT_ABLE:
        m = mults.by_name(name)
        lut = lutgen.generate(m)
        assert np.all(lut >> 24 == 0), name  # nothing above carry bit
        low_mask = (1 << (23 - m.m)) - 1
        assert np.all(lut & low_mask == 0), name  # no sub-m mantissa bits
