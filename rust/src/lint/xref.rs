//! R7 — registration cross-checks between artifacts that must agree but
//! live in different files:
//!
//! * every `rust/tests/*.rs` suite has a `Cargo.toml` `[[test]]` entry
//!   (the crate sets `autotests = false`, so an unregistered suite
//!   silently never runs) — and every entry has a file;
//! * every `--test <name>` pinned in `ci.sh` names a registered suite
//!   (a renamed suite must not leave a stale pin behind);
//! * the `approxtrain/bench_*/v*` schema-version strings emitted by
//!   `rust/src/coordinator/experiments.rs` and documented in
//!   `docs/BENCHMARKS.md` are the same set in both directions.

use std::fs;
use std::path::Path;

use crate::lint::lexer::{find_sub, line_of, line_offsets};
use crate::lint::Finding;

fn missing(path: &str, out: &mut Vec<Finding>) {
    out.push(Finding {
        rule: "R7",
        path: path.to_string(),
        line: 1,
        msg: "required file missing or unreadable".to_string(),
    });
}

pub fn r7_xref(root: &Path, out: &mut Vec<Finding>) {
    // -- Cargo.toml [[test]] registrations
    let cargo = match fs::read_to_string(root.join("Cargo.toml")) {
        Ok(t) => t,
        Err(_) => return missing("Cargo.toml", out),
    };
    let mut registered: Vec<(String, usize)> = Vec::new();
    let mut in_test_section = false;
    for (i, raw) in cargo.lines().enumerate() {
        let t = raw.trim();
        if t.starts_with('[') {
            in_test_section = t == "[[test]]";
            continue;
        }
        if in_test_section && t.starts_with("name") {
            if let Some(name) = t.split('"').nth(1) {
                registered.push((name.to_string(), i + 1));
            }
        }
    }

    // -- rust/tests/*.rs suite files (top level only; fixture corpora in
    //    subdirectories are data, not suites)
    let tests_dir = root.join("rust/tests");
    let mut stems: Vec<String> = Vec::new();
    match fs::read_dir(&tests_dir) {
        Ok(rd) => {
            for entry in rd.flatten() {
                let p = entry.path();
                if p.is_file() && p.extension().is_some_and(|e| e == "rs") {
                    if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                        stems.push(stem.to_string());
                    }
                }
            }
        }
        Err(_) => return missing("rust/tests", out),
    }
    stems.sort();
    for stem in &stems {
        if !registered.iter().any(|(n, _)| n == stem) {
            out.push(Finding {
                rule: "R7",
                path: format!("rust/tests/{stem}.rs"),
                line: 1,
                msg: format!(
                    "suite `{stem}` has no Cargo.toml [[test]] entry (autotests are off: it \
                     would silently never run)"
                ),
            });
        }
    }
    for (name, line) in &registered {
        if !stems.contains(name) {
            out.push(Finding {
                rule: "R7",
                path: "Cargo.toml".to_string(),
                line: *line,
                msg: format!("[[test]] entry `{name}` has no rust/tests/{name}.rs file"),
            });
        }
    }

    // -- ci.sh --test pins (shell comments stripped line-wise)
    match fs::read_to_string(root.join("ci.sh")) {
        Ok(ci) => {
            for (i, raw) in ci.lines().enumerate() {
                let code = raw.split('#').next().unwrap_or("");
                for pos in find_sub(code, "--test ") {
                    let rest = &code[pos + "--test ".len()..];
                    let name: String = rest
                        .chars()
                        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                        .collect();
                    if !name.is_empty() && !registered.iter().any(|(n, _)| *n == name) {
                        out.push(Finding {
                            rule: "R7",
                            path: "ci.sh".to_string(),
                            line: i + 1,
                            msg: format!("pinned suite `{name}` is not a registered [[test]]"),
                        });
                    }
                }
            }
        }
        Err(_) => missing("ci.sh", out),
    }

    // -- BENCH schema versions: emitter vs methodology doc
    let exp_path = "rust/src/coordinator/experiments.rs";
    let doc_path = "docs/BENCHMARKS.md";
    let exp = fs::read_to_string(root.join(exp_path));
    let doc = fs::read_to_string(root.join(doc_path));
    match (exp, doc) {
        (Ok(exp), Ok(doc)) => {
            let emitted = schema_strings(&exp);
            let documented = schema_strings(&doc);
            let doc_offs = line_offsets(&doc);
            let exp_offs = line_offsets(&exp);
            for (s, pos) in &emitted {
                if !documented.iter().any(|(d, _)| d == s) {
                    out.push(Finding {
                        rule: "R7",
                        path: exp_path.to_string(),
                        line: line_of(&exp_offs, *pos),
                        msg: format!("schema `{s}` is emitted but not documented in {doc_path}"),
                    });
                }
            }
            for (s, pos) in &documented {
                if !emitted.iter().any(|(e, _)| e == s) {
                    out.push(Finding {
                        rule: "R7",
                        path: doc_path.to_string(),
                        line: line_of(&doc_offs, *pos),
                        msg: format!("schema `{s}` is documented but never emitted by {exp_path}"),
                    });
                }
            }
        }
        (exp, doc) => {
            if exp.is_err() {
                missing(exp_path, out);
            }
            if doc.is_err() {
                missing(doc_path, out);
            }
        }
    }
}

/// Distinct `approxtrain/bench_*/v*`-style schema tokens in `text`, with
/// the byte position of their first occurrence.
fn schema_strings(text: &str) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for pos in find_sub(text, "approxtrain/bench_") {
        let token: String = text[pos..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || matches!(c, '/' | '_'))
            .collect();
        if !out.iter().any(|(t, _)| *t == token) {
            out.push((token, pos));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_tokens_terminate_and_dedupe() {
        let s = "w(\"approxtrain/bench_gemm/v5\") and `approxtrain/bench_gemm/v5` plus \
                 \"approxtrain/bench_serve/v2\"";
        let toks = schema_strings(s);
        let names: Vec<&str> = toks.iter().map(|(t, _)| t.as_str()).collect();
        assert_eq!(names, ["approxtrain/bench_gemm/v5", "approxtrain/bench_serve/v2"]);
    }
}
