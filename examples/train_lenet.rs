//! End-to-end validation driver (DESIGN.md: "one of your examples MUST be
//! an end-to-end driver"): train LeNet-5 on the synthetic MNIST workload
//! for a few hundred steps with an *approximate* multiplier (AFM16 through
//! the LUT artifact) and with exact FP32, from the same seed, and log both
//! loss curves — the paper's Fig 10(b) in miniature.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_lenet
//! ```

use std::path::Path;

use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
use approxtrain::data::synth::{mnist_like, SynthSpec};
use approxtrain::runtime::executor::Engine;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let mut engine = Engine::new(dir)?;
    let ds = mnist_like(&SynthSpec { n: 640, ..SynthSpec::mnist_like_default() });
    let (train, test) = ds.split(128);
    println!("synthetic MNIST: {} train / {} test samples", train.n, test.n);

    let mut finals = Vec::new();
    for (disp, mode, mult) in
        [("FP32 (exact)", "custom", "fp32"), ("AFM16 (approximate)", "lut", "afm16")]
    {
        println!("\n=== {disp} ===");
        let cfg = TrainConfig {
            model: "lenet5".into(),
            mode: mode.into(),
            mult: mult.into(),
            epochs: 5,
            lr: 0.05,
            seed: 42, // identical init across multipliers
            eval_every: 1,
        };
        let mut tr = Trainer::new(&mut engine, cfg, dir)?;
        let log = tr.fit(&train, &test)?;
        for e in &log.epochs {
            println!(
                "epoch {:>2}  loss {:.4}  train acc {:>6.2}%  test acc {:>6.2}%  ({:.1}s, {} steps)",
                e.epoch,
                e.train_loss,
                e.train_acc * 100.0,
                e.test_acc * 100.0,
                e.seconds,
                train.n / tr.batch_size()
            );
        }
        finals.push((disp, log.final_test_acc()));
    }
    println!("\nfinal test accuracy:");
    for (disp, acc) in &finals {
        println!("  {disp:<22} {:.2}%", acc * 100.0);
    }
    let diff = (finals[0].1 - finals[1].1).abs() * 100.0;
    println!("difference: {diff:.2} pp (paper Table III reports <= 0.2 pp at full scale)");
    Ok(())
}
