//! Length-prefixed binary wire protocol for the networked serving tier.
//!
//! Layout of one frame (little endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"ATW1"
//! 4       1     kind   (1 = request, 2 = response)
//! 5       4     body length (u32, <= MAX_BODY — validated BEFORE any
//!               allocation, so a hostile declared size can never OOM)
//! 9       n     body
//! 9+n     4     crc32 of the body
//! ```
//!
//! Request body: `id u64 | priority u8 | deadline_ms u32 | tenant_len u16
//! | tenant utf-8 | image_count u32 | image f32s`. The deadline is a
//! **relative** time budget in milliseconds (0 = none) so client and
//! server need no clock sync; the server stamps it against its own clock
//! at frame arrival.
//!
//! Response body: `id u64 | status u8 | epoch u64 | logit_count u32 |
//! logits f32s | msg_len u16 | msg utf-8`. `epoch` is the model epoch
//! that computed the logits (LUT hot-swaps bump it), 0 for replies that
//! never reached a backend.
//!
//! Every decode path is total: malformed bytes are a typed [`WireError`],
//! never a panic, and all declared sizes are checked against what is
//! actually present before anything is allocated or sliced.

use std::io::{Read, Write};

use crate::lut::format::crc32;

/// Frame magic: "ApproxTrain Wire v1".
pub const MAGIC: [u8; 4] = *b"ATW1";
/// Fixed frame header size: magic + kind + body length.
pub const HEADER_LEN: usize = 9;
/// Hard cap on a frame body. Far above any real request (a resnet image
/// row is ~3 KiB) but small enough that a hostile length field cannot
/// drive an allocation anywhere near memory limits.
pub const MAX_BODY: usize = 1 << 24;
/// Hard cap on a tenant-name field.
pub const MAX_TENANT_LEN: usize = 128;

/// Frame discriminator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    Request,
    Response,
}

impl FrameKind {
    fn as_u8(self) -> u8 {
        match self {
            FrameKind::Request => 1,
            FrameKind::Response => 2,
        }
    }

    fn from_u8(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::Request),
            2 => Some(FrameKind::Response),
            _ => None,
        }
    }
}

/// Request priority class, highest first. Load shedding under queue
/// pressure sheds `Low` before `Normal` before `High` (see
/// `coordinator::net::admission_limit`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    High = 0,
    Normal = 1,
    Low = 2,
}

impl Priority {
    /// All classes, highest first — the queue pop / shed iteration order.
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(b: u8) -> Option<Priority> {
        match b {
            0 => Some(Priority::High),
            1 => Some(Priority::Normal),
            2 => Some(Priority::Low),
            _ => None,
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// Reply status byte. Everything except `Ok` is a typed failure; `Shed`
/// and `Overflow` are the only **idempotent** rejections (the request was
/// definitely not admitted), so they are the only statuses a client may
/// retry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Ok = 0,
    /// Shed at admission under queue pressure (below-High priority).
    Shed = 1,
    /// Admission queue full (High priority, or queue at hard depth).
    Overflow = 2,
    /// Deadline expired — at admission, in-queue, or at reply time.
    DeadlineExceeded = 3,
    UnknownTenant = 4,
    QuotaExceeded = 5,
    /// Admission closed for graceful drain.
    Draining = 6,
    /// Server stopped (or failed) before replying.
    Stopped = 7,
    /// Malformed frame or request contents.
    BadRequest = 8,
}

impl Status {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(b: u8) -> Option<Status> {
        match b {
            0 => Some(Status::Ok),
            1 => Some(Status::Shed),
            2 => Some(Status::Overflow),
            3 => Some(Status::DeadlineExceeded),
            4 => Some(Status::UnknownTenant),
            5 => Some(Status::QuotaExceeded),
            6 => Some(Status::Draining),
            7 => Some(Status::Stopped),
            8 => Some(Status::BadRequest),
            _ => None,
        }
    }

    /// True for the statuses a client may safely resend after: the
    /// request was rejected at admission without being enqueued, so a
    /// retry cannot double-execute it.
    pub fn idempotent_rejection(self) -> bool {
        matches!(self, Status::Shed | Status::Overflow)
    }
}

/// Typed wire failure — every malformed input maps here, never a panic.
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    BadMagic([u8; 4]),
    BadKind(u8),
    /// Declared body length exceeds [`MAX_BODY`]; detected from the
    /// 9-byte header alone, before any body allocation.
    Oversized { declared: usize, max: usize },
    /// A declared size runs past the bytes actually present.
    Truncated { need: usize, have: usize },
    Crc { want: u32, got: u32 },
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadKind(k) => write!(f, "bad frame kind {k}"),
            WireError::Oversized { declared, max } => {
                write!(f, "declared body length {declared} exceeds max {max}")
            }
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} more bytes, have {have}")
            }
            WireError::Crc { want, got } => {
                write!(f, "frame body corrupt: crc {got:#x} != {want:#x}")
            }
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Frame assembly / header parsing
// ---------------------------------------------------------------------------

/// Assemble a complete frame (header + body + crc) for `body`.
pub fn frame_bytes(kind: FrameKind, body: &[u8]) -> Vec<u8> {
    assert!(body.len() <= MAX_BODY, "frame body over MAX_BODY");
    let mut out = Vec::with_capacity(HEADER_LEN + body.len() + 4);
    out.extend_from_slice(&MAGIC);
    out.push(kind.as_u8());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    out.extend_from_slice(&crc32(body).to_le_bytes());
    out
}

/// Parse and validate a frame header. The declared length is bounds-
/// checked here, so callers can size the body read without ever
/// allocating for a hostile length.
pub fn decode_header(hdr: &[u8; HEADER_LEN]) -> Result<(FrameKind, usize), WireError> {
    if hdr[0..4] != MAGIC {
        return Err(WireError::BadMagic([hdr[0], hdr[1], hdr[2], hdr[3]]));
    }
    let kind = FrameKind::from_u8(hdr[4]).ok_or(WireError::BadKind(hdr[4]))?;
    let len = u32::from_le_bytes(hdr[5..9].try_into().unwrap()) as usize;
    if len > MAX_BODY {
        return Err(WireError::Oversized { declared: len, max: MAX_BODY });
    }
    Ok((kind, len))
}

/// Check a body against its trailing crc bytes.
pub fn verify_crc(body: &[u8], crc_le: &[u8]) -> Result<(), WireError> {
    if crc_le.len() != 4 {
        return Err(WireError::Truncated { need: 4, have: crc_le.len() });
    }
    let want = u32::from_le_bytes(crc_le.try_into().unwrap());
    let got = crc32(body);
    if want != got {
        return Err(WireError::Crc { want, got });
    }
    Ok(())
}

/// Write one frame (single `write_all`, so a healthy sender never emits a
/// torn frame).
pub fn write_frame(w: &mut impl Write, kind: FrameKind, body: &[u8]) -> std::io::Result<()> {
    w.write_all(&frame_bytes(kind, body))
}

/// Blocking frame read (client side and tests; the server uses its own
/// interruptible reader over the same [`decode_header`]/[`verify_crc`]).
pub fn read_frame(r: &mut impl Read) -> Result<(FrameKind, Vec<u8>), WireError> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr)?;
    let (kind, len) = decode_header(&hdr)?;
    let mut rest = vec![0u8; len + 4];
    r.read_exact(&mut rest)?;
    let crc = rest.split_off(len);
    verify_crc(&rest, &crc)?;
    Ok((kind, rest))
}

// ---------------------------------------------------------------------------
// Body encode/decode
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let have = self.b.len() - self.pos;
        if have < n {
            return Err(WireError::Truncated { need: n, have });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// `n` f32s; the byte size is computed with checked arithmetic and
    /// bounds-checked before the vector is allocated.
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| WireError::Malformed(format!("f32 count {n} overflows")))?;
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Trailing bytes after a complete decode are an error — a frame is
    /// exactly its fields, nothing smuggled after them.
    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after body",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// One inference request as carried on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestFrame {
    pub id: u64,
    pub priority: Priority,
    /// Relative deadline budget in milliseconds; 0 = no deadline.
    pub deadline_ms: u32,
    pub tenant: String,
    pub image: Vec<f32>,
}

impl RequestFrame {
    pub fn encode(&self) -> Vec<u8> {
        let tb = self.tenant.as_bytes();
        assert!(tb.len() <= MAX_TENANT_LEN, "tenant name over MAX_TENANT_LEN");
        let mut out = Vec::with_capacity(8 + 1 + 4 + 2 + tb.len() + 4 + self.image.len() * 4);
        out.extend_from_slice(&self.id.to_le_bytes());
        out.push(self.priority.as_u8());
        out.extend_from_slice(&self.deadline_ms.to_le_bytes());
        out.extend_from_slice(&(tb.len() as u16).to_le_bytes());
        out.extend_from_slice(tb);
        out.extend_from_slice(&(self.image.len() as u32).to_le_bytes());
        for &v in &self.image {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<RequestFrame, WireError> {
        let mut c = Cur::new(body);
        let id = c.u64()?;
        let pb = c.u8()?;
        let priority = Priority::from_u8(pb)
            .ok_or_else(|| WireError::Malformed(format!("bad priority byte {pb}")))?;
        let deadline_ms = c.u32()?;
        let tlen = c.u16()? as usize;
        if tlen > MAX_TENANT_LEN {
            return Err(WireError::Malformed(format!(
                "tenant name length {tlen} exceeds max {MAX_TENANT_LEN}"
            )));
        }
        let tenant = std::str::from_utf8(c.take(tlen)?)
            .map_err(|_| WireError::Malformed("tenant name not utf-8".into()))?
            .to_string();
        let n = c.u32()? as usize;
        let image = c.f32s(n)?;
        c.finish()?;
        Ok(RequestFrame { id, priority, deadline_ms, tenant, image })
    }
}

/// One reply as carried on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub status: Status,
    /// Model epoch that computed the logits (0 when no backend ran).
    pub epoch: u64,
    /// Logits for `Status::Ok`, empty otherwise.
    pub logits: Vec<f32>,
    /// Human-readable detail for failures, empty for `Ok`.
    pub message: String,
}

impl ResponseFrame {
    pub fn encode(&self) -> Vec<u8> {
        let mb = self.message.as_bytes();
        assert!(mb.len() <= u16::MAX as usize, "response message too long");
        let mut out = Vec::with_capacity(8 + 1 + 8 + 4 + self.logits.len() * 4 + 2 + mb.len());
        out.extend_from_slice(&self.id.to_le_bytes());
        out.push(self.status.as_u8());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&(self.logits.len() as u32).to_le_bytes());
        for &v in &self.logits {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(mb.len() as u16).to_le_bytes());
        out.extend_from_slice(mb);
        out
    }

    pub fn decode(body: &[u8]) -> Result<ResponseFrame, WireError> {
        let mut c = Cur::new(body);
        let id = c.u64()?;
        let sb = c.u8()?;
        let status = Status::from_u8(sb)
            .ok_or_else(|| WireError::Malformed(format!("bad status byte {sb}")))?;
        let epoch = c.u64()?;
        let n = c.u32()? as usize;
        let logits = c.f32s(n)?;
        let mlen = c.u16()? as usize;
        let message = std::str::from_utf8(c.take(mlen)?)
            .map_err(|_| WireError::Malformed("message not utf-8".into()))?
            .to_string();
        c.finish()?;
        Ok(ResponseFrame { id, status, epoch, logits, message })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> RequestFrame {
        RequestFrame {
            id: 7,
            priority: Priority::Normal,
            deadline_ms: 250,
            tenant: "t0".into(),
            image: vec![0.25, -1.5, 3.0],
        }
    }

    #[test]
    fn request_roundtrip_via_frame() {
        let r = req();
        let frame = frame_bytes(FrameKind::Request, &r.encode());
        let (kind, body) = read_frame(&mut frame.as_slice()).unwrap();
        assert_eq!(kind, FrameKind::Request);
        let back = RequestFrame::decode(&body).unwrap();
        assert_eq!(back, r);
        // f32 payload is bit-exact through the wire
        assert_eq!(
            back.image.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r.image.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn response_roundtrip() {
        let r = ResponseFrame {
            id: 9,
            status: Status::Ok,
            epoch: 3,
            logits: vec![f32::NEG_INFINITY, 0.0, -0.0, 1.5e-40],
            message: String::new(),
        };
        let back = ResponseFrame::decode(&r.encode()).unwrap();
        assert_eq!(
            back.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r.logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.status, Status::Ok);
        let e = ResponseFrame {
            id: 10,
            status: Status::DeadlineExceeded,
            epoch: 0,
            logits: vec![],
            message: "deadline expired in queue".into(),
        };
        assert_eq!(ResponseFrame::decode(&e.encode()).unwrap(), e);
    }

    #[test]
    fn bad_magic_and_kind_rejected() {
        let mut frame = frame_bytes(FrameKind::Request, &req().encode());
        frame[0] ^= 0xFF;
        assert!(matches!(read_frame(&mut frame.as_slice()), Err(WireError::BadMagic(_))));
        let mut frame = frame_bytes(FrameKind::Request, &req().encode());
        frame[4] = 99;
        assert!(matches!(read_frame(&mut frame.as_slice()), Err(WireError::BadKind(99))));
    }

    #[test]
    fn oversized_declared_length_rejected_from_header_alone() {
        // only a header exists — if the reader tried to honor the
        // declared length it would attempt a 4 GiB read; instead the
        // header check fires before any body allocation
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&MAGIC);
        hdr.push(FrameKind::Request.as_u8());
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        match read_frame(&mut hdr.as_slice()) {
            Err(WireError::Oversized { declared, max }) => {
                assert_eq!(declared, u32::MAX as usize);
                assert_eq!(max, MAX_BODY);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn truncation_at_every_boundary_is_typed() {
        let frame = frame_bytes(FrameKind::Request, &req().encode());
        for keep in 0..frame.len() {
            let err = read_frame(&mut &frame[..keep]).unwrap_err();
            match err {
                WireError::Io(_) | WireError::Truncated { .. } => {}
                other => panic!("truncated at {keep}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn crc_flip_detected() {
        let mut frame = frame_bytes(FrameKind::Response, &ResponseFrame {
            id: 1,
            status: Status::Ok,
            epoch: 1,
            logits: vec![2.0],
            message: String::new(),
        }
        .encode());
        let n = frame.len();
        frame[n - 6] ^= 0x01; // a body byte, keeping the length intact
        assert!(matches!(read_frame(&mut frame.as_slice()), Err(WireError::Crc { .. })));
    }

    #[test]
    fn malformed_bodies_are_typed_errors() {
        // bad priority byte
        let mut body = req().encode();
        body[8] = 7;
        assert!(matches!(RequestFrame::decode(&body), Err(WireError::Malformed(_))));
        // bad status byte
        let mut rb = ResponseFrame {
            id: 1,
            status: Status::Ok,
            epoch: 0,
            logits: vec![],
            message: String::new(),
        }
        .encode();
        rb[8] = 200;
        assert!(matches!(ResponseFrame::decode(&rb), Err(WireError::Malformed(_))));
        // tenant length field pointing past the end
        let mut body = req().encode();
        body[13] = 0xFF; // tenant_len low byte
        assert!(RequestFrame::decode(&body).is_err());
        // non-utf8 tenant
        let mut body = req().encode();
        body[15] = 0xFF;
        assert!(matches!(RequestFrame::decode(&body), Err(WireError::Malformed(_))));
        // trailing garbage after a complete request
        let mut body = req().encode();
        body.push(0);
        assert!(matches!(RequestFrame::decode(&body), Err(WireError::Malformed(_))));
        // declared image count larger than the remaining bytes
        let r = req();
        let mut body = r.encode();
        let off = 8 + 1 + 4 + 2 + r.tenant.len();
        body[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(RequestFrame::decode(&body).is_err());
    }

    #[test]
    fn priority_and_status_tables_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(Priority::from_u8(p.as_u8()), Some(p));
        }
        assert_eq!(Priority::from_u8(3), None);
        for s in [
            Status::Ok,
            Status::Shed,
            Status::Overflow,
            Status::DeadlineExceeded,
            Status::UnknownTenant,
            Status::QuotaExceeded,
            Status::Draining,
            Status::Stopped,
            Status::BadRequest,
        ] {
            assert_eq!(Status::from_u8(s.as_u8()), Some(s));
            assert_eq!(s.idempotent_rejection(), matches!(s, Status::Shed | Status::Overflow));
        }
        assert_eq!(Status::from_u8(9), None);
    }
}
