//! Loader for the MNIST IDX file format (big-endian magic + dims). If real
//! MNIST files are placed under `data/mnist/`, the coordinator prefers them
//! over the synthetic generator.

use std::io::Read;
use std::path::Path;

use super::Dataset;

#[derive(Debug)]
pub enum IdxError {
    Io(std::io::Error),
    BadMagic(u32),
    Shape(String),
}

impl std::fmt::Display for IdxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IdxError::Io(e) => write!(f, "idx io: {e}"),
            IdxError::BadMagic(m) => write!(f, "idx bad magic {m:#x}"),
            IdxError::Shape(s) => write!(f, "idx shape: {s}"),
        }
    }
}
impl std::error::Error for IdxError {}
impl From<std::io::Error> for IdxError {
    fn from(e: std::io::Error) -> Self {
        IdxError::Io(e)
    }
}

fn read_u32(data: &[u8], off: usize) -> u32 {
    u32::from_be_bytes(data[off..off + 4].try_into().unwrap())
}

/// Parse an IDX byte buffer into `(dims, payload)`.
pub fn parse_idx(data: &[u8]) -> Result<(Vec<usize>, &[u8]), IdxError> {
    if data.len() < 4 {
        return Err(IdxError::Shape("truncated header".into()));
    }
    let magic = read_u32(data, 0);
    // 0x0000 08 <ndims>: unsigned byte data
    if magic >> 8 != 0x8 {
        return Err(IdxError::BadMagic(magic));
    }
    let ndims = (magic & 0xFF) as usize;
    let header = 4 + 4 * ndims;
    if data.len() < header {
        return Err(IdxError::Shape("truncated dims".into()));
    }
    let dims: Vec<usize> = (0..ndims).map(|i| read_u32(data, 4 + 4 * i) as usize).collect();
    // hostile dims can overflow the product (2^32-1 per dim, up to 255
    // dims): checked math turns that into a typed error, not a panic
    let expect: usize = dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d)).ok_or_else(
        || IdxError::Shape(format!("product(dims) overflows: {dims:?}")),
    )?;
    let total = header
        .checked_add(expect)
        .ok_or_else(|| IdxError::Shape(format!("declared size overflows: {dims:?}")))?;
    if data.len() != total {
        return Err(IdxError::Shape(format!(
            "payload {} != product(dims) {}",
            data.len() - header,
            expect
        )));
    }
    Ok((dims, &data[header..]))
}

/// Number of classes an MNIST-format label file may reference (digits
/// 0-9). Validated at load time: an out-of-range label would otherwise
/// survive until it trips the softmax `assert!(y < c)` deep inside a
/// training step, long after the corrupt file was read.
pub const MNIST_CLASSES: usize = 10;

/// Load an images + labels IDX pair as a [`Dataset`] (pixels scaled to
/// [0, 1]). Labels are validated against [`MNIST_CLASSES`]; a corrupt
/// label payload is an [`IdxError::Shape`] here, not a panic mid-training.
pub fn load_pair(images_path: &Path, labels_path: &Path, name: &str) -> Result<Dataset, IdxError> {
    let mut img_bytes = Vec::new();
    std::fs::File::open(images_path)?.read_to_end(&mut img_bytes)?;
    let mut lbl_bytes = Vec::new();
    std::fs::File::open(labels_path)?.read_to_end(&mut lbl_bytes)?;
    let (idims, ipay) = parse_idx(&img_bytes)?;
    let (ldims, lpay) = parse_idx(&lbl_bytes)?;
    if idims.len() != 3 || ldims.len() != 1 || idims[0] != ldims[0] {
        return Err(IdxError::Shape(format!("dims {:?} / {:?}", idims, ldims)));
    }
    if let Some((i, &bad)) =
        lpay.iter().enumerate().find(|&(_, &b)| b as usize >= MNIST_CLASSES)
    {
        return Err(IdxError::Shape(format!(
            "label[{i}] = {bad} out of range (classes = {MNIST_CLASSES})"
        )));
    }
    let (n, h, w) = (idims[0], idims[1], idims[2]);
    Ok(Dataset {
        name: name.to_string(),
        images: ipay.iter().map(|&b| b as f32 / 255.0).collect(),
        labels: lpay.iter().map(|&b| b as u32).collect(),
        n,
        h,
        w,
        c: 1,
        classes: MNIST_CLASSES,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx(dims: &[u32], payload: &[u8]) -> Vec<u8> {
        let mut out = vec![0u8, 0, 8, dims.len() as u8];
        for &d in dims {
            out.extend_from_slice(&d.to_be_bytes());
        }
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn parse_roundtrip() {
        let data = make_idx(&[2, 2, 2], &[0, 64, 128, 255, 1, 2, 3, 4]);
        let (dims, payload) = parse_idx(&data).unwrap();
        assert_eq!(dims, vec![2, 2, 2]);
        assert_eq!(payload.len(), 8);
    }

    #[test]
    fn rejects_bad_magic_and_size() {
        assert!(parse_idx(&[1, 2, 3, 4, 5]).is_err());
        let mut data = make_idx(&[2], &[1, 2]);
        data.push(99); // extra byte
        assert!(parse_idx(&data).is_err());
    }

    #[test]
    fn truncation_at_every_byte_is_a_typed_error() {
        let data = make_idx(&[2, 2, 2], &[0, 64, 128, 255, 1, 2, 3, 4]);
        for keep in 0..data.len() {
            assert!(parse_idx(&data[..keep]).is_err(), "prefix of {keep} bytes");
        }
    }

    /// Every single-bit corruption of a valid file must yield Ok or a
    /// typed Err — never a panic. (parse_idx only borrows the payload, so
    /// there is no allocation for hostile dims to inflate either.)
    #[test]
    fn bit_flips_never_panic() {
        let data = make_idx(&[2, 3], &[1, 2, 3, 4, 5, 6]);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                let _ = parse_idx(&flipped);
            }
        }
    }

    /// Declared dims whose product overflows usize get a typed error
    /// (the unchecked product would panic in debug, wrap in release).
    #[test]
    fn overflowing_dims_product_rejected() {
        let data = make_idx(&[u32::MAX, u32::MAX, u32::MAX], &[]);
        match parse_idx(&data) {
            Err(IdxError::Shape(msg)) => assert!(msg.contains("overflow"), "{msg}"),
            other => panic!("expected Shape overflow error, got {other:?}"),
        }
    }

    /// A label byte ≥ classes must be rejected at load time with a Shape
    /// error naming the offending index/value — not die later in the
    /// softmax assert of a training step.
    #[test]
    fn load_pair_rejects_out_of_range_labels() {
        let dir = std::env::temp_dir().join("approxtrain_idx_badlabel_test");
        std::fs::create_dir_all(&dir).unwrap();
        let imgs = make_idx(&[2, 2, 2], &[0, 255, 0, 255, 255, 0, 255, 0]);
        let lbls = make_idx(&[2], &[3, 10]); // 10 >= MNIST_CLASSES
        let ip = dir.join("imgs.idx");
        let lp = dir.join("lbls.idx");
        std::fs::write(&ip, &imgs).unwrap();
        std::fs::write(&lp, &lbls).unwrap();
        let err = load_pair(&ip, &lp, "mnist").unwrap_err();
        match &err {
            IdxError::Shape(msg) => {
                assert!(msg.contains("label[1]") && msg.contains("10"), "{msg}");
            }
            other => panic!("expected Shape error, got {other}"),
        }
    }

    #[test]
    fn load_pair_via_tempfiles() {
        let dir = std::env::temp_dir().join("approxtrain_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let imgs = make_idx(&[2, 2, 2], &[0, 255, 0, 255, 255, 0, 255, 0]);
        let lbls = make_idx(&[2], &[3, 7]);
        let ip = dir.join("imgs.idx");
        let lp = dir.join("lbls.idx");
        std::fs::write(&ip, &imgs).unwrap();
        std::fs::write(&lp, &lbls).unwrap();
        let ds = load_pair(&ip, &lp, "mnist").unwrap();
        assert_eq!((ds.n, ds.h, ds.w, ds.c), (2, 2, 2, 1));
        assert_eq!(ds.labels, vec![3, 7]);
        assert_eq!(ds.images[1], 1.0);
    }
}
