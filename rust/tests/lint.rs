//! Teeth tests for the `approxlint` pass (`rust/src/lint/`).
//!
//! Both directions for every rule: each planted fixture under
//! `lint_fixtures/` trips exactly its rule when linted under the policy
//! scope the test assigns it, the clean fixture trips nothing under the
//! strictest scope, and — the meta-check — the shipped tree itself
//! lints clean, allowlists included. The fixtures are `include_str!`
//! data, never compiled: `collect_files` only walks the top level of
//! `rust/tests/`, so the lint never scans its own corpus.

use std::path::Path;

use approxtrain::lint::{self, rules, xref, Finding, SourceFile};

/// Lint `src` as if it lived at `path`, running the per-file rules that
/// push findings directly (R1/R2/R5/R6).
fn lint_as(path: &str, src: &str) -> Vec<Finding> {
    let sf = SourceFile::new(path.to_string(), src);
    let mut out = Vec::new();
    rules::r1_safety(&sf, &mut out);
    rules::r2_determinism(&sf, &mut out);
    rules::r5_condvar_locks(&sf, &mut out);
    rules::r6_cfg_gates(&sf, &mut out);
    out
}

#[test]
fn r1_fixture_missing_safety_comment() {
    let src = include_str!("lint_fixtures/r1_unsafe_no_safety.rs");
    let out = lint_as("rust/src/kernels/fx.rs", src);
    assert_eq!(out.len(), 1, "{out:?}");
    assert_eq!(out[0].rule, "R1");
}

#[test]
fn r2_fixture_fires_only_in_deterministic_scope() {
    let src = include_str!("lint_fixtures/r2_wall_clock.rs");
    let out = lint_as("rust/src/kernels/fx.rs", src);
    // 2x Instant + 3x HashMap outside tests; the #[cfg(test)] mod is exempt
    assert_eq!(out.len(), 5, "{out:?}");
    assert!(out.iter().all(|f| f.rule == "R2"), "{out:?}");
    assert!(
        lint_as("rust/src/util/fx.rs", src).is_empty(),
        "R2 must not fire outside the declared deterministic modules"
    );
}

#[test]
fn r3_fixture_site_and_normalized_key() {
    let src = include_str!("lint_fixtures/r3_atomics.rs");
    let sf = SourceFile::new("rust/src/util/fx.rs".to_string(), src);
    let sites = rules::r3_sites(&sf);
    assert_eq!(sites.len(), 1, "{sites:?}");
    assert_eq!(sites[0].1, "c.fetch_add(1,Ordering::SeqCst)");
}

#[test]
fn r4_fixture_scope_and_shapes() {
    let src = include_str!("lint_fixtures/r4_accum.rs");
    let in_scope = SourceFile::new("rust/src/kernels/fx.rs".to_string(), src);
    let mut whats: Vec<&str> = rules::r4_sites(&in_scope).iter().map(|s| s.what).collect();
    whats.sort();
    assert_eq!(whats, ["+= with product", ".sum", "mul_add"]);
    let out_of_scope = SourceFile::new("rust/src/util/fx.rs".to_string(), src);
    let whats: Vec<&str> = rules::r4_sites(&out_of_scope).iter().map(|s| s.what).collect();
    assert_eq!(whats, ["mul_add"], "only the fused-op tier is crate-wide");
}

#[test]
fn r5_fixture_wait_and_nesting() {
    let src = include_str!("lint_fixtures/r5_condvar_locks.rs");
    let out = lint_as("rust/src/coordinator/fx.rs", src);
    assert_eq!(out.len(), 2, "{out:?}");
    assert!(out.iter().all(|f| f.rule == "R5"), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("while/loop")), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("`state` -> `other`")), "{out:?}");
}

#[test]
fn r6_fixture_unpaired_gates() {
    let src = include_str!("lint_fixtures/r6_unpaired_gate.rs");
    let out = lint_as("rust/src/kernels/fx.rs", src);
    assert_eq!(out.len(), 2, "{out:?}");
    assert!(out.iter().all(|f| f.rule == "R6"), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("no scalar fallthrough")), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("`fast_only`")), "{out:?}");
    // files compiled only under the gate are exempt wholesale
    assert!(lint_as("rust/src/kernels/simd.rs", src).is_empty());
}

#[test]
fn clean_fixture_under_strictest_scope() {
    let src = include_str!("lint_fixtures/clean.rs");
    let out = lint_as("rust/src/kernels/fx.rs", src);
    assert!(out.is_empty(), "{out:?}");
    let sf = SourceFile::new("rust/src/kernels/fx.rs".to_string(), src);
    assert!(rules::r3_sites(&sf).is_empty(), "cmp::Ordering is not an atomic site");
    assert!(rules::r4_sites(&sf).is_empty(), "bracket-internal products are not accumulation");
}

#[test]
fn r7_fixture_tree_cross_checks() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/lint_fixtures/r7_tree");
    let mut out = Vec::new();
    xref::r7_xref(&root, &mut out);
    assert_eq!(out.len(), 5, "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "R7"), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("`ghost`")), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("`real_suite`")), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("`stale_pin`")), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("bench_gemm/v9")), "{out:?}");
    assert!(out.iter().any(|f| f.msg.contains("bench_phantom/v1")), "{out:?}");
}

/// The meta-check: the tree this repo ships — sources, allowlists,
/// registrations, schema docs — must lint clean. This is the same run
/// the `approxlint` ci.sh stage performs before the build.
#[test]
fn shipped_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint::run_all(root).expect("lint tree walk");
    let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(
        findings.is_empty(),
        "approxlint findings on the shipped tree:\n{}",
        rendered.join("\n")
    );
}
