//! Pluggable inference backends for the batching server.
//!
//! The serving layer ([`super::server`]) is written against one trait,
//! [`InferBackend`]: "run one fixed-shape image batch, give me the
//! logits". Two implementations exist:
//!
//! * [`EngineBackend`] — the compiled-artifact path: a PJRT [`Engine`]
//!   executing a forward HLO artifact with host-side params (+ optional
//!   LUT). This is the deployment shape of the paper's inference support,
//!   but it needs `make artifacts` and the real `xla` bindings. The PJRT
//!   client is not `Send`, so this backend serves from the caller's
//!   thread ([`super::server::serve_on_caller`]).
//! * [`CpuBackend`] — the pure-Rust executor path: the ATxC
//!   `Lenet300`/`Lenet5`/`CpuResnet` forward passes with every multiply
//!   routed through a [`MulKernel`]. No artifacts, no PJRT — the server
//!   is runnable and testable end-to-end in this repo, under all three
//!   simulation strategies. `CpuBackend` is `Send` and [`Clone`]-able
//!   into per-lane replicas (same seed → bit-identical weights), so it
//!   is what the multi-lane [`super::server::serve_pool`] and
//!   `bench-serve` run on.
//!
//! [`MulSpec`] is the *owned* counterpart of the borrowing [`MulKernel`]:
//! a lane thread owns its multiplier state (functional model box or LUT)
//! and materializes the borrowing kernel per batch.

use anyhow::{anyhow, bail, Result};

use crate::kernels::MulKernel;
use crate::lut::MantissaLut;
use crate::mult::{registry, ApproxMul};
use crate::nn::cpu_lenet::{Lenet300, Lenet5};
use crate::nn::cpu_resnet::{CpuResnet, Depth};
use crate::runtime::artifact::Role;
use crate::runtime::executor::{Engine, Value};
use crate::tensor::Tensor;

/// A fixed-batch inference executor the serving lanes drive.
///
/// Contract: `run_batch` consumes exactly `batch() * image_elems()` f32s
/// (row-major `[batch, ...image dims]`) and returns `batch() * classes()`
/// logits, row `i` belonging to input row `i`. Implementations must be
/// deterministic: the same image buffer always yields the same logits
/// bits (the multi-lane bit-exactness gates are built on this).
pub trait InferBackend {
    /// Fixed batch size of one `run_batch` call.
    fn batch(&self) -> usize;
    /// f32 elements per image row.
    fn image_elems(&self) -> usize;
    /// Logit columns per row.
    fn classes(&self) -> usize;
    /// Human-readable identity for logs/records.
    fn describe(&self) -> String;
    /// Run one full batch; returns row-major `[batch, classes]` logits.
    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>>;
}

// ---------------------------------------------------------------------------
// MulSpec — owned multiplication strategy (lane-replicable)
// ---------------------------------------------------------------------------

/// Owned multiplication strategy: what a serving lane holds so it can
/// materialize a borrowing [`MulKernel`] for each batch. Mirrors the
/// artifact mode strings: `native`, `direct:<mult>`, `lut:<mult>`.
pub enum MulSpec {
    /// Hardware `*` (ATnG).
    Native,
    /// Per-multiply functional-model call (ATxC direct simulation).
    Direct(Box<dyn ApproxMul>),
    /// AMSim mantissa-LUT gather (ATxG); owns a validated LUT.
    Lut { mult: String, lut: MantissaLut },
}

impl MulSpec {
    /// Parse a mode string: `native` | `direct:<mult>` | `lut:<mult>`
    /// (bare `lut` defaults to `afm16`). LUT tables are generated from
    /// the registered functional model and validated.
    pub fn parse(mode: &str) -> Result<MulSpec> {
        if mode == "native" {
            return Ok(MulSpec::Native);
        }
        if let Some(name) = mode.strip_prefix("direct:") {
            let model = registry::by_name(name)
                .ok_or_else(|| anyhow!("unknown multiplier {name:?} in mode {mode:?}"))?;
            return Ok(MulSpec::Direct(model));
        }
        let name = if mode == "lut" { "afm16" } else { mode.strip_prefix("lut:").unwrap_or("") };
        if name.is_empty() {
            bail!("unknown simulation mode {mode:?} (want native | direct:<mult> | lut:<mult>)");
        }
        let model = registry::by_name(name)
            .ok_or_else(|| anyhow!("unknown multiplier {name:?} in mode {mode:?}"))?;
        if !registry::lut_able(name) {
            bail!("multiplier {name} is not tabulatable; use direct:{name}");
        }
        let lut = MantissaLut::generate(model.as_ref());
        lut.validate().map_err(|e| anyhow!("generated {name} LUT failed validation: {e}"))?;
        Ok(MulSpec::Lut { mult: name.to_string(), lut })
    }

    /// The borrowing kernel for this spec (cheap; construct per batch).
    pub fn kernel(&self) -> MulKernel<'_> {
        match self {
            MulSpec::Native => MulKernel::Native,
            MulSpec::Direct(m) => MulKernel::Direct(m.as_ref()),
            MulSpec::Lut { lut, .. } => MulKernel::Lut(crate::amsim::AmSim::new(lut)),
        }
    }

    /// Mode string this spec round-trips to.
    pub fn describe(&self) -> String {
        match self {
            MulSpec::Native => "native".into(),
            MulSpec::Direct(m) => format!("direct:{}", m.name()),
            MulSpec::Lut { mult, .. } => format!("lut:{mult}"),
        }
    }
}

impl Clone for MulSpec {
    fn clone(&self) -> MulSpec {
        match self {
            MulSpec::Native => MulSpec::Native,
            MulSpec::Direct(m) => MulSpec::Direct(
                registry::by_name(m.name()).expect("registered model stays registered"),
            ),
            MulSpec::Lut { mult, lut } => MulSpec::Lut { mult: mult.clone(), lut: lut.clone() },
        }
    }
}

// ---------------------------------------------------------------------------
// CpuBackend — pure-Rust executor backend (ATxC path, replicable lanes)
// ---------------------------------------------------------------------------

/// The model a [`CpuBackend`] serving replica or a
/// [`super::data_parallel::DpTrainer`] training replica executes.
#[derive(Clone)]
pub enum CpuModel {
    Lenet300(Lenet300),
    Lenet5(Lenet5),
    Resnet(CpuResnet),
}

impl CpuModel {
    /// Build a pure-Rust model by name (`lenet300` | `lenet5` |
    /// `resnet18` | `resnet34` | `resnet50`), freshly initialized from
    /// `seed` — deterministic, so two models built with the same
    /// arguments hold bit-identical weights. The resnets are the
    /// CIFAR-shaped width-scaled variants used by the experiment
    /// harness's quick paths.
    pub fn for_name(model: &str, seed: u64) -> Result<CpuModel> {
        Ok(match model {
            "lenet300" => CpuModel::Lenet300(Lenet300::init(28 * 28, 10, seed)),
            "lenet5" => CpuModel::Lenet5(Lenet5::init(seed)),
            "resnet18" | "resnet34" | "resnet50" => {
                let depth = match model {
                    "resnet18" => Depth::R18,
                    "resnet34" => Depth::R34,
                    _ => Depth::R50,
                };
                CpuModel::Resnet(CpuResnet::init(depth, (16, 16, 3), 10, 8, seed))
            }
            other => bail!("no CPU executor for model {other:?}"),
        })
    }

    /// Per-sample input shape (no batch dim), NHWC for the image models.
    pub fn input_dims(&self) -> Vec<usize> {
        match self {
            CpuModel::Lenet300(net) => vec![net.w1.shape[0]],
            CpuModel::Lenet5(_) => vec![28, 28, 1],
            CpuModel::Resnet(net) => vec![net.input.0, net.input.1, net.input.2],
        }
    }

    /// Logit columns.
    pub fn classes(&self) -> usize {
        match self {
            CpuModel::Lenet300(net) => net.w3.shape[1],
            CpuModel::Lenet5(net) => net.w3.shape[1],
            CpuModel::Resnet(net) => net.classes,
        }
    }

    /// Forward pass; `x` carries the leading batch dim.
    pub fn forward(&self, mul: &MulKernel, x: &Tensor) -> Tensor {
        match self {
            CpuModel::Lenet300(net) => net.forward(mul, x),
            CpuModel::Lenet5(net) => net.forward(mul, x),
            CpuModel::Resnet(net) => net.forward(mul, x),
        }
    }

    /// Total parameter elements in the model's canonical flat layout.
    pub fn param_count(&self) -> usize {
        match self {
            CpuModel::Lenet300(net) => net.param_count(),
            CpuModel::Lenet5(net) => net.param_count(),
            CpuModel::Resnet(net) => net.param_count(),
        }
    }

    /// Snapshot every parameter into one flat vector (canonical layout).
    pub fn flat_params(&self) -> Vec<f32> {
        match self {
            CpuModel::Lenet300(net) => net.flat_params(),
            CpuModel::Lenet5(net) => net.flat_params(),
            CpuModel::Resnet(net) => net.flat_params(),
        }
    }

    /// Overwrite every parameter from a flat vector (canonical layout).
    pub fn load_flat(&mut self, flat: &[f32]) {
        match self {
            CpuModel::Lenet300(net) => net.load_flat(flat),
            CpuModel::Lenet5(net) => net.load_flat(flat),
            CpuModel::Resnet(net) => net.load_flat(flat),
        }
    }

    /// Compute-only training step (`&self`, parameters untouched): loss
    /// sum, correct count, flat gradient with the loss gradient scaled by
    /// `1/divisor` (pass the effective batch size).
    pub fn grad_step(
        &self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        divisor: usize,
    ) -> (f32, usize, Vec<f32>) {
        match self {
            CpuModel::Lenet300(net) => net.grad_step(mul, x, labels, divisor),
            CpuModel::Lenet5(net) => net.grad_step(mul, x, labels, divisor),
            CpuModel::Resnet(net) => net.grad_step(mul, x, labels, divisor),
        }
    }

    /// Plain SGD over a flat gradient: `p -= lr * g` per element.
    pub fn apply_grads(&mut self, flat: &[f32], lr: f32) {
        match self {
            CpuModel::Lenet300(net) => net.apply_grads(flat, lr),
            CpuModel::Lenet5(net) => net.apply_grads(flat, lr),
            CpuModel::Resnet(net) => net.apply_grads(flat, lr),
        }
    }
}

/// Pure-Rust inference backend: an owned model + an owned [`MulSpec`].
/// `Send` and `Clone`, so [`replicas`](CpuBackend::replicas) hands one
/// bit-identical copy to each serving lane.
#[derive(Clone)]
pub struct CpuBackend {
    model: CpuModel,
    mul: MulSpec,
    name: String,
    batch: usize,
    /// full input shape including the leading batch dim
    input_shape: Vec<usize>,
    image_elems: usize,
    classes: usize,
}

impl CpuBackend {
    /// Build a backend for a model by name (`lenet300` | `lenet5` |
    /// `resnet18` | `resnet34` | `resnet50`), freshly initialized from
    /// `seed` — deterministic, so two backends built with the same
    /// arguments hold bit-identical weights.
    pub fn for_model(model: &str, mul: MulSpec, batch: usize, seed: u64) -> Result<CpuBackend> {
        assert!(batch > 0, "batch must be positive");
        let m = CpuModel::for_name(model, seed)?;
        let mut input_shape = vec![batch];
        input_shape.extend(m.input_dims());
        let image_elems = input_shape.iter().skip(1).product();
        let classes = m.classes();
        Ok(CpuBackend {
            model: m,
            mul,
            name: model.to_string(),
            batch,
            input_shape,
            image_elems,
            classes,
        })
    }

    /// Wrap an already-initialized ResNet (callers pick depth/input/width).
    pub fn from_resnet(net: CpuResnet, mul: MulSpec, batch: usize) -> CpuBackend {
        let (h, w, c) = net.input;
        let classes = net.classes;
        CpuBackend {
            name: format!("resnet-{:?}", net.depth).to_lowercase(),
            input_shape: vec![batch, h, w, c],
            image_elems: h * w * c,
            model: CpuModel::Resnet(net),
            mul,
            batch,
            classes,
        }
    }

    /// `n` bit-identical lane replicas (weights and multiplier cloned).
    pub fn replicas(&self, n: usize) -> Vec<CpuBackend> {
        (0..n).map(|_| self.clone()).collect()
    }

    /// Replace the multiplication strategy in place (weights untouched).
    /// The networked tier's LUT hot-swap mutates a tenant's *template*
    /// backend under a lock and bumps an epoch; lanes then re-clone the
    /// whole template, so no request can ever observe a half-swapped
    /// table (see `coordinator::net`).
    pub fn set_mul(&mut self, mul: MulSpec) {
        self.mul = mul;
    }

    /// The current mode string (`native` | `direct:<m>` | `lut:<m>`).
    pub fn mul_describe(&self) -> String {
        self.mul.describe()
    }

    /// Replace the backend's weights with an externally trained (e.g.
    /// pruned and fine-tuned) flat parameter vector; the length must
    /// match the model's parameter count. Load before
    /// [`replicas`](CpuBackend::replicas) so every serving lane carries
    /// the loaded weights bit-identically.
    pub fn load_flat_params(&mut self, flat: &[f32]) -> Result<()> {
        let n = self.model.param_count();
        if flat.len() != n {
            bail!("flat params carry {} f32s, {} expects {n}", flat.len(), self.describe());
        }
        self.model.load_flat(flat);
        Ok(())
    }
}

impl InferBackend for CpuBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_elems(&self) -> usize {
        self.image_elems
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn describe(&self) -> String {
        format!("cpu:{}:{}", self.name, self.mul.describe())
    }

    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>> {
        if images.len() != self.batch * self.image_elems {
            bail!(
                "{}: batch has {} elements, expected {}",
                self.describe(),
                images.len(),
                self.batch * self.image_elems
            );
        }
        let x = Tensor::from_vec(&self.input_shape, images.to_vec());
        let logits = self.model.forward(&self.mul.kernel(), &x);
        debug_assert_eq!(logits.data.len(), self.batch * self.classes);
        Ok(logits.data)
    }
}

// ---------------------------------------------------------------------------
// EngineBackend — compiled-artifact (PJRT) backend
// ---------------------------------------------------------------------------

/// Artifact-executing backend: owns the PJRT [`Engine`], the forward
/// artifact name, and the fixed params (+ optional LUT payload) passed
/// around the image input. Not `Send` in deployment (the PJRT client is
/// thread-pinned) — serve it with [`super::server::serve_on_caller`].
pub struct EngineBackend {
    engine: Engine,
    artifact: String,
    params: Vec<Value>,
    lut: Option<Vec<u32>>,
    batch: usize,
    image_elems: usize,
    classes: usize,
}

impl EngineBackend {
    /// Wrap a prepared engine + forward artifact; shapes come from the
    /// manifest. `params` are the positional params in manifest order;
    /// `lut` is appended after the image when the artifact takes one.
    pub fn new(
        mut engine: Engine,
        artifact: &str,
        params: Vec<Value>,
        lut: Option<Vec<u32>>,
    ) -> Result<EngineBackend> {
        let art = engine.manifest().get(artifact)?.clone();
        let x_idx = art.input_indices(Role::Input);
        if x_idx.is_empty() {
            bail!("{artifact}: no image input in manifest");
        }
        let x_spec = &art.inputs[x_idx[0]];
        let batch = x_spec.shape[0];
        let image_elems = x_spec.elements() / batch;
        let classes = art.outputs[0].shape[1];
        if !art.input_indices(Role::Lut).is_empty() && lut.is_none() {
            bail!("{artifact}: artifact takes a LUT but none was provided");
        }
        // compile before the serving loop so no request pays for it
        engine.prepare(artifact)?;
        Ok(EngineBackend {
            engine,
            artifact: artifact.to_string(),
            params,
            lut,
            batch,
            image_elems,
            classes,
        })
    }
}

impl InferBackend for EngineBackend {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_elems(&self) -> usize {
        self.image_elems
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn describe(&self) -> String {
        format!("engine:{}", self.artifact)
    }

    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>> {
        let mut inputs = self.params.clone();
        inputs.push(Value::F32(images.to_vec()));
        if let Some(l) = &self.lut {
            inputs.push(Value::U32(l.clone()));
        }
        let out = self.engine.run(&self.artifact, &inputs)?;
        Ok(out[0].as_f32()?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mulspec_parses_and_describes() {
        assert!(matches!(MulSpec::parse("native").unwrap(), MulSpec::Native));
        let d = MulSpec::parse("direct:afm32").unwrap();
        assert_eq!(d.describe(), "direct:afm32");
        let l = MulSpec::parse("lut:afm16").unwrap();
        assert_eq!(l.describe(), "lut:afm16");
        assert_eq!(MulSpec::parse("lut").unwrap().describe(), "lut:afm16");
        assert!(MulSpec::parse("nope").is_err());
        assert!(MulSpec::parse("lut:doesnotexist").is_err());
        // clones keep semantics: same product bits
        let l2 = l.clone();
        assert_eq!(l.kernel().mul(1.5, 2.25).to_bits(), l2.kernel().mul(1.5, 2.25).to_bits());
    }

    #[test]
    fn cpu_backend_shapes_and_determinism() {
        let mut a = CpuBackend::for_model("lenet300", MulSpec::Native, 4, 11).unwrap();
        assert_eq!(a.batch(), 4);
        assert_eq!(a.image_elems(), 784);
        assert_eq!(a.classes(), 10);
        let images: Vec<f32> = (0..4 * 784).map(|i| (i % 97) as f32 / 97.0).collect();
        let y1 = a.run_batch(&images).unwrap();
        assert_eq!(y1.len(), 40);
        // a replica built from the same seed answers bit-identically
        let mut b = CpuBackend::for_model("lenet300", MulSpec::Native, 4, 11).unwrap();
        let y2 = b.run_batch(&images).unwrap();
        assert_eq!(
            y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // and so do clone()d replicas
        let mut c = a.replicas(1).pop().unwrap();
        let y3 = c.run_batch(&images).unwrap();
        assert_eq!(
            y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y3.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // wrong batch size is a typed error, not a panic
        assert!(a.run_batch(&images[..784]).is_err());
    }

    #[test]
    fn cpu_backend_rejects_unknown_model() {
        assert!(CpuBackend::for_model("vgg", MulSpec::Native, 2, 1).is_err());
    }
}
