//! Integration suite for the multi-lane batching inference server.
//!
//! Pins the serving-layer contracts that back the `bench-serve` record:
//!
//! * **N-lane ≡ 1-lane**: replies are bit-identical whichever lane count
//!   (and whichever batching schedule the race produces) served them,
//!   for all three `MulKernel` strategies.
//! * **Partial-batch padding**: a partially-filled batch is padded by
//!   cycling its real request images, so its replies are bit-identical
//!   to the same images served in a full batch of themselves — and zero
//!   padding provably would NOT be (batch-statistics batchnorm).
//! * **Bounded admission**: overload yields typed
//!   [`InferError::Rejected`] replies, never unbounded queue growth.
//! * **Merged stats**: per-lane stats aggregate with exact streaming
//!   sums (requests/batches/fill) and a seen-consistent reservoir.

use std::collections::BTreeMap;
use std::time::Duration;

use approxtrain::coordinator::backend::{CpuBackend, InferBackend, MulSpec};
use approxtrain::coordinator::server::{
    serve_on_caller, serve_pool, InferError, Reply, ServeConfig, Stats,
};
use approxtrain::data::synth::{mnist_like, SynthSpec};
use approxtrain::util::rng::Pcg32;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Serve `images` through `lanes` lane replicas of `base` with a
/// closed-loop `clients`-thread load; returns merged stats + replies by
/// request index. Every request must be accepted.
fn run_server(
    base: &CpuBackend,
    lanes: usize,
    cfg: ServeConfig,
    images: &[Vec<f32>],
    clients: usize,
) -> (Stats, BTreeMap<usize, Reply>) {
    let mut backends = base.replicas(lanes);
    let n = images.len();
    let (stats, replies) = serve_pool(&mut backends, cfg, |client| {
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|t| {
                    let client = client.clone();
                    s.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = t;
                        while i < n {
                            out.push((i, client.infer(images[i].clone()).expect("infer")));
                            i += clients;
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("client thread panicked"))
                .collect::<Vec<(usize, Reply)>>()
        })
    })
    .expect("serve_pool");
    (stats, replies.into_iter().collect())
}

/// Multi-lane replies must be bit-identical to the single-lane reference
/// for every simulation strategy, regardless of how requests raced into
/// batches; and the merged aggregate stats must keep their exact-sum
/// invariants.
#[test]
fn multi_lane_bit_identical_to_single_lane_every_kernel() {
    // kept small: the direct:<mult> strategy pays a functional-model
    // call per multiply and this suite also runs in debug builds
    let n = 8usize;
    let ds = mnist_like(&SynthSpec { n, seed: 31, ..SynthSpec::mnist_like_default() });
    let images: Vec<Vec<f32>> = (0..n).map(|i| ds.image(i).to_vec()).collect();
    let cfg = ServeConfig { max_wait: Duration::from_millis(2), queue_depth: 64 };
    for mode in ["native", "direct:afm16", "lut:afm16"] {
        let base =
            CpuBackend::for_model("lenet300", MulSpec::parse(mode).unwrap(), 4, 3).unwrap();
        let (s1, r1) = run_server(&base, 1, cfg, &images, 3);
        let (s4, r4) = run_server(&base, 4, cfg, &images, 3);
        assert_eq!(s1.requests, n, "{mode}: single lane answered everything");
        assert_eq!(s4.requests, n, "{mode}: four lanes answered everything");
        for i in 0..n {
            assert_eq!(
                bits(&r1[&i].logits),
                bits(&r4[&i].logits),
                "{mode}: request {i} diverged between 1-lane and 4-lane serving"
            );
        }

        // merged-stats invariants: every request in exactly one batch,
        // streaming sums exact, reservoir seen count consistent
        for (label, s) in [("1-lane", &s1), ("4-lane", &s4)] {
            assert_eq!(s.rejected, 0, "{mode} {label}");
            assert!(s.batches >= 1 && s.batches <= n, "{mode} {label}: batches {}", s.batches);
            let fill_sum = s.mean_fill() * s.batches as f64;
            assert!(
                (fill_sum - n as f64).abs() < 1e-9,
                "{mode} {label}: fills sum to {fill_sum}, want {n}"
            );
            assert_eq!(s.latencies.seen(), n as u64, "{mode} {label}");
            assert!(s.max_latency_s() >= s.mean_latency_s(), "{mode} {label}");
        }
    }

    // the caller-thread single-lane driver (the engine-backend shape)
    // produces the same bits as a serve_pool lane
    let base = CpuBackend::for_model("lenet300", MulSpec::Native, 4, 3).unwrap();
    let (_, r_pool) = run_server(&base, 1, cfg, &images, 3);
    let mut caller_backend = base.replicas(1).pop().unwrap();
    let images_ref = &images;
    let (s_caller, replies) = serve_on_caller(&mut caller_backend, cfg, |client| {
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..3)
                .map(|t| {
                    let client = client.clone();
                    s.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = t;
                        while i < images_ref.len() {
                            out.push((i, client.infer(images_ref[i].clone()).expect("infer")));
                            i += 3;
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("client thread panicked"))
                .collect::<Vec<(usize, Reply)>>()
        })
    })
    .expect("serve_on_caller");
    assert_eq!(s_caller.requests, n);
    for (i, reply) in replies {
        assert_eq!(bits(&r_pool[&i].logits), bits(&reply.logits), "caller-thread lane, req {i}");
    }
}

/// The partial-batch padding regression (the headline bugfix): a batch
/// that fills `k < batch` slots must reply with exactly the bits the
/// same images produce in a full batch of themselves (cycled) — and a
/// zero-row pad demonstrably corrupts those bits through the
/// batch-statistics batchnorm, which is why the policy exists.
#[test]
fn partial_batch_padding_matches_full_batch_of_cycled_images() {
    // resnet18 normalizes with batch statistics: padding rows influence
    // every real row, so this model detects any padding-policy change
    let base = CpuBackend::for_model("resnet18", MulSpec::Native, 4, 9).unwrap();
    let sz = base.image_elems();
    let classes = base.classes();
    let mut rng = Pcg32::seeded(77);
    let imgs: Vec<Vec<f32>> =
        (0..2).map(|_| (0..sz).map(|_| rng.uniform()).collect()).collect();

    // serve exactly 2 requests into one batch of 4: submit in a fixed
    // order (staggered well inside the batching window) so the batch
    // composition is deterministic
    let cfg = ServeConfig { max_wait: Duration::from_millis(400), queue_depth: 8 };
    let mut backends = base.replicas(1);
    let imgs_ref = &imgs;
    let (stats, replies) = serve_pool(&mut backends, cfg, |client| {
        std::thread::scope(|s| {
            let c0 = client.clone();
            let first = s.spawn(move || c0.infer(imgs_ref[0].clone()).expect("infer 0"));
            std::thread::sleep(Duration::from_millis(40));
            let c1 = client.clone();
            let second = s.spawn(move || c1.infer(imgs_ref[1].clone()).expect("infer 1"));
            (first.join().unwrap(), second.join().unwrap())
        })
    })
    .expect("serve_pool");
    let (r0, r1) = replies;
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.batches, 1, "both requests must share one partial batch");
    assert_eq!(r0.batch_fill, 2);
    assert_eq!(r1.batch_fill, 2);

    // reference: the same two images as a full batch of themselves,
    // cycled [i0, i1, i0, i1] — the lane's padding policy
    let mut reference = base.replicas(1).pop().unwrap();
    let mut full = Vec::with_capacity(4 * sz);
    for k in 0..4 {
        full.extend_from_slice(&imgs[k % 2]);
    }
    let want = reference.run_batch(&full).unwrap();
    assert_eq!(bits(&r0.logits), bits(&want[..classes]), "row 0: padding must cycle real images");
    assert_eq!(
        bits(&r1.logits),
        bits(&want[classes..2 * classes]),
        "row 1: padding must cycle real images"
    );

    // teeth: the old zero-row padding produces DIFFERENT bits for the
    // real rows — the bug this regression test guards against
    let mut zeroed = Vec::with_capacity(4 * sz);
    for img in &imgs {
        zeroed.extend_from_slice(img);
    }
    zeroed.resize(4 * sz, 0.0);
    let corrupted = reference.run_batch(&zeroed).unwrap();
    assert_ne!(
        bits(&corrupted[..2 * classes]),
        bits(&want[..2 * classes]),
        "zero-row padding must actually perturb batch-stats batchnorm, \
         else this regression test has no teeth"
    );
}

/// A gated backend for overload tests: batch 1, identity logits. It
/// signals `entered` when a batch starts and then blocks until the
/// test's gate sender is dropped — no sleeps, no scheduling races.
struct GatedBackend {
    entered: std::sync::mpsc::Sender<()>,
    gate: std::sync::mpsc::Receiver<()>,
}

impl InferBackend for GatedBackend {
    fn batch(&self) -> usize {
        1
    }
    fn image_elems(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        1
    }
    fn describe(&self) -> String {
        "test:gated".into()
    }
    fn run_batch(&mut self, images: &[f32]) -> anyhow::Result<Vec<f32>> {
        let _ = self.entered.send(());
        let _ = self.gate.recv(); // released when the test drops the sender
        Ok(images.to_vec())
    }
}

/// Overload against the bounded admission queue: with the single lane
/// provably busy (blocked inside `run_batch`) and the queue empty,
/// exactly `depth` further submissions are admitted and the rest get the
/// typed `Rejected` reply carrying the configured depth. Fully
/// deterministic: admission is probed sequentially via `Client::submit`
/// while the backend is gated on a channel.
#[test]
fn bounded_queue_rejects_overload_with_typed_reply() {
    use std::sync::mpsc;

    let depth = 2usize;
    let flood = 6usize;
    let cfg = ServeConfig { max_wait: Duration::from_millis(1), queue_depth: depth };
    let (entered_tx, entered_rx) = mpsc::channel();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let mut backends = vec![GatedBackend { entered: entered_tx, gate: gate_rx }];
    let (stats, (accepted, rejected)) = serve_pool(&mut backends, cfg, move |client| {
        // request 0 occupies the lane; wait until the backend has
        // actually started on it (queue is empty again from here on)
        let pending0 = client.submit(vec![0.5]).expect("first submit admitted");
        entered_rx.recv().expect("lane entered run_batch");
        // sequential flood while the lane is blocked: the first `depth`
        // submissions are admitted, the rest rejected — typed,
        // immediate, no unbounded growth
        let mut pendings = Vec::new();
        let mut rejected = 0usize;
        for k in 0..flood {
            match client.submit(vec![k as f32]) {
                Ok(p) => pendings.push(p),
                Err(InferError::Rejected { queue_depth }) => {
                    assert_eq!(queue_depth, depth, "reject reports the configured depth");
                    rejected += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        // open the gate for this and every later batch
        drop(gate_tx);
        let r0 = pending0.wait().expect("first reply");
        assert_eq!(r0.logits, vec![0.5]);
        let accepted = pendings.len();
        for p in pendings {
            assert_eq!(p.wait().expect("admitted reply").logits.len(), 1);
        }
        (accepted, rejected)
    })
    .expect("serve_pool");

    assert_eq!(accepted, depth, "exactly the queue depth is admitted while the lane is busy");
    assert_eq!(rejected, flood - depth);
    assert_eq!(stats.requests, 1 + depth, "first request + admitted flood all get replies");
    assert_eq!(stats.rejected, (flood - depth) as u64);
    let offered = (1 + flood) as f64;
    assert!((stats.reject_rate() - (flood - depth) as f64 / offered).abs() < 1e-12);
}

/// Lanes answer *every* admitted request exactly once even when the
/// load finishes before the queue drains (shutdown drains, never drops).
#[test]
fn shutdown_drains_admitted_requests() {
    let base = CpuBackend::for_model("lenet300", MulSpec::Native, 4, 5).unwrap();
    let ds = mnist_like(&SynthSpec { n: 9, seed: 8, ..SynthSpec::mnist_like_default() });
    let images: Vec<Vec<f32>> = (0..9).map(|i| ds.image(i).to_vec()).collect();
    // tiny wait: lots of partial batches + a queue that outlives load
    let cfg = ServeConfig { max_wait: Duration::from_micros(100), queue_depth: 64 };
    let (stats, replies) = run_server(&base, 2, cfg, &images, 9);
    assert_eq!(stats.requests, 9);
    assert_eq!(replies.len(), 9, "every admitted request answered exactly once");
    let fill_sum = stats.mean_fill() * stats.batches as f64;
    assert!((fill_sum - 9.0).abs() < 1e-9);
}
