//! Magnitude pruning with a polynomial-decay sparsity schedule — the
//! substrate for Fig 11 ("Approximate Multiplier on top of Pruning").
//!
//! The paper follows the standard TensorFlow model-optimization recipe:
//! pre-train, then prune to increasing sparsity levels with brief retraining
//! after each level. We implement the same schedule: sparsity(t) =
//! final + (initial - final) * (1 - t/T)^3.

use crate::runtime::executor::Value;

/// Polynomial-decay sparsity schedule (TF model-optimization semantics).
#[derive(Clone, Copy, Debug)]
pub struct PolynomialDecay {
    pub initial_sparsity: f32,
    pub final_sparsity: f32,
    pub steps: usize,
}

impl PolynomialDecay {
    pub fn sparsity_at(&self, step: usize) -> f32 {
        if self.steps == 0 || step >= self.steps {
            return self.final_sparsity;
        }
        let frac = 1.0 - step as f32 / self.steps as f32;
        self.final_sparsity + (self.initial_sparsity - self.final_sparsity) * frac.powi(3)
    }
}

/// A pruning mask over one tensor.
#[derive(Clone, Debug)]
pub struct Mask {
    pub keep: Vec<bool>,
}

impl Mask {
    pub fn sparsity(&self) -> f32 {
        let pruned = self.keep.iter().filter(|&&k| !k).count();
        pruned as f32 / self.keep.len().max(1) as f32
    }
}

/// Build a magnitude mask pruning the smallest-|w| fraction of `weights`.
pub fn magnitude_mask(weights: &[f32], sparsity: f32) -> Mask {
    let n = weights.len();
    let k = ((n as f32) * sparsity.clamp(0.0, 1.0)).round() as usize;
    if k == 0 {
        return Mask { keep: vec![true; n] };
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| weights[a].abs().partial_cmp(&weights[b].abs()).unwrap());
    let mut keep = vec![true; n];
    for &i in &idx[..k.min(n)] {
        keep[i] = false;
    }
    Mask { keep }
}

/// Apply a mask in place.
pub fn apply_mask(weights: &mut [f32], mask: &Mask) {
    assert_eq!(weights.len(), mask.keep.len());
    for (w, &k) in weights.iter_mut().zip(&mask.keep) {
        if !k {
            *w = 0.0;
        }
    }
}

/// Prune a set of parameter values (only tensors with >= `min_elems`
/// elements — biases and BN scales are conventionally left dense) to the
/// given sparsity. Returns the masks so retraining can re-apply them after
/// each optimizer step.
pub fn prune_params(params: &mut [Value], sparsity: f32, min_elems: usize) -> Vec<Option<Mask>> {
    params
        .iter_mut()
        .map(|v| match v {
            Value::F32(data) if data.len() >= min_elems => {
                let mask = magnitude_mask(data, sparsity);
                apply_mask(data, &mask);
                Some(mask)
            }
            _ => None,
        })
        .collect()
}

/// Re-apply masks after a training step (pruned weights stay zero).
pub fn reapply_masks(params: &mut [Value], masks: &[Option<Mask>]) {
    for (v, m) in params.iter_mut().zip(masks) {
        if let (Value::F32(data), Some(mask)) = (v, m) {
            apply_mask(data, mask);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_decays_to_final() {
        let s = PolynomialDecay { initial_sparsity: 0.7, final_sparsity: 0.9, steps: 100 };
        assert!((s.sparsity_at(0) - 0.7).abs() < 1e-6);
        assert!((s.sparsity_at(100) - 0.9).abs() < 1e-6);
        assert!((s.sparsity_at(1000) - 0.9).abs() < 1e-6);
        // monotone non-decreasing
        let mut prev = 0.0;
        for t in 0..=100 {
            let v = s.sparsity_at(t);
            assert!(v >= prev - 1e-6, "step {t}");
            prev = v;
        }
    }

    #[test]
    fn magnitude_mask_prunes_smallest() {
        let w = vec![0.1, -5.0, 0.01, 3.0, -0.2];
        let mask = magnitude_mask(&w, 0.4);
        assert_eq!(mask.keep, vec![false, true, false, true, true]);
        assert!((mask.sparsity() - 0.4).abs() < 1e-6);
        let mut w2 = w.clone();
        apply_mask(&mut w2, &mask);
        assert_eq!(w2, vec![0.0, -5.0, 0.0, 3.0, -0.2]);
    }

    #[test]
    fn prune_params_skips_small_tensors() {
        let mut params = vec![
            Value::F32(vec![1.0, 0.001, 2.0, 0.002, 3.0, 0.003, 4.0, 0.004]),
            Value::F32(vec![0.5, 0.5]), // bias-like, untouched
        ];
        let masks = prune_params(&mut params, 0.5, 4);
        assert!(masks[0].is_some());
        assert!(masks[1].is_none());
        let pruned = params[0].as_f32().unwrap().iter().filter(|&&v| v == 0.0).count();
        assert_eq!(pruned, 4);
        assert_eq!(params[1].as_f32().unwrap(), &[0.5, 0.5]);
    }

    #[test]
    fn masks_persist_through_reapply() {
        let mut params = vec![Value::F32(vec![1.0, 0.01, 2.0, 0.02])];
        let masks = prune_params(&mut params, 0.5, 2);
        // simulate a training step reviving pruned weights
        if let Value::F32(d) = &mut params[0] {
            for v in d.iter_mut() {
                *v += 1.0;
            }
        }
        reapply_masks(&mut params, &masks);
        let d = params[0].as_f32().unwrap();
        assert_eq!(d.iter().filter(|&&v| v == 0.0).count(), 2);
    }
}
