//! GEMM kernel — the paper's workhorse (§VI-D "GEMM kernel").
//!
//! The CUDA version fetches 16x16 tiles of both operands into on-chip
//! shared memory; the CPU analog is cache blocking: pack a `BK x BN` panel
//! of `B` once per tile row and walk `A` rows through it, accumulating in
//! FP32. The multiply itself is pluggable ([`MulKernel`]) and the inner
//! loop runs on the batched [`MulBackend`] panel ops, so strategy dispatch
//! is paid once per packed panel column instead of once per multiply —
//! the AMSim path becomes a tight LUT-gather loop, the native path a
//! plain FMA loop. [`gemm_scalar_reference`] preserves the old
//! per-element-dispatch implementation as the bench baseline and the
//! bit-exactness oracle.
//!
//! Threading goes through the persistent pool in [`crate::util::threads`]
//! (row-blocks over lanes, the coarse-grained parallelism axis of the
//! CUDA grid); per-call `thread::scope` spawning is gone from the hot
//! path. Results are bit-identical for any thread count: each output row
//! is computed by exactly one lane with the same per-row arithmetic.

use super::{MulBackend, MulKernel};
use crate::util::threads::{self, SendMutPtr};

/// Cache-block sizes. 64x64 f32 panels are 16 KiB — two fit in a typical
/// 32 KiB L1D the way two 16x16 tiles fit in a CUDA SM's shared memory.
pub const BM: usize = 64;
pub const BN: usize = 64;
pub const BK: usize = 64;

/// MAC-count threshold above which [`gemm_auto`] fans out over the pool.
/// Below it, panel packing + chunk handoff costs more than it saves.
pub const AUTO_THREAD_MACS: usize = 1 << 18;

/// `c[M,N] = a[M,K] * b[K,N]` (row-major, C overwritten), multiplications
/// routed through `mul`, accumulation in FP32.
pub fn gemm(mul: &MulKernel, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_threaded(mul, a, b, c, m, k, n, 1);
}

/// [`gemm`] that picks its own thread count: the persistent pool's full
/// width for large problems, single-lane for small ones. The layers
/// (conv/dense) call this so every model forward/backward shares the same
/// warm pool.
pub fn gemm_auto(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let lanes = threads::global().width();
    let big = m.saturating_mul(k).saturating_mul(n) >= AUTO_THREAD_MACS;
    gemm_threaded(mul, a, b, c, m, k, n, if big { lanes } else { 1 });
}

/// Threaded variant: output row-blocks are distributed over `threads`
/// lanes of the persistent worker pool (the coarse-grained parallelism
/// axis of the CUDA grid). Bit-identical to the single-threaded result
/// for every strategy and thread count.
pub fn gemm_threaded(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let threads = threads.max(1).min(m);
    if threads == 1 {
        gemm_rows_into(mul, a, b, c, 0, m, k, n);
        return;
    }
    let base = SendMutPtr(c.as_mut_ptr());
    threads::global().run_chunks(m, threads, |_, m0, m1| {
        // SAFETY: run_chunks hands out disjoint row ranges [m0, m1) and
        // blocks until all chunks complete, so each C row is written by
        // exactly one lane while `c` is alive.
        let c_block = unsafe { std::slice::from_raw_parts_mut(base.0.add(m0 * n), (m1 - m0) * n) };
        gemm_rows_into(mul, a, b, c_block, m0, m1, k, n);
    });
}

/// Blocked GEMM of global rows `[m0, m1)` written into a C sub-slice that
/// starts at row `m0`. The B panel `[k0..kn, j0..jn]` is packed
/// contiguously (the CUDA "shared-memory fetch") and transposed so the
/// inner `dot_panel` walks both operands with stride 1.
fn gemm_rows_into(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c_block: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    let mut b_panel = vec![0.0f32; BK * BN];
    for j0 in (0..n).step_by(BN) {
        let jn = (j0 + BN).min(n);
        for k0 in (0..k).step_by(BK) {
            let kn = (k0 + BK).min(k);
            let kw = kn - k0;
            for j in j0..jn {
                for kk in k0..kn {
                    b_panel[(j - j0) * kw + (kk - k0)] = b[kk * n + j];
                }
            }
            for i in m0..m1 {
                let a_row = &a[i * k + k0..i * k + kn];
                let c_row = &mut c_block[(i - m0) * n + j0..(i - m0) * n + jn];
                for (jj, c_val) in c_row.iter_mut().enumerate() {
                    let b_col = &b_panel[jj * kw..jj * kw + kw];
                    *c_val += mul.dot_panel(a_row, b_col);
                }
            }
        }
    }
}

/// Per-element-dispatch reference: identical blocking and accumulation
/// order, but every multiply goes through the scalar [`MulKernel::mul`]
/// enum dispatch with none of the panel hoisting/unrolling.
///
/// Scope note for the bench record: the pre-panel GEMM already hoisted
/// dispatch once per packed column (via the old `MulKernel::dot`), so
/// this is *not* a faithful replay of the old GEMM — it is the fully
/// unamortized per-multiply dispatch cost that the AdaPT-style argument
/// is about, and that the old dense weight-gradient inner loop
/// (`row[o] += mul.mul(..)`) actually paid. Kept deliberately:
///
/// * benches measure the dispatch-amortization headroom against it
///   (`BENCH_gemm.json`, strategy `lut_scalar_dispatch`);
/// * `tests/batched_vs_scalar.rs` uses it as the bit-exactness oracle.
pub fn gemm_scalar_reference(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let mut b_panel = vec![0.0f32; BK * BN];
    for j0 in (0..n).step_by(BN) {
        let jn = (j0 + BN).min(n);
        for k0 in (0..k).step_by(BK) {
            let kn = (k0 + BK).min(k);
            let kw = kn - k0;
            for j in j0..jn {
                for kk in k0..kn {
                    b_panel[(j - j0) * kw + (kk - k0)] = b[kk * n + j];
                }
            }
            for i in 0..m {
                let a_row = &a[i * k + k0..i * k + kn];
                let c_row = &mut c[i * n + j0..i * n + jn];
                for (jj, c_val) in c_row.iter_mut().enumerate() {
                    let b_col = &b_panel[jj * kw..jj * kw + kw];
                    // per-element dispatch + the same two-level sequential
                    // accumulation as dot_panel
                    let mut acc = 0.0f32;
                    for t in 0..kw {
                        acc += mul.mul(a_row[t], b_col[t]);
                    }
                    *c_val += acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amsim::AmSim;
    use crate::lut::MantissaLut;
    use crate::mult::fpbits::quantize_mantissa;
    use crate::mult::registry;
    use crate::util::rng::Pcg32;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn native_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 70, 130)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
            let mut c = vec![0.0f32; m * n];
            gemm(&MulKernel::Native, &a, &b, &mut c, m, k, n);
            let want = naive_gemm(&a, &b, m, k, n);
            for i in 0..m * n {
                assert!(
                    (c[i] - want[i]).abs() <= 1e-4 * want[i].abs().max(1.0),
                    "({m},{k},{n}) idx {i}: {} vs {}",
                    c[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn lut_and_direct_agree_bitwise() {
        // ATxG and ATxC must produce identical numbers — the paper's own
        // validation methodology (§VI footnote 2).
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(22);
        let (m, k, n) = (19, 31, 23);
        let a: Vec<f32> =
            (0..m * k).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let mut c_direct = vec![0.0f32; m * n];
        let mut c_lut = vec![0.0f32; m * n];
        gemm(&MulKernel::Direct(model.as_ref()), &a, &b, &mut c_direct, m, k, n);
        gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_lut, m, k, n);
        for i in 0..m * n {
            assert_eq!(c_direct[i].to_bits(), c_lut[i].to_bits(), "idx {i}");
        }
    }

    #[test]
    fn approx_gemm_is_close_to_exact() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(23);
        let (m, k, n) = (16, 64, 16);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut c_exact = vec![0.0f32; m * n];
        let mut c_approx = vec![0.0f32; m * n];
        gemm(&MulKernel::Native, &a, &b, &mut c_exact, m, k, n);
        gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_approx, m, k, n);
        let scale = (k as f32).sqrt();
        for i in 0..m * n {
            assert!(
                (c_exact[i] - c_approx[i]).abs() < 0.05 * scale,
                "idx {i}: {} vs {}",
                c_exact[i],
                c_approx[i]
            );
        }
    }

    #[test]
    fn threaded_pool_matches_single_thread_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(24);
        let (m, k, n) = (37, 41, 29);
        let a: Vec<f32> =
            (0..m * k).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        for mul in [
            MulKernel::Native,
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(AmSim::new(&lut)),
        ] {
            let mut c1 = vec![0.0f32; m * n];
            gemm_threaded(&mul, &a, &b, &mut c1, m, k, n, 1);
            for threads in [2, 3, 8, 64] {
                let mut ct = vec![0.0f32; m * n];
                gemm_threaded(&mul, &a, &b, &mut ct, m, k, n, threads);
                for i in 0..m * n {
                    assert_eq!(
                        c1[i].to_bits(),
                        ct[i].to_bits(),
                        "{} threads={threads} idx {i}",
                        mul.describe()
                    );
                }
            }
        }
    }

    #[test]
    fn auto_matches_fixed_thread_counts() {
        let mut rng = Pcg32::seeded(25);
        // large enough to cross AUTO_THREAD_MACS with k=96
        let (m, k, n) = (72, 96, 72);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut c_auto = vec![0.0f32; m * n];
        let mut c_one = vec![0.0f32; m * n];
        gemm_auto(&MulKernel::Native, &a, &b, &mut c_auto, m, k, n);
        gemm(&MulKernel::Native, &a, &b, &mut c_one, m, k, n);
        for i in 0..m * n {
            assert_eq!(c_auto[i].to_bits(), c_one[i].to_bits(), "idx {i}");
        }
    }

    #[test]
    fn empty_dims() {
        let mut c = vec![0.0f32; 0];
        gemm(&MulKernel::Native, &[], &[], &mut c, 0, 5, 0);
        gemm_scalar_reference(&MulKernel::Native, &[], &[], &mut c, 0, 5, 0);
    }
}
