//! Approximate-multiplier functional models.
//!
//! The paper's flow (Fig. 5) takes a *C/C++ functional model* of an
//! approximate FP multiplier from the designer and tabulates its mantissa
//! products (Algorithm 1). In this reproduction the functional models are
//! Rust implementations of the [`ApproxMul`] trait (with bit-exact Python
//! mirrors in `python/compile/mults.py` used for LUT cross-checks); the
//! contract is identical: a black-box `fn(f32, f32) -> f32` whose sign and
//! exponent behave like an exact FP multiplier and whose mantissa may be
//! approximated.
//!
//! Implemented designs (see DESIGN.md §Substitutions #5):
//!
//! | name | paper reference | mantissa strategy |
//! |---|---|---|
//! | `fp32`, `fpN` | IEEE 754 / bfloat16 | exact multiply, RNE to N bits |
//! | `bfloat16` | [34] | alias of `fp7` |
//! | `afm16`/`afm32` | Saadat et al. [29] | addition-based (log-domain) product + minimal-bias correction |
//! | `mit16` | Mitchell [25] | plain log-domain addition, truncated |
//! | `realm16` | Saadat et al. [30] | log-domain addition + piecewise-linear error correction |
//! | `trunc16` | DRUM-style | exact product, round-toward-zero |
//! | `comp16` | Kim [18] | log-domain addition + single compensation term |
pub mod fpbits;
pub mod models;
pub mod registry;

pub use fpbits::{compose, decompose, quantize_mantissa, FpParts};

/// A black-box approximate FP multiplier functional model.
///
/// Inputs and output are FP32 bit patterns; a model with `mantissa_bits() ==
/// m < 23` treats only the top `m` mantissa bits of each operand as
/// significant (the LUT generator only ever presents such operands).
pub trait ApproxMul: Send + Sync {
    /// Multiplier identifier, e.g. `"afm16"`. Used by the CLI, the LUT file
    /// header and the experiment configs.
    fn name(&self) -> &str;

    /// Number of significant mantissa bits `m` (1..=23).
    fn mantissa_bits(&self) -> u32;

    /// The functional model: approximate product of `a` and `b`.
    fn mul(&self, a: f32, b: f32) -> f32;

    /// Approximate the *mantissa product* of two operands given as 23-bit
    /// mantissa fields (top `m` bits significant). Returns `(carry,
    /// mantissa23)`: `carry` is set when the true product of `(1+x)(1+y)`
    /// reaches 2 (i.e. the exponent must be incremented) and `mantissa23` is
    /// the normalized 23-bit mantissa field of the result.
    ///
    /// This is the piece Algorithm 1 extracts by probing `mul`; models
    /// implement `mul` in terms of it via [`models::mul_via_mantissa`].
    fn mantissa_product(&self, ma: u32, mb: u32) -> (u32, u32);

    /// Capability flag: does this model satisfy the *zero identity*
    /// `mul(±0, x) == ±0` and `mul(x, ±0) == ±0` (a zero of the XOR-sign,
    /// i.e. the IEEE product sign) for **every** operand `x` — finite,
    /// subnormal, infinite and NaN alike?
    ///
    /// This is the machine-checked contract the sparse packed-GEMM drain
    /// relies on to skip all-zero micro-panels (see
    /// `kernels::gemm::PackA::pack_a_occ` and `MulKernel::zero_skip_ok`):
    /// a skipped (a-panel × b-strip) pair is a bitwise no-op only if every
    /// elided product would have been a zero, which `0 × inf == NaN`
    /// hardware semantics violate. Models returning `true` here commit to
    /// zero-dominant semantics — a zero (or flushed-subnormal) operand
    /// yields a signed zero *before* IEEE special-case handling, the way
    /// real approximate datapaths gate a zero operand. The default is the
    /// conservative `false` (dense fallback); the declared flag is audited
    /// against brute-force behaviour over all exponent/mantissa corners and
    /// specials in `tests/golden_mults.rs`, so it cannot silently drift
    /// from the functional model.
    fn zero_identity(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::registry;
    use crate::util::rng::Pcg32;

    /// Every registered multiplier must keep sign and exponent semantics of
    /// an exact FP multiplier: correct sign, zero handling, and a mantissa
    /// relative error bounded by the design class (< 12.5% — Mitchell's
    /// worst case is ~11.1%).
    #[test]
    fn all_models_are_plausible_multipliers() {
        let mut rng = Pcg32::seeded(99);
        for name in registry::names() {
            let m = registry::by_name(name).unwrap();
            assert_eq!(m.name(), *name);
            for _ in 0..2000 {
                let a = quant(rng.range(-100.0, 100.0), m.mantissa_bits());
                let b = quant(rng.range(-100.0, 100.0), m.mantissa_bits());
                let c = m.mul(a, b);
                let exact = a * b;
                if exact == 0.0 {
                    assert_eq!(c, 0.0, "{name}: 0 handling");
                    continue;
                }
                assert_eq!(
                    c.is_sign_negative(),
                    exact.is_sign_negative() || c == 0.0 && exact.abs() < 1e-30,
                    "{name}: sign of {a}*{b}"
                );
                let re = ((c - exact) / exact).abs();
                assert!(re < 0.125, "{name}: rel err {re} for {a}*{b} -> {c} (exact {exact})");
            }
        }
    }

    fn quant(v: f32, m: u32) -> f32 {
        crate::mult::quantize_mantissa(v, m)
    }
}
