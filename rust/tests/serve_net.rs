//! End-to-end suite for the networked serving tier, over real loopback
//! sockets.
//!
//! Two pillars:
//!
//! * **The standing bit-gate**: every accepted networked reply is
//!   bit-identical to the in-process `serve_on_caller` reference, for all
//!   three multiplier strategies (native / direct / LUT), under
//!   multi-client × multi-lane × mixed-priority load. The network layer
//!   may shed, expire, or fail a request — it may never alter its bits.
//! * **The fault matrix**: every scripted fault (lane kill mid-batch,
//!   slow lane, admission delay, mid-frame disconnect, truncated /
//!   oversized / garbage / corrupt frames, expired deadlines, overload,
//!   quota, drain timeout) must surface as a *typed* error or a clean
//!   degradation — never a hang, a panic, or a silent wrong answer.
//!
//! Every scenario asserts through the fault-injection counters that the
//! scripted fault actually fired, so none of these tests can rot into
//! vacuous passes.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use approxtrain::coordinator::backend::{CpuBackend, MulSpec};
use approxtrain::coordinator::faults::{
    oversized_header, send_raw_and_read_reply, send_truncated, FaultPlan,
};
use approxtrain::coordinator::net::{
    spawn, NetClient, NetConfig, NetHandle, NetRegistry, RetryPolicy, TenantSpec,
};
use approxtrain::coordinator::server::{serve_on_caller, InferError, ServeConfig};
use approxtrain::coordinator::wire::{
    self, frame_bytes, FrameKind, Priority, RequestFrame, ResponseFrame, Status,
};
use approxtrain::data::synth::{mnist_like, SynthSpec};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Deterministic client policy: bounded retries, no sleeping.
fn test_retry(max_attempts: usize) -> RetryPolicy {
    RetryPolicy {
        max_attempts,
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(8),
        sleep: false,
    }
}

fn raw_request(id: u64, priority: Priority, deadline_ms: u32, tenant: &str, image: Vec<f32>) -> Vec<u8> {
    frame_bytes(
        FrameKind::Request,
        &RequestFrame { id, priority, deadline_ms, tenant: tenant.to_string(), image }.encode(),
    )
}

fn read_response(s: &mut TcpStream) -> ResponseFrame {
    let (kind, body) = wire::read_frame(s).expect("read response frame");
    assert_eq!(kind, FrameKind::Response);
    ResponseFrame::decode(&body).expect("decode response")
}

/// Poll until `cond` holds (bounded) — used to wait for a scripted fault
/// to have provably fired before the next step of a scenario.
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn lenet_images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let ds = mnist_like(&SynthSpec { n, seed, ..SynthSpec::mnist_like_default() });
    (0..n).map(|i| ds.image(i).to_vec()).collect()
}

/// The standing bit-gate: for every multiplier strategy, replies served
/// over loopback by 2 lanes to 3 concurrent mixed-priority clients carry
/// exactly the bits the in-process caller-thread reference produces.
#[test]
fn networked_replies_bit_identical_to_in_process_reference() {
    let n = 6usize;
    let images = lenet_images(n, 31);
    let cfg = ServeConfig { max_wait: Duration::from_millis(2), queue_depth: 64 };
    for mode in ["native", "direct:afm16", "lut:afm16"] {
        let base =
            CpuBackend::for_model("lenet300", MulSpec::parse(mode).unwrap(), 4, 3).unwrap();

        // in-process reference replies, one per request index
        let mut reference = base.replicas(1).pop().unwrap();
        let images_ref = &images;
        let (_, want) = serve_on_caller(&mut reference, cfg, |client| {
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..3)
                    .map(|t| {
                        let client = client.clone();
                        s.spawn(move || {
                            let mut out = Vec::new();
                            let mut i = t;
                            while i < images_ref.len() {
                                out.push((i, client.infer(images_ref[i].clone()).expect("ref")));
                                i += 3;
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().unwrap())
                    .collect::<BTreeMap<usize, approxtrain::coordinator::server::Reply>>()
            })
        })
        .expect("serve_on_caller reference");

        // the same images over real sockets: 2 lanes, 3 clients, all
        // three priority classes in the mix
        let mut reg = NetRegistry::new();
        reg.add("t0", base.replicas(1).pop().unwrap(), TenantSpec { lanes: 2, quota: 0 })
            .unwrap();
        let handle = spawn(
            "127.0.0.1:0",
            reg,
            NetConfig { serve: cfg, ..NetConfig::default() },
            FaultPlan::none(),
        )
        .expect("spawn server");
        let addr = handle.addr();
        let got: BTreeMap<usize, (u64, Vec<f32>)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..3)
                .map(|t| {
                    let images = &images;
                    s.spawn(move || {
                        let mut client =
                            NetClient::connect(addr, "t0", test_retry(1)).expect("connect");
                        let mut out = Vec::new();
                        let mut i = t;
                        while i < images.len() {
                            let prio = Priority::ALL[i % 3];
                            let reply = client.infer(&images[i], prio, None).expect("infer");
                            out.push((i, (reply.epoch, reply.logits)));
                            i += 3;
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let report = handle.shutdown().expect("shutdown");
        assert!(report.lane_errors.is_empty(), "{mode}: {:?}", report.lane_errors);
        assert_eq!(report.counts.replied_ok, n as u64, "{mode}");
        assert_eq!(report.counts.shed_total() + report.counts.overflow, 0, "{mode}");
        for i in 0..n {
            let (epoch, logits) = &got[&i];
            assert_eq!(*epoch, 1, "{mode}: pre-swap replies carry epoch 1");
            assert_eq!(
                bits(logits),
                bits(&want[&i].logits),
                "{mode}: request {i} diverged between network and in-process serving"
            );
        }
    }
}

fn one_tenant_server(
    batch: usize,
    spec: TenantSpec,
    cfg: NetConfig,
    faults: FaultPlan,
) -> (NetHandle, Vec<Vec<f32>>) {
    let base = CpuBackend::for_model("lenet300", MulSpec::Native, batch, 3).unwrap();
    let mut reg = NetRegistry::new();
    reg.add("t0", base, spec).unwrap();
    let handle = spawn("127.0.0.1:0", reg, cfg, faults).expect("spawn server");
    (handle, lenet_images(4, 7))
}

/// Expired deadlines surface as typed errors at all three enforcement
/// points — admission, in-queue, and post-compute — and an expired
/// request NEVER receives stale logits.
#[test]
fn deadlines_enforced_at_admission_queue_and_reply() {
    let faults = FaultPlan::none();
    let cfg = NetConfig {
        serve: ServeConfig { max_wait: Duration::from_millis(1), queue_depth: 16 },
        ..NetConfig::default()
    };
    let (handle, images) = one_tenant_server(1, TenantSpec::default(), cfg, faults.clone());
    let addr = handle.addr();

    // (a) admission: an injected admission delay burns the whole budget
    faults.delay_admission("t0", Duration::from_millis(120));
    let mut client = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    let err = client.infer(&images[0], Priority::High, Some(Duration::from_millis(40)));
    assert_eq!(err.unwrap_err(), InferError::DeadlineExceeded, "admission-time expiry");
    assert!(faults.admission_delays_applied() >= 1, "the scripted delay must have fired");
    faults.clear_admission_delay("t0");
    wait_until("admission counter", || handle.counts().expired_admission >= 1);

    // (b)+(c): a slow lane (250ms/batch, batch=1). Request A is popped
    // immediately and expires during compute (post-compute check);
    // request B waits in queue past its budget and expires at pop,
    // without ever being computed.
    faults.delay_lane("t0", 0, Duration::from_millis(250));
    let before = handle.counts();
    std::thread::scope(|s| {
        let ia = &images[0];
        let ib = &images[1];
        let a = s.spawn(move || {
            let mut c = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
            c.infer(ia, Priority::High, Some(Duration::from_millis(100)))
        });
        let b = s.spawn(move || {
            // arrive while A's batch is provably in flight
            std::thread::sleep(Duration::from_millis(60));
            let mut c = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
            c.infer(ib, Priority::High, Some(Duration::from_millis(100)))
        });
        assert_eq!(a.join().unwrap().unwrap_err(), InferError::DeadlineExceeded);
        assert_eq!(b.join().unwrap().unwrap_err(), InferError::DeadlineExceeded);
    });
    assert!(faults.delays_applied() >= 1, "the scripted lane delay must have fired");
    let report = handle.shutdown().expect("shutdown");
    let c = &report.counts;
    assert!(c.expired_reply > before.expired_reply, "post-compute expiry fired");
    assert!(c.expired_queue > before.expired_queue, "in-queue expiry fired");
    assert_eq!(c.replied_ok, 0, "no expired request may receive logits");
    assert_eq!(report.stats.requests, 0, "stats count only successful replies");
}

/// A lane killed mid-batch (after popping requests) fail-stops: the
/// popped requests get typed `Stopped` replies, the queue fails, later
/// requests are turned away typed — nobody hangs, nothing is silent.
#[test]
fn lane_kill_mid_batch_fail_stops_with_typed_replies() {
    let faults = FaultPlan::none();
    faults.kill_lane("t0", 0, 0); // die on the very first batch
    let (handle, images) =
        one_tenant_server(1, TenantSpec::default(), NetConfig::default(), faults.clone());
    let addr = handle.addr();

    let mut client = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    let err = client.infer(&images[0], Priority::High, None).unwrap_err();
    assert_eq!(err, InferError::Stopped, "popped request answered typed, not stranded");
    assert_eq!(faults.kills_fired(), 1, "the scripted kill must have fired");

    // the tenant is now fail-stopped: fresh requests get typed rejections
    let mut client2 = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    let err2 = client2.infer(&images[1], Priority::High, None).unwrap_err();
    assert_eq!(err2, InferError::Stopped);

    let report = handle.shutdown().expect("shutdown");
    assert_eq!(report.lane_errors.len(), 1, "{:?}", report.lane_errors);
    assert!(report.lane_errors[0].contains("injected fault"), "{:?}", report.lane_errors);
    assert!(report.counts.stopped_replies >= 1);
}

/// The hostile-peer half of the matrix: truncated, oversized, garbage,
/// wrong-kind, corrupt-CRC, and undecodable frames each produce a typed
/// `BadRequest` reply (or a counted mid-frame disconnect) and leave the
/// server fully healthy for the next well-behaved client.
#[test]
fn malformed_frames_get_typed_replies_and_leave_server_healthy() {
    let (handle, images) =
        one_tenant_server(2, TenantSpec::default(), NetConfig::default(), FaultPlan::none());
    let addr = handle.addr();
    let valid = raw_request(9, Priority::Normal, 0, "t0", images[0].clone());

    // (a) mid-frame disconnect: a few body bytes then close
    send_truncated(addr, &valid, wire::HEADER_LEN + 3).unwrap();
    wait_until("mid-frame disconnect counted", || handle.counts().disconnects_midframe >= 1);

    // (b) garbage header → typed BadRequest reply, then close
    let (_, body) = send_raw_and_read_reply(addr, &[0xAAu8; 64]).expect("garbage gets a reply");
    assert_eq!(ResponseFrame::decode(&body).unwrap().status, Status::BadRequest);

    // (c) oversized declared body: rejected from the header alone,
    // before any allocation could happen
    let (_, body) =
        send_raw_and_read_reply(addr, &oversized_header(u32::MAX)).expect("oversize gets a reply");
    let resp = ResponseFrame::decode(&body).unwrap();
    assert_eq!(resp.status, Status::BadRequest);
    assert!(resp.message.contains("body"), "{}", resp.message);

    // (d) corrupt CRC
    let mut corrupt = valid.clone();
    let n = corrupt.len();
    corrupt[n - 1] ^= 0xFF;
    let (_, body) = send_raw_and_read_reply(addr, &corrupt).expect("bad crc gets a reply");
    assert_eq!(ResponseFrame::decode(&body).unwrap().status, Status::BadRequest);

    // (e) wrong frame kind (a response where a request belongs)
    let wrong_kind = frame_bytes(
        FrameKind::Response,
        &RequestFrame {
            id: 1,
            priority: Priority::Low,
            deadline_ms: 0,
            tenant: "t0".into(),
            image: images[0].clone(),
        }
        .encode(),
    );
    let (_, body) = send_raw_and_read_reply(addr, &wrong_kind).expect("wrong kind gets a reply");
    assert_eq!(ResponseFrame::decode(&body).unwrap().status, Status::BadRequest);

    // (f) valid framing, undecodable body
    let junk_frame = frame_bytes(FrameKind::Request, &[0xFFu8; 5]);
    let (_, body) = send_raw_and_read_reply(addr, &junk_frame).expect("junk body gets a reply");
    assert_eq!(ResponseFrame::decode(&body).unwrap().status, Status::BadRequest);

    let counts = handle.counts();
    assert!(counts.malformed >= 5, "every hostile frame counted: {counts:?}");

    // the server shrugged it all off: a well-behaved client still works
    let mut client = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    let reply = client.infer(&images[1], Priority::High, None).expect("server still healthy");
    assert_eq!(reply.epoch, 1);
    let report = handle.shutdown().expect("shutdown");
    assert!(report.lane_errors.is_empty());
    assert_eq!(report.counts.replied_ok, 1);
}

/// Exact shed accounting under deterministic overload: with the single
/// lane provably busy and depth 4 (limits: Low 2, Normal 3, High 4), a
/// scripted pipelined flood produces exactly one Low shed, one Normal
/// shed, and one High overflow — and every admitted request is served.
#[test]
fn priority_load_shedding_with_exact_accounting() {
    let faults = FaultPlan::none();
    faults.delay_lane("t0", 0, Duration::from_millis(400));
    let cfg = NetConfig {
        serve: ServeConfig { max_wait: Duration::from_millis(1), queue_depth: 4 },
        ..NetConfig::default()
    };
    let (handle, images) = one_tenant_server(1, TenantSpec::default(), cfg, faults.clone());
    let addr = handle.addr();

    let mut s = TcpStream::connect(addr).unwrap();
    use std::io::Write;
    // request 1 occupies the lane (popped, then the scripted 400ms delay
    // holds it there while the flood below races nothing)
    s.write_all(&raw_request(1, Priority::High, 0, "t0", images[0].clone())).unwrap();
    s.flush().unwrap();
    wait_until("lane busy on request 1", || faults.delays_applied() >= 1);
    // pipelined flood, admitted sequentially by the single reader:
    // occupancy walks 0→1→2 | shed → 3 | shed → 4 | overflow
    let flood: [(u64, Priority); 7] = [
        (2, Priority::Low),    // occ 0 < 2 → admit
        (3, Priority::Low),    // occ 1 < 2 → admit
        (4, Priority::Low),    // occ 2 ≥ 2 → SHED
        (5, Priority::Normal), // occ 2 < 3 → admit
        (6, Priority::Normal), // occ 3 ≥ 3 → SHED
        (7, Priority::High),   // occ 3 < 4 → admit
        (8, Priority::High),   // occ 4 ≥ 4 → OVERFLOW
    ];
    for (id, prio) in flood {
        s.write_all(&raw_request(id, prio, 0, "t0", images[(id % 4) as usize].clone())).unwrap();
    }
    s.flush().unwrap();
    faults.clear_lane_delay("t0", 0); // let the backlog drain fast

    let mut statuses = BTreeMap::new();
    for _ in 0..8 {
        let resp = read_response(&mut s);
        statuses.insert(resp.id, resp.status);
    }
    for id in [1u64, 2, 3, 5, 7] {
        assert_eq!(statuses[&id], Status::Ok, "admitted request {id} served");
    }
    assert_eq!(statuses[&4], Status::Shed, "third Low shed at occupancy 2");
    assert_eq!(statuses[&6], Status::Shed, "second Normal shed at occupancy 3");
    assert_eq!(statuses[&8], Status::Overflow, "second High overflows at depth 4");

    let report = handle.shutdown().expect("shutdown");
    let c = &report.counts;
    assert_eq!(c.accepted, 6);
    assert_eq!(c.replied_ok, 6);
    assert_eq!(c.shed, [0, 1, 1], "one Normal shed, one Low shed, High never shed");
    assert_eq!(c.overflow, 1);
    assert_eq!(report.stats.rejected, 3, "aggregate reject accounting: 2 sheds + 1 overflow");
}

/// Client retry discipline, proven against a scripted server: idempotent
/// rejections (shed) are retried with fresh ids up to the bound;
/// non-idempotent rejections are surfaced immediately; a connection that
/// dies awaiting a reply is `Ambiguous` and NEVER retried.
#[test]
fn client_retries_only_idempotent_rejections() {
    use std::io::Write;
    use std::net::TcpListener;

    // scripted peer: shed, shed, then Ok — a 3-attempt client succeeds
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let script = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut seen = Vec::new();
        for attempt in 0..3 {
            let (_, body) = wire::read_frame(&mut s).unwrap();
            let req = RequestFrame::decode(&body).unwrap();
            seen.push(req.id);
            let status = if attempt < 2 { Status::Shed } else { Status::Ok };
            let resp = ResponseFrame {
                id: req.id,
                status,
                epoch: 1,
                logits: if attempt < 2 { vec![] } else { vec![0.25] },
                message: String::new(),
            };
            s.write_all(&frame_bytes(FrameKind::Response, &resp.encode())).unwrap();
        }
        seen
    });
    let mut client = NetClient::connect(addr, "t0", test_retry(3)).unwrap();
    let reply = client.infer(&[1.0], Priority::Normal, None).expect("third attempt lands");
    assert_eq!(reply.logits, vec![0.25]);
    let seen = script.join().unwrap();
    assert_eq!(seen.len(), 3, "exactly max_attempts requests on the wire");
    assert!(seen[0] < seen[1] && seen[1] < seen[2], "every attempt gets a fresh id");

    // scripted peer: always shed — the bound holds and the error is typed
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let script = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut n = 0usize;
        while let Ok((_, body)) = wire::read_frame(&mut s) {
            let req = RequestFrame::decode(&body).unwrap();
            n += 1;
            let resp = ResponseFrame {
                id: req.id,
                status: Status::Shed,
                epoch: 0,
                logits: vec![],
                message: "no".into(),
            };
            if s.write_all(&frame_bytes(FrameKind::Response, &resp.encode())).is_err() {
                break;
            }
        }
        n
    });
    let mut client = NetClient::connect(addr, "t0", test_retry(2)).unwrap();
    let err = client.infer(&[1.0], Priority::Low, None).unwrap_err();
    assert_eq!(err, InferError::Shed { priority: Priority::Low });
    drop(client); // close the socket so the script thread sees EOF
    assert_eq!(script.join().unwrap(), 2, "retry bound respected");

    // scripted peer: DeadlineExceeded is NOT idempotent → no retry
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let script = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut n = 0usize;
        while let Ok((_, body)) = wire::read_frame(&mut s) {
            let req = RequestFrame::decode(&body).unwrap();
            n += 1;
            let resp = ResponseFrame {
                id: req.id,
                status: Status::DeadlineExceeded,
                epoch: 0,
                logits: vec![],
                message: String::new(),
            };
            if s.write_all(&frame_bytes(FrameKind::Response, &resp.encode())).is_err() {
                break;
            }
        }
        n
    });
    let mut client = NetClient::connect(addr, "t0", test_retry(5)).unwrap();
    let err = client.infer(&[1.0], Priority::High, None).unwrap_err();
    assert_eq!(err, InferError::DeadlineExceeded);
    drop(client);
    assert_eq!(script.join().unwrap(), 1, "non-idempotent rejection never retried");

    // scripted peer: accept the request, then slam the door — the
    // request is in flight, so the client reports Ambiguous and must NOT
    // have re-sent it (the accept loop would have seen a second conn/req)
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let script = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let (_, _body) = wire::read_frame(&mut s).unwrap();
        drop(s); // close without replying
        // a retry would need a new connection: give it a moment to appear
        listener.set_nonblocking(true).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        listener.accept().is_ok()
    });
    let mut client = NetClient::connect(addr, "t0", test_retry(5)).unwrap();
    match client.infer(&[1.0], Priority::High, None).unwrap_err() {
        InferError::Ambiguous(_) => {}
        other => panic!("expected Ambiguous, got {other:?}"),
    }
    assert!(!script.join().unwrap(), "an ambiguous in-flight request must never be retried");
}

/// Typed admission errors: unknown tenant, wrong image shape, and the
/// per-tenant outstanding-request quota (with exact accounting).
#[test]
fn typed_admission_errors_and_quota() {
    let faults = FaultPlan::none();
    let (handle, images) = one_tenant_server(
        1,
        TenantSpec { lanes: 1, quota: 1 },
        NetConfig::default(),
        faults.clone(),
    );
    let addr = handle.addr();

    let mut client = NetClient::connect(addr, "nope", test_retry(1)).unwrap();
    match client.infer(&images[0], Priority::High, None).unwrap_err() {
        InferError::UnknownTenant(msg) => assert!(msg.contains("nope"), "{msg}"),
        other => panic!("expected UnknownTenant, got {other:?}"),
    }
    let mut client = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    match client.infer(&[1.0, 2.0], Priority::High, None).unwrap_err() {
        InferError::BadRequest(msg) => assert!(msg.contains("f32s"), "{msg}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }

    // quota 1: with one request provably outstanding (lane held by the
    // scripted delay), pipelined requests 2 and 3 are quota-rejected
    faults.delay_lane("t0", 0, Duration::from_millis(300));
    use std::io::Write;
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&raw_request(1, Priority::High, 0, "t0", images[0].clone())).unwrap();
    s.flush().unwrap();
    wait_until("lane busy on request 1", || faults.delays_applied() >= 1);
    for id in [2u64, 3] {
        s.write_all(&raw_request(id, Priority::High, 0, "t0", images[1].clone())).unwrap();
    }
    s.flush().unwrap();
    faults.clear_lane_delay("t0", 0);
    let mut statuses = BTreeMap::new();
    for _ in 0..3 {
        let resp = read_response(&mut s);
        statuses.insert(resp.id, resp.status);
    }
    assert_eq!(statuses[&1], Status::Ok);
    assert_eq!(statuses[&2], Status::QuotaExceeded);
    assert_eq!(statuses[&3], Status::QuotaExceeded);

    // the quota slot came back once request 1 resolved
    let mut client = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    client.infer(&images[2], Priority::High, None).expect("quota released after reply");

    let report = handle.shutdown().expect("shutdown");
    assert_eq!(report.counts.quota_rejected, 2);
    assert_eq!(report.counts.unknown_tenant, 1);
    assert_eq!(report.counts.replied_ok, 2);
}

/// LUT hot-swap behind the epoch: replies before the swap carry epoch 1
/// and the old multiplier's bits; replies after it carry epoch 2 and are
/// bit-identical to an in-process reference built on the new multiplier.
/// No request ever observes a half-swapped table (every reply's bits
/// match one epoch's reference exactly).
#[test]
fn lut_hot_swap_is_epoch_atomic_and_bit_exact() {
    let images = lenet_images(3, 11);
    let cfg = ServeConfig { max_wait: Duration::from_millis(1), queue_depth: 16 };
    let base = CpuBackend::for_model("lenet300", MulSpec::parse("lut:afm16").unwrap(), 2, 5)
        .unwrap();
    // reference bits for both epochs, computed in-process
    let reference = |mul: &str| -> Vec<Vec<f32>> {
        let mut b =
            CpuBackend::for_model("lenet300", MulSpec::parse(mul).unwrap(), 2, 5).unwrap();
        let images = &images;
        let (_, replies) = serve_on_caller(&mut b, cfg, |client| {
            images.iter().map(|im| client.infer(im.clone()).unwrap().logits).collect::<Vec<_>>()
        })
        .unwrap();
        replies
    };
    let want_old = reference("lut:afm16");
    let want_new = reference("lut:mit16");

    let mut reg = NetRegistry::new();
    reg.add("t0", base, TenantSpec { lanes: 2, quota: 0 }).unwrap();
    let handle = spawn(
        "127.0.0.1:0",
        reg,
        NetConfig { serve: cfg, ..NetConfig::default() },
        FaultPlan::none(),
    )
    .unwrap();
    let mut client = NetClient::connect(handle.addr(), "t0", test_retry(1)).unwrap();

    for (i, im) in images.iter().enumerate() {
        let r = client.infer(im, Priority::Normal, None).unwrap();
        assert_eq!(r.epoch, 1);
        assert_eq!(bits(&r.logits), bits(&want_old[i]), "pre-swap request {i}");
    }
    let epoch = handle.swap_mul("t0", MulSpec::parse("lut:mit16").unwrap()).unwrap();
    assert_eq!(epoch, 2);
    assert!(matches!(
        handle.swap_mul("ghost", MulSpec::Native),
        Err(InferError::UnknownTenant(_))
    ));
    for (i, im) in images.iter().enumerate() {
        let r = client.infer(im, Priority::Normal, None).unwrap();
        assert_eq!(r.epoch, 2, "post-swap replies carry the new epoch");
        assert_eq!(bits(&r.logits), bits(&want_new[i]), "post-swap request {i}");
        assert_ne!(bits(&r.logits), bits(&want_old[i]), "the swap visibly changed the bits");
    }
    let report = handle.shutdown().unwrap();
    assert_eq!(report.counts.lut_swaps, 1);
    assert!(report.lane_errors.is_empty());
}

/// Graceful drain: shutdown finishes everything admitted, reports clean;
/// afterwards the port is closed to new clients.
#[test]
fn graceful_drain_finishes_admitted_work() {
    let (handle, images) =
        one_tenant_server(2, TenantSpec::default(), NetConfig::default(), FaultPlan::none());
    let addr = handle.addr();
    let mut client = NetClient::connect(addr, "t0", test_retry(1)).unwrap();
    for im in &images {
        client.infer(im, Priority::Normal, None).expect("served");
    }
    let report = handle.shutdown().expect("shutdown");
    assert!(!report.drain_timed_out);
    assert!(report.lane_errors.is_empty());
    assert_eq!(report.counts.replied_ok, images.len() as u64);
    assert_eq!(report.counts.drain_dropped, 0);
    assert_eq!(report.stats.requests, images.len());
    // the listener is gone: connecting (or speaking) now fails
    let refused = match NetClient::connect(addr, "t0", test_retry(1)) {
        Err(_) => true,
        Ok(mut c) => c.infer(&images[0], Priority::High, None).is_err(),
    };
    assert!(refused, "a drained server accepts no new work");
}

/// Drain timeout: work that cannot finish inside the drain deadline is
/// fail-stopped — queued requests get typed `Stopped` replies and are
/// counted in `drain_dropped`, and the report says the drain timed out.
#[test]
fn drain_timeout_fail_stops_queued_work_with_typed_replies() {
    let faults = FaultPlan::none();
    faults.delay_lane("t0", 0, Duration::from_millis(400));
    let cfg = NetConfig {
        serve: ServeConfig { max_wait: Duration::from_millis(1), queue_depth: 16 },
        drain_deadline: Duration::from_millis(50),
        ..NetConfig::default()
    };
    let (handle, images) = one_tenant_server(1, TenantSpec::default(), cfg, faults.clone());
    let addr = handle.addr();

    use std::io::Write;
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&raw_request(1, Priority::High, 0, "t0", images[0].clone())).unwrap();
    s.flush().unwrap();
    wait_until("lane busy on request 1", || faults.delays_applied() >= 1);
    // these two are admitted but the lane (held 400ms) can't reach them
    // inside the 50ms drain deadline
    for id in [2u64, 3] {
        s.write_all(&raw_request(id, Priority::High, 0, "t0", images[1].clone())).unwrap();
    }
    s.flush().unwrap();
    wait_until("flood admitted", || handle.counts().accepted >= 3);

    let report = handle.shutdown().expect("shutdown");
    assert!(report.drain_timed_out, "the drain deadline was provably exceeded");
    assert_eq!(report.counts.drain_dropped, 2, "exactly the unreachable requests dropped");
    assert!(report.counts.stopped_replies >= 2);

    // the client still got a typed answer for every single request
    let mut statuses = BTreeMap::new();
    for _ in 0..3 {
        let resp = read_response(&mut s);
        statuses.insert(resp.id, resp.status);
    }
    assert_eq!(statuses[&1], Status::Ok, "in-flight work finished even past the deadline");
    assert_eq!(statuses[&2], Status::Stopped);
    assert_eq!(statuses[&3], Status::Stopped);
}

/// Multi-tenant isolation: two tenants with different multipliers serve
/// concurrently from one port; each one's replies match its own
/// in-process reference, and a fault in one tenant's lane leaves the
/// other serving untouched.
#[test]
fn tenants_are_isolated_including_under_faults() {
    let images = lenet_images(3, 13);
    let cfg = ServeConfig { max_wait: Duration::from_millis(1), queue_depth: 16 };
    let mk = |mul: &str, seed: u64| {
        CpuBackend::for_model("lenet300", MulSpec::parse(mul).unwrap(), 2, seed).unwrap()
    };
    let reference = |mul: &str, seed: u64| -> Vec<Vec<f32>> {
        let mut b = mk(mul, seed);
        let images = &images;
        let (_, replies) = serve_on_caller(&mut b, cfg, |client| {
            images.iter().map(|im| client.infer(im.clone()).unwrap().logits).collect::<Vec<_>>()
        })
        .unwrap();
        replies
    };
    let want_a = reference("native", 21);
    let want_b = reference("lut:afm16", 22);

    let faults = FaultPlan::none();
    faults.kill_lane("doomed", 0, 0);
    let mut reg = NetRegistry::new();
    reg.add("alpha", mk("native", 21), TenantSpec { lanes: 1, quota: 0 }).unwrap();
    reg.add("beta", mk("lut:afm16", 22), TenantSpec { lanes: 1, quota: 0 }).unwrap();
    reg.add("doomed", mk("native", 23), TenantSpec { lanes: 1, quota: 0 }).unwrap();
    let handle = spawn(
        "127.0.0.1:0",
        reg,
        NetConfig { serve: cfg, ..NetConfig::default() },
        faults.clone(),
    )
    .unwrap();
    let addr = handle.addr();

    // kill the doomed tenant first; alpha/beta must not notice
    let mut doomed = NetClient::connect(addr, "doomed", test_retry(1)).unwrap();
    assert_eq!(doomed.infer(&images[0], Priority::High, None).unwrap_err(), InferError::Stopped);
    assert_eq!(faults.kills_fired(), 1);

    std::thread::scope(|sc| {
        for (tenant, want) in [("alpha", &want_a), ("beta", &want_b)] {
            let images = &images;
            sc.spawn(move || {
                let mut c = NetClient::connect(addr, tenant, test_retry(1)).unwrap();
                for (i, im) in images.iter().enumerate() {
                    let r = c.infer(im, Priority::ALL[i % 3], None).unwrap();
                    assert_eq!(
                        bits(&r.logits),
                        bits(&want[i]),
                        "{tenant}: request {i} must match its own reference"
                    );
                }
            });
        }
    });
    let report = handle.shutdown().unwrap();
    assert_eq!(report.lane_errors.len(), 1, "only the doomed lane errored");
    assert_eq!(report.counts.replied_ok, 6);
}
