//! Model-side support for the coordinator: parameter initialization from
//! manifest metadata, checkpoints, metrics, and the pure-Rust (ATxC)
//! LeNet executors used for CPU-path benchmarks and artifact validation.
pub mod checkpoint;
pub mod cpu_lenet;
pub mod cpu_resnet;
pub mod init;
pub mod metrics;
