//! Integration: the compiled artifacts (Pallas/XLA path, "GPU" analog) must
//! produce the same numbers as the pure-Rust CPU kernels — the paper's own
//! validation methodology (§VI footnote 2).
//!
//! These tests are skipped (pass trivially) when `artifacts/` has not been
//! built; `make test` builds it first.

use std::path::{Path, PathBuf};

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::gemm;
use approxtrain::kernels::MulKernel;
use approxtrain::lut::MantissaLut;
use approxtrain::mult::fpbits::quantize_mantissa;
use approxtrain::mult::registry;
use approxtrain::runtime::executor::{Engine, Value};
use approxtrain::util::rng::Pcg32;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn gemm_lut_artifact_matches_rust_kernels_bitwise() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let Some(art) = engine.manifest().find("gemm128", "gemm", "lut") else {
        eprintln!("skipping: gemm128 lut artifact absent");
        return;
    };
    let name = art.name.clone();
    let n = 128usize;
    let mut rng = Pcg32::seeded(2024);
    let a: Vec<f32> =
        (0..n * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
    let b: Vec<f32> =
        (0..n * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();

    // LUT generated in Rust from the same functional model
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());

    let out = engine
        .run(&name, &[Value::F32(a.clone()), Value::F32(b.clone()), Value::U32(lut.entries.clone())])
        .unwrap();
    let c_xla = out[0].as_f32().unwrap();

    let mut c_rust = vec![0.0f32; n * n];
    gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_rust, n, n, n);

    let mut max_diff = 0.0f32;
    for i in 0..n * n {
        max_diff = max_diff.max((c_xla[i] - c_rust[i]).abs());
    }
    // identical multiplies, different accumulation order -> tiny fp drift
    assert!(max_diff < 2e-3, "ATxG vs ATxC mismatch: {max_diff}");
}

#[test]
fn gemm_native_artifact_matches_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    if engine.manifest().find("gemm128", "gemm", "native").is_none() {
        return;
    }
    let n = 128usize;
    let mut rng = Pcg32::seeded(7);
    let a: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let out = engine
        .run("gemm128_native", &[Value::F32(a.clone()), Value::F32(b.clone())])
        .unwrap();
    let c = out[0].as_f32().unwrap();
    let mut c_ref = vec![0.0f32; n * n];
    gemm(&MulKernel::Native, &a, &b, &mut c_ref, n, n, n);
    for i in 0..n * n {
        assert!((c[i] - c_ref[i]).abs() < 1e-3, "idx {i}: {} vs {}", c[i], c_ref[i]);
    }
}

#[test]
fn lut_files_from_python_match_rust_generation() {
    let Some(dir) = artifacts_dir() else { return };
    let lut_dir = dir.join("luts");
    if !lut_dir.exists() {
        return;
    }
    let mut checked = 0;
    for name in registry::names() {
        if !registry::lut_able(name) {
            continue;
        }
        let path = lut_dir.join(format!("{name}.lut"));
        if !path.exists() {
            continue;
        }
        let from_py = MantissaLut::load(&path).unwrap();
        let model = registry::by_name(name).unwrap();
        let from_rust = MantissaLut::generate(model.as_ref());
        assert_eq!(from_py, from_rust, "python and rust LUTs differ for {name}");
        checked += 1;
    }
    assert!(checked >= 5, "only {checked} LUT golden files checked");
}
