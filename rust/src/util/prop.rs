//! Tiny property-testing helper (no `proptest` offline): run a predicate on
//! many seeded-random cases; on failure report the seed + case index so the
//! exact input can be replayed.

use super::rng::Pcg32;

/// Run `cases` random trials. `gen` draws an input from the RNG, `check`
/// returns `Err(description)` on violation. Panics with a replayable
/// message on the first failure.
pub fn for_all<T: std::fmt::Debug, G, C>(name: &str, seed: u64, cases: usize, mut gen: G, check: C)
where
    G: FnMut(&mut Pcg32) -> T,
    C: Fn(&T) -> Result<(), String>,
{
    let mut rng = Pcg32::new(seed, 0xF00D);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!("property '{name}' failed (seed={seed}, case={i}): {msg}\ninput: {input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        for_all("add-commutes", 1, 200, |r| (r.finite_f32(), r.finite_f32()), |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn reports_failures() {
        for_all("always-fails", 2, 10, |r| r.next_u32(), |_| Err("nope".into()));
    }
}
