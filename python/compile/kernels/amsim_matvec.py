"""Layer-1 Pallas matrix-vector kernel — the paper's dedicated dense-layer
kernel (§VI-C: "shared-memory-based tiling is superfluous for a 1-D
vector", §VI-D "Matrix-Vector Multiplication Kernel").

Grid over output-row blocks only; each cell streams the full input vector
(resident in VMEM) against its weight rows. Used by the single-request
(batch = 1) serving path; batched training uses the GEMM kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bitmath

DEFAULT_BLOCK_OUT = 64


def _kernel(w_ref, x_ref, lut_ref, o_ref, *, mode: str, m: int):
    w = w_ref[...]  # (bo, n_in)
    x = x_ref[...]  # (n_in,)
    if mode == "native":
        o_ref[...] = jnp.dot(w, x, preferred_element_type=jnp.float32)
        return
    if mode == "lut":
        prod = bitmath.amsim_mul(w, x[None, :], lut_ref[...], m)
    else:
        prod = bitmath.direct_mul(w, x[None, :], mode.split(":", 1)[1])
    o_ref[...] = jnp.sum(prod, axis=1, dtype=jnp.float32)


def _kernel_nolut(w_ref, x_ref, o_ref, *, mode: str):
    # duplicate of _kernel without the LUT ref (pallas kernels have a fixed
    # ref arity per pallas_call)
    w = w_ref[...]
    x = x_ref[...]
    if mode == "native":
        o_ref[...] = jnp.dot(w, x, preferred_element_type=jnp.float32)
    else:
        prod = bitmath.direct_mul(w, x[None, :], mode.split(":", 1)[1])
        o_ref[...] = jnp.sum(prod, axis=1, dtype=jnp.float32)


def am_matvec(w, x, mode: str = "native", lut=None, m: int = 7,
              block_out: Optional[int] = None):
    """``y[o] = sum_i mul(w[o, i], x[i])`` for ``w[n_out, n_in]``."""
    bo = block_out or DEFAULT_BLOCK_OUT
    n_out, n_in = w.shape
    assert x.shape == (n_in,)
    pad_o = -(-n_out // bo) * bo - n_out
    w_p = jnp.pad(w, ((0, pad_o), (0, 0))) if pad_o else w
    grid = ((n_out + pad_o) // bo,)
    w_spec = pl.BlockSpec((bo, n_in), lambda i: (i, 0))
    x_spec = pl.BlockSpec((n_in,), lambda i: (0,))
    o_spec = pl.BlockSpec((bo,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((n_out + pad_o,), jnp.float32)
    if mode == "lut":
        assert lut is not None
        lut_spec = pl.BlockSpec((lut.shape[0],), lambda i: (0,))
        y = pl.pallas_call(
            functools.partial(_kernel, mode=mode, m=m),
            grid=grid,
            in_specs=[w_spec, x_spec, lut_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(w_p, x, lut)
    else:
        y = pl.pallas_call(
            functools.partial(_kernel_nolut, mode=mode),
            grid=grid,
            in_specs=[w_spec, x_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(w_p, x)
    return y[:n_out]
