//! Training driver: runs a (model x mode x multiplier) configuration by
//! repeatedly executing the fused train-step artifact and periodically the
//! forward artifact for test accuracy.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::{Batcher, Dataset, EvalBatcher};
use crate::lut::MantissaLut;
use crate::mult::registry;
use crate::nn::checkpoint::Checkpoint;
use crate::nn::init::{init_params, init_velocities};
use crate::nn::metrics::{correct_from_logits, EpochRecord, RunLog};
use crate::runtime::artifact::Role;
use crate::runtime::executor::{Engine, Value};
use crate::util::json::Json;

/// Configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    /// artifact mode: `tf` | `custom` | `lut` | `direct:afm32`
    pub mode: String,
    /// multiplier name (selects the LUT for `lut` mode; informational
    /// otherwise)
    pub mult: String,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// evaluate test accuracy every N epochs (always on the last)
    pub eval_every: usize,
}

impl TrainConfig {
    pub fn label(&self) -> String {
        format!("{}/{}", self.model, self.mult)
    }
}

/// A live training session holding parameter state host-side.
pub struct Trainer<'e> {
    engine: &'e mut Engine,
    pub cfg: TrainConfig,
    train_art: String,
    fwd_art: String,
    pub params: Vec<Value>,
    pub vels: Vec<Value>,
    lut: Option<Vec<u32>>,
    batch: usize,
    pub classes: usize,
    input_elems: usize,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e mut Engine, cfg: TrainConfig, artifacts_dir: &Path) -> Result<Trainer<'e>> {
        // Spawn the persistent kernel worker pool up front: every GEMM of
        // the CPU fallback/oracle path (and the experiment harness's ATxC
        // timings) reuses it, so no per-step thread spawning ever lands in
        // a timed training step. warm_tiled additionally pre-allocates the
        // per-lane pack buffers of the tiled GEMM (best effort) so first-
        // step latency excludes those allocations too.
        crate::kernels::gemm::warm_tiled();
        let train_art = engine
            .manifest()
            .find(&cfg.model, "train", &cfg.mode)
            .ok_or_else(|| anyhow!("no train artifact for {}/{}", cfg.model, cfg.mode))?
            .clone();
        let fwd_art = engine
            .manifest()
            .find(&cfg.model, "fwd", &cfg.mode)
            .ok_or_else(|| anyhow!("no fwd artifact for {}/{}", cfg.model, cfg.mode))?
            .name
            .clone();

        // parameter init from manifest metadata (same seed across modes)
        let raw = Json::parse(
            &std::fs::read_to_string(artifacts_dir.join("manifest.json"))
                .context("reading manifest for init metadata")?,
        )?;
        let params = init_params(&train_art, cfg.seed, &raw)?;
        let vels = init_velocities(&train_art);

        // LUT: required iff the artifact takes one
        let lut = if !train_art.input_indices(Role::Lut).is_empty() {
            let model = registry::by_name(&cfg.mult)
                .ok_or_else(|| anyhow!("unknown multiplier {}", cfg.mult))?;
            if !registry::lut_able(&cfg.mult) {
                bail!("multiplier {} is not tabulatable; use a direct-mode artifact", cfg.mult);
            }
            // prefer the Python-generated golden file; fall back to Rust gen
            let path = artifacts_dir.join("luts").join(format!("{}.lut", cfg.mult));
            let table = if path.exists() {
                MantissaLut::load(&path).map_err(|e| anyhow!("{e}"))?
            } else {
                MantissaLut::generate(model.as_ref())
            };
            // a structurally invalid table would silently corrupt every
            // simulated multiply (AmSim elides its bounds check on the
            // strength of these invariants) — fail loudly instead
            table
                .validate()
                .map_err(|e| anyhow!("LUT for {} failed validation: {e}", cfg.mult))?;
            Some(table.entries)
        } else {
            None
        };

        let x_idx = train_art.input_indices(Role::Input);
        let input_spec = &train_art.inputs[x_idx[0]];
        let batch = input_spec.shape[0];
        let input_elems = input_spec.elements();
        let fwd = engine.manifest().get(&fwd_art)?.clone();
        let classes = fwd.outputs[0].shape[1];
        Ok(Trainer {
            engine,
            cfg,
            train_art: train_art.name.clone(),
            fwd_art,
            params,
            vels,
            lut,
            batch,
            classes,
            input_elems,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// One train step; returns (loss, train-batch accuracy).
    pub fn step(&mut self, images: &[f32], labels: &[u32]) -> Result<(f32, f32)> {
        assert_eq!(images.len(), self.input_elems, "batch image size");
        assert_eq!(labels.len(), self.batch);
        let mut inputs: Vec<Value> = Vec::with_capacity(self.params.len() * 2 + 4);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.vels.iter().cloned());
        inputs.push(Value::F32(images.to_vec()));
        inputs.push(Value::I32(labels.iter().map(|&l| l as i32).collect()));
        if let Some(lut) = &self.lut {
            inputs.push(Value::U32(lut.clone()));
        }
        inputs.push(Value::F32(vec![self.cfg.lr]));
        let mut out = self.engine.run(&self.train_art, &inputs)?;
        let n = self.params.len();
        let acc = out.pop().unwrap().scalar_f32()?;
        let loss = out.pop().unwrap().scalar_f32()?;
        self.vels = out.split_off(n);
        self.params = out;
        Ok((loss, acc))
    }

    /// Test-set accuracy via the forward artifact. Walks the test set in
    /// dataset order (no shuffle — evaluation is order-independent, and
    /// determinism is clearer unshuffled), pads the trailing partial
    /// batch to the artifact's fixed batch shape by cycling its real
    /// samples (so batch-statistics layers never see artificial zero
    /// rows), and scores only the real samples, weighted per sample — so
    /// 100% of the test set contributes exactly once, including test
    /// sets smaller than one batch. Only an *empty* test set is an error.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<f32> {
        if ds.n == 0 {
            bail!("cannot evaluate: test set is empty");
        }
        let mut correct = 0usize;
        for (images, labels) in EvalBatcher::new(ds, self.batch) {
            let mut inputs: Vec<Value> = self.params.clone();
            inputs.push(Value::F32(images));
            if let Some(lut) = &self.lut {
                inputs.push(Value::U32(lut.clone()));
            }
            let out = self.engine.run(&self.fwd_art, &inputs)?;
            let logits = out[0].as_f32()?;
            // padded rows sit at the tail; score only the real ones
            correct +=
                correct_from_logits(&logits[..labels.len() * self.classes], labels, self.classes);
        }
        Ok(correct as f32 / ds.n as f32)
    }

    /// Full training loop over `train`/`test`; returns the per-epoch log.
    pub fn fit(&mut self, train: &Dataset, test: &Dataset) -> Result<RunLog> {
        let mut log = RunLog::new(&self.cfg.label());
        for epoch in 0..self.cfg.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0;
            let mut acc_sum = 0.0;
            let mut n = 0;
            for (images, labels) in Batcher::new(train, self.batch, self.cfg.seed, epoch as u64) {
                let (loss, acc) = self.step(&images, &labels)?;
                loss_sum += loss;
                acc_sum += acc;
                n += 1;
            }
            let eval_due = (epoch + 1) % self.cfg.eval_every.max(1) == 0
                || epoch + 1 == self.cfg.epochs;
            let test_acc = if eval_due { self.evaluate(test)? } else { f32::NAN };
            log.epochs.push(EpochRecord {
                epoch,
                train_loss: loss_sum / n.max(1) as f32,
                train_acc: acc_sum / n.max(1) as f32,
                test_acc,
                seconds: t0.elapsed().as_secs_f64(),
            });
        }
        Ok(log)
    }

    /// Export parameters as a named checkpoint (names from the manifest).
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        let art = self.engine.manifest().get(&self.train_art)?.clone();
        let mut ckpt = Checkpoint::default();
        for (value, idx) in self.params.iter().zip(art.input_indices(Role::Param)) {
            let spec = &art.inputs[idx];
            ckpt.insert(&spec.name, &spec.shape, value.as_f32()?.to_vec());
        }
        Ok(ckpt)
    }

    /// Load parameters from a checkpoint (e.g. trained under a different
    /// multiplier — the Table IV cross-format experiment).
    pub fn load_checkpoint(&mut self, ckpt: &Checkpoint) -> Result<()> {
        let art = self.engine.manifest().get(&self.train_art)?.clone();
        for (value, idx) in self.params.iter_mut().zip(art.input_indices(Role::Param)) {
            let spec = &art.inputs[idx];
            let (shape, data) = ckpt
                .get(&spec.name)
                .ok_or_else(|| anyhow!("checkpoint missing {}", spec.name))?;
            if *shape != spec.shape {
                bail!("checkpoint shape mismatch for {}", spec.name);
            }
            *value = Value::F32(data.clone());
        }
        Ok(())
    }

    /// Mutable access to parameters (pruning applies masks in place).
    pub fn params_mut(&mut self) -> &mut Vec<Value> {
        &mut self.params
    }
}
