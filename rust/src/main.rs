//! ApproxTrain launcher — the Layer-3 entrypoint.
//!
//! ```text
//! approxtrain gen-lut --mult afm16 --out afm16.lut
//! approxtrain hwmodel
//! approxtrain train --model lenet5 --mode lut --mult afm16 --epochs 3
//! approxtrain train-dp --model lenet300 --mode lut:afm16 --workers 4 --epochs 2
//! approxtrain infer --model lenet5 --mode lut --mult afm16
//! approxtrain serve --model lenet300 --lanes 4 --mode lut:afm16 --requests 64
//! approxtrain bench-gemm --size 256
//! approxtrain bench-conv
//! approxtrain bench-serve
//! approxtrain bench-train
//! approxtrain experiment fig6|fig10|table3|table4|table5|table6|fig11|fig12|all [--quick]
//! approxtrain list-artifacts
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use approxtrain::cli::Args;
use approxtrain::coordinator::experiments;
use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::runtime::executor::Engine;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("artifacts", "artifacts"))
}

fn results_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("results", "results"))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_str() {
        "gen-lut" => gen_lut(&args),
        "hwmodel" => {
            println!("{}", experiments::fig1(&results_dir(&args))?);
            Ok(())
        }
        "train" => train(&args),
        "train-dp" => train_dp(&args),
        "infer" => infer(&args),
        "serve" => serve(&args),
        "bench-gemm" => {
            // pure CPU-kernel benchmark; needs no artifacts. Full-budget
            // runs refresh the committed BENCH_gemm.json at the repo root;
            // --quick keeps its low-budget numbers in results/ only.
            let quick = args.has_flag("quick");
            let out = experiments::bench_gemm(
                &results_dir(&args),
                args.opt_usize("size", 256),
                quick,
                !quick,
            )?;
            println!("{out}");
            Ok(())
        }
        "bench-conv" => {
            // implicit-GEMM vs materialized-im2col conv benchmark; pure
            // CPU path, same root-record policy as bench-gemm
            let quick = args.has_flag("quick");
            let out = experiments::bench_conv(&results_dir(&args), quick, !quick)?;
            println!("{out}");
            Ok(())
        }
        "bench-serve" => {
            // multi-lane batching server sweep over the CPU executor
            // backend; pure CPU path, same root-record policy as the
            // other bench commands. --net adds the loopback TCP sweep
            // through the fault-tolerant serving tier (deadlines,
            // priorities, shedding) with its bit-exactness gate.
            let quick = args.has_flag("quick");
            let net = args.has_flag("net");
            let out = experiments::bench_serve(&results_dir(&args), quick, !quick, net)?;
            println!("{out}");
            Ok(())
        }
        "bench-train" => {
            // deterministic data-parallel training sweep over the CPU
            // executors; pure CPU path, same root-record policy as the
            // other bench commands
            let quick = args.has_flag("quick");
            let out = experiments::bench_train(&results_dir(&args), quick, !quick)?;
            println!("{out}");
            Ok(())
        }
        "experiment" => experiment(&args),
        "list-artifacts" => list_artifacts(&args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `approxtrain help`"),
    }
}

const HELP: &str = "\
ApproxTrain — fast simulation of approximate FP multipliers for DNN \
training and inference (Rust + JAX + Pallas reproduction).

commands:
  gen-lut --mult <name> [--out file.lut]   generate a mantissa-product LUT
  hwmodel                                  Fig 1 resource-efficiency model
  train --model <m> --mode <tf|custom|lut|direct:afm32> --mult <name>
        [--epochs N] [--lr F] [--samples N] [--seed N] [--ckpt out.ckpt]
  train-dp --model <m> [--mode native|direct:<mult>|lut:<mult>] [--workers N]
        [--shard N] [--accum N] [--epochs N] [--batch N] [--lr F] [--samples N]
        [--seed N] [--ckpt-dir DIR] [--ckpt-shards N]
        deterministic data-parallel training on the pure-Rust executors
        (loss curve is bit-identical for any --workers)
  infer --model <m> --mode <...> --mult <name> [--samples N] [--ckpt f]
  serve --model <m> [--backend cpu|engine] [--lanes N] [--batch N]
        [--queue-depth N] [--requests N] [--clients N] [--batch-wait-ms N]
        [--mode ...]                       multi-lane batching inference server
        (cpu modes: native|direct:<mult>|lut:<mult>; engine modes are
         artifact modes: tf|custom|lut|direct:<mult>, plus --mult for the LUT)
  bench-gemm [--size N] [--quick]          CPU GEMM perf record (BENCH_gemm.json;
        per-SIMD-level rows — env APPROXTRAIN_SIMD=scalar|avx2|avx2fma|auto
        caps the active level for all kernels, requests above the machine clamp)
  bench-conv [--quick]                     implicit vs materialized conv (BENCH_conv.json)
  bench-serve [--quick] [--net]            serving sweep: lanes x load x strategy; --net adds the
                                           networked tier (connections x lanes x priority mix)
  bench-train [--quick]                    data-parallel training sweep: workers x strategy (BENCH_train.json)
  experiment <fig1|fig6|fig10|table3|table4|table5|table6|fig11|fig12|all>
        [--quick]
  list-artifacts
common options: --artifacts DIR (default artifacts) --results DIR
";

fn gen_lut(args: &Args) -> Result<()> {
    let name = args.opt("mult").context("--mult required")?;
    let model = registry::by_name(name).with_context(|| format!("unknown multiplier {name}"))?;
    let lut = MantissaLut::generate(model.as_ref());
    lut.validate()
        .map_err(|e| anyhow::anyhow!("generated {name} LUT failed validation: {e}"))?;
    let out = args.opt_or("out", &format!("{name}.lut"));
    lut.save(Path::new(&out)).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "wrote {out}: m={} entries={} payload={} bytes",
        lut.m,
        lut.len(),
        lut.payload_bytes()
    );
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut engine = Engine::new(&dir)?;
    let cfg = TrainConfig {
        model: args.opt_or("model", "lenet5"),
        mode: args.opt_or("mode", "lut"),
        mult: args.opt_or("mult", "afm16"),
        epochs: args.opt_usize("epochs", 3),
        lr: args.opt_f32("lr", 0.05),
        seed: args.opt_u64("seed", 42),
        eval_every: args.opt_usize("eval-every", 1),
    };
    let samples = args.opt_usize("samples", 512);
    let ds = experiments::dataset_for(experiments::dataset_of(&cfg.model), samples, cfg.seed);
    let (train_ds, test_ds) = ds.split(samples / 4);
    println!(
        "training {} mode={} mult={} epochs={} on {} ({} train / {} test)",
        cfg.model, cfg.mode, cfg.mult, cfg.epochs, train_ds.name, train_ds.n, test_ds.n
    );
    let mut tr = Trainer::new(&mut engine, cfg, &dir)?;
    let log = tr.fit(&train_ds, &test_ds)?;
    for e in &log.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  train acc {:.2}%  test acc {:.2}%  ({:.1}s)",
            e.epoch,
            e.train_loss,
            e.train_acc * 100.0,
            e.test_acc * 100.0,
            e.seconds
        );
    }
    if let Some(path) = args.opt("ckpt") {
        tr.checkpoint()?.save(Path::new(path))?;
        println!("checkpoint -> {path}");
    }
    Ok(())
}

fn train_dp(args: &Args) -> Result<()> {
    use approxtrain::coordinator::backend::MulSpec;
    use approxtrain::coordinator::data_parallel::{DpConfig, DpTrainer};

    let model = args.opt_or("model", "lenet300");
    let mode = args.opt_or("mode", "native");
    let workers = args.opt_usize("workers", 2).max(1);
    let shard = args.opt_usize("shard", 8).max(1);
    let accum = args.opt_usize("accum", 1).max(1);
    let epochs = args.opt_usize("epochs", 2);
    let batch = args.opt_usize("batch", 32);
    let lr = args.opt_f32("lr", 0.05);
    let seed = args.opt_u64("seed", 42);
    let samples = args.opt_usize("samples", 512);

    let ds = experiments::dataset_for(experiments::dataset_of(&model), samples, seed);
    let (train_ds, test_ds) = ds.split(samples / 4);
    let cfg = DpConfig { workers, shard, lr };
    let mut tr = DpTrainer::new(&model, MulSpec::parse(&mode)?, cfg, seed)?;
    println!(
        "training {} | {workers} workers x shard {shard} | accum {accum} | \
         epochs {epochs} batch {batch} on {} ({} train / {} test)",
        tr.describe(),
        train_ds.name,
        train_ds.n,
        test_ds.n
    );
    let stats = tr.fit(&train_ds, epochs, batch, accum, seed)?;
    let per_epoch = stats.len().div_ceil(epochs.max(1)).max(1);
    for (e, chunk) in stats.chunks(per_epoch).enumerate() {
        let last = chunk.last().expect("fit emits at least one step per epoch");
        println!(
            "epoch {:>3}  loss {:.4}  train acc {:.2}%  ({} optimizer steps)",
            e + 1,
            last.loss,
            last.acc * 100.0,
            chunk.len()
        );
    }
    let acc = tr.evaluate(&test_ds, batch)?;
    println!("test accuracy: {:.2}%", acc * 100.0);
    if let Some(dir) = args.opt("ckpt-dir") {
        let shards = args.opt_usize("ckpt-shards", workers).max(1);
        tr.save_sharded(Path::new(dir), shards)?;
        println!("sharded checkpoint ({shards} shards) -> {dir}/dp-shard-*.ckpt");
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut engine = Engine::new(&dir)?;
    let cfg = TrainConfig {
        model: args.opt_or("model", "lenet5"),
        mode: args.opt_or("mode", "lut"),
        mult: args.opt_or("mult", "afm16"),
        epochs: 0,
        lr: 0.0,
        seed: args.opt_u64("seed", 42),
        eval_every: 1,
    };
    let samples = args.opt_usize("samples", 256);
    let ds = experiments::dataset_for(experiments::dataset_of(&cfg.model), samples, cfg.seed);
    let mut tr = Trainer::new(&mut engine, cfg, &dir)?;
    if let Some(path) = args.opt("ckpt") {
        tr.load_checkpoint(&approxtrain::nn::checkpoint::Checkpoint::load(Path::new(path))?)?;
    }
    let acc = tr.evaluate(&ds)?;
    println!("test accuracy (untrained unless --ckpt given): {:.2}%", acc * 100.0);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    use approxtrain::coordinator::backend::{CpuBackend, EngineBackend, InferBackend, MulSpec};
    use approxtrain::coordinator::server::{serve_on_caller, serve_pool, ServeConfig};
    use approxtrain::nn::init::init_params;
    use approxtrain::util::json::Json;
    use approxtrain::util::rng::Pcg32;
    use std::time::Duration;

    let model = args.opt_or("model", "lenet300");
    let requests = args.opt_usize("requests", 64);
    let clients = args.opt_usize("clients", 4).max(1);
    let lanes = args.opt_usize("lanes", 2).max(1);
    let queue_depth = args.opt_usize("queue-depth", 64);
    if queue_depth == 0 {
        bail!("--queue-depth must be >= 1");
    }
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(args.opt_u64("batch-wait-ms", 5)),
        queue_depth,
    };

    // build the backend(s) first; the request images are sized to them
    enum Built {
        Cpu(Vec<CpuBackend>),
        Engine(Box<EngineBackend>),
    }
    let backend_kind = args.opt_or("backend", "cpu");
    let built = match backend_kind.as_str() {
        "cpu" => {
            // pure-Rust executor backend: runnable with no artifacts, one
            // bit-identical model replica per lane. --mult composes with a
            // bare --mode lut|direct (the train/infer flag convention);
            // a fully-qualified --mode that contradicts --mult is an error
            let m = args.opt_or("mode", "lut");
            let mode = match (m.as_str(), args.opt("mult")) {
                ("lut", Some(mult)) => format!("lut:{mult}"),
                ("direct", Some(mult)) => format!("direct:{mult}"),
                ("native", _) | (_, None) => m.clone(),
                (other, Some(mult)) => {
                    if !other.ends_with(&format!(":{mult}")) {
                        bail!("--mode {other} contradicts --mult {mult}");
                    }
                    other.to_string()
                }
            };
            let batch = args.opt_usize("batch", 16);
            let seed = args.opt_u64("seed", 42);
            let base = CpuBackend::for_model(&model, MulSpec::parse(&mode)?, batch, seed)?;
            println!(
                "serving {} | {lanes} lanes x batch {batch} | queue depth {} | {clients} clients",
                base.describe(),
                cfg.queue_depth
            );
            Built::Cpu(base.replicas(lanes))
        }
        "engine" => {
            // compiled-artifact path: PJRT is thread-pinned, so this
            // serves single-lane from the current thread
            if lanes > 1 {
                println!("note: the engine backend is not Send; serving 1 lane (not {lanes})");
            }
            // --mode here selects the *artifact* mode (tf | custom | lut
            // | direct:<mult>), matching train/infer
            let mode = args.opt_or("mode", "lut");
            let dir = artifacts_dir(args);
            let engine = Engine::new(&dir)?;
            let art = engine
                .manifest()
                .find(&model, "fwd", &mode)
                .with_context(|| format!("no {mode} fwd artifact for {model}"))?
                .clone();
            let raw = Json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)?;
            let params = init_params(&art, 42, &raw)?;
            // LUT payload only when the artifact takes one
            let lut = if art.input_indices(approxtrain::runtime::artifact::Role::Lut).is_empty()
            {
                None
            } else {
                let mult = args.opt_or("mult", "afm16");
                let lut = MantissaLut::load(&dir.join(format!("luts/{mult}.lut")))
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                lut.validate()
                    .map_err(|e| anyhow::anyhow!("loaded {mult} LUT failed validation: {e}"))?;
                Some(lut.entries)
            };
            let backend = EngineBackend::new(engine, &art.name, params, lut)?;
            println!(
                "serving {} | 1 lane (caller thread) x batch {} | queue depth {} | \
                 {clients} clients",
                backend.describe(),
                backend.batch(),
                cfg.queue_depth
            );
            Built::Engine(Box::new(backend))
        }
        other => bail!("unknown backend {other:?} (want cpu | engine)"),
    };
    let (batch, image_elems) = match &built {
        Built::Cpu(v) => (v[0].batch(), v[0].image_elems()),
        Built::Engine(b) => (b.batch(), b.image_elems()),
    };

    // request stream: dataset images when the shapes line up (lenet
    // models), otherwise deterministic synthetic rows of the right size
    // (the scaled-down CPU resnets take 16x16x3, not the dataset's
    // shape). Probe with a 1-sample dataset before building the real one
    // so a mismatch never pays for `requests` images it then discards.
    let probe = experiments::dataset_for(experiments::dataset_of(&model), 1, 7);
    let images: Vec<Vec<f32>> = if probe.image_len() == image_elems {
        let ds = experiments::dataset_for(experiments::dataset_of(&model), requests, 7);
        (0..requests).map(|i| ds.image(i).to_vec()).collect()
    } else {
        let mut rng = Pcg32::seeded(7);
        (0..requests).map(|_| (0..image_elems).map(|_| rng.uniform()).collect()).collect()
    };

    // closed-loop load shared by both backends; returns the reject count
    let drive = |client: approxtrain::coordinator::server::Client| -> usize {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rejected = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..clients {
                let client = client.clone();
                let images = &images;
                let rejected = &rejected;
                s.spawn(move || {
                    for i in (t..requests).step_by(clients) {
                        if client.infer(images[i].clone()).is_err() {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        rejected.load(Ordering::Relaxed)
    };

    let stats = match built {
        Built::Cpu(mut backends) => serve_pool(&mut backends, cfg, drive)?.0,
        Built::Engine(mut backend) => serve_on_caller(backend.as_mut(), cfg, drive)?.0,
    };
    println!(
        "served {} requests in {} batches ({} rejected, {:.1}%) | p50 {:.1} ms p99 {:.1} ms | \
         mean fill {:.1}/{batch}",
        stats.requests,
        stats.batches,
        stats.rejected,
        stats.reject_rate() * 100.0,
        stats.latency_percentile_s(50.0) * 1e3,
        stats.latency_percentile_s(99.0) * 1e3,
        stats.mean_fill(),
    );
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = args.has_flag("quick");
    let dir = artifacts_dir(args);
    let results = results_dir(args);
    let mut out = String::new();
    if which == "fig1" || which == "all" {
        out.push_str(&experiments::fig1(&results)?);
    }
    if which != "fig1" {
        let mut engine = Engine::new(&dir)?;
        match which {
            "fig6" => out.push_str(&experiments::fig6(
                &mut engine,
                &results,
                args.opt_usize("size", 256),
                quick,
            )?),
            "fig10" | "table3" => {
                out.push_str(&experiments::fig10_table3(&mut engine, &dir, &results, quick)?)
            }
            "table4" => out.push_str(&experiments::table4(&mut engine, &dir, &results, quick)?),
            "table5" => {
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, true, quick)?)
            }
            "table6" => {
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, false, quick)?)
            }
            "fig11" => out.push_str(&experiments::fig11(&mut engine, &dir, &results, quick)?),
            "fig12" => out.push_str(&experiments::fig12(&mut engine, &results, quick)?),
            "all" => {
                out.push_str(&experiments::fig6(&mut engine, &results, 256, quick)?);
                out.push_str(&experiments::fig10_table3(&mut engine, &dir, &results, quick)?);
                out.push_str(&experiments::table4(&mut engine, &dir, &results, quick)?);
                out.push_str(&experiments::fig11(&mut engine, &dir, &results, quick)?);
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, true, quick)?);
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, false, quick)?);
                out.push_str(&experiments::fig12(&mut engine, &results, quick)?);
            }
            other => bail!("unknown experiment {other:?}"),
        }
    }
    println!("{out}");
    approxtrain::coordinator::report::write_result(&results, "report.md", &out)?;
    Ok(())
}

fn list_artifacts(args: &Args) -> Result<()> {
    let engine = Engine::new(&artifacts_dir(args))?;
    for art in engine.manifest().artifacts.values() {
        println!(
            "{:<28} model={:<10} phase={:<6} mode={:<13} inputs={} outputs={}",
            art.name,
            art.model,
            art.phase,
            art.mode,
            art.inputs.len(),
            art.outputs.len()
        );
    }
    Ok(())
}
