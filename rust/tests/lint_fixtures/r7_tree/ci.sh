#!/usr/bin/env bash
# Planted R7 fixture: the first pin names a suite that is not
# registered; the second is registered and must not be reported.
cargo test --release --test stale_pin
cargo test --release --test ghost # registered: no finding
