//! Report emitters: markdown tables + CSV files under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple markdown table builder.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        writeln!(out, "### {}\n", self.title).unwrap();
        writeln!(out, "| {} |", self.header.join(" | ")).unwrap();
        writeln!(out, "|{}|", vec!["---"; self.header.len()].join("|")).unwrap();
        for r in &self.rows {
            writeln!(out, "| {} |", r.join(" | ")).unwrap();
        }
        out.push('\n');
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write a string to `results/<name>` (creating the directory).
pub fn write_result(results_dir: &Path, name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all(results_dir)?;
    std::fs::write(results_dir.join(name), content)?;
    Ok(())
}

/// Format a seconds value the way the paper's tables do.
pub fn fmt_time(s: f64) -> String {
    crate::util::fmt_duration(s)
}

/// Format a ratio like "4.2x".
pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.1}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
