//! CPU compute kernels mirroring the paper's custom CUDA kernels (§VI-D):
//! GEMM, three IM2COL variants, transpose-and-reverse, matrix-vector and
//! pooling. Every multiplication goes through a [`MulKernel`], which selects
//! between the native hardware `*`, a direct functional-model call (the
//! paper's "direct C simulation"), or the LUT-based AMSim — the three
//! simulation strategies compared in Fig 6 and Tables V/VI.
//!
//! The kernels are written against the **batched** [`MulBackend`] panel
//! operations rather than per-element [`MulKernel::mul`] calls: strategy
//! dispatch happens once per contiguous panel, so the AMSim path is a
//! tight LUT-gather loop with its shift/mask hoisted into registers
//! (AdaPT, arXiv 2203.04071, makes the same observation: per-multiply
//! dispatch must be amortized by vectorized LUT lookups for emulation to
//! be usable at training scale) and the native path is a plain FMA loop
//! the compiler can treat as the cuBLAS stand-in baseline. Each panel op
//! is bit-identical to its scalar per-element reference; see
//! `tests/batched_vs_scalar.rs`.
//!
//! The GEMM hot path on top of the panel ops is the hierarchical
//! cache-blocked kernel in [`gemm`] (`gemm_tiled`): `A` row-panels and
//! `B` column-panels are packed into the reusable per-thread buffers of
//! [`with_pack_buffers`] so LUT gathers stream over contiguous memory,
//! and the output is partitioned into 2D tiles scheduled over the
//! persistent worker pool. Packing is generalized over
//! [`gemm::PackA`]/[`gemm::PackB`] panel sources: the conv layer's
//! *implicit GEMM* packs its panels straight from the NHWC tensors via
//! the fused im2col index computations ([`im2col`]), so no cols matrix
//! is ever materialized. Each tile is drained by the register-blocked
//! `MR x NR` *micro-kernel* ([`MulBackend::mul_microtile`], the BLIS
//! register-blocking idea): per contraction step the `MR` `A` operands
//! and `NR` `B` operands are decomposed once and feed `MR x NR`
//! **independent** FP32 accumulators, so the ~4-cycle FP-add latency is
//! hidden behind many chains and per-MAC decomposition cost drops by
//! ~`MR*NR / (MR+NR)`. Accumulation follows one crate-wide contract — a
//! single running FP32 accumulator per output element, products added in
//! ascending contraction order — so every blocking/threading/micro-tile
//! choice is bit-identical to the per-element scalar oracle
//! ([`gemm::gemm_scalar_reference`]).
//!
//! On x86-64 the micro-kernel additionally carries runtime-detected
//! AVX2 arms ([`crate::amsim::simd`] for the LUT gather path, [`simd`]
//! for the native baseline), with lanes running **across** the
//! independent accumulator chains so the contract — and therefore the
//! bit-identity gate — survives vectorization. The tier is a
//! [`SimdLevel`] (detection + `APPROXTRAIN_SIMD` override, see
//! [`crate::util::simd`]); `tests/simd_lanes.rs` is the forced-level ×
//! multiplier × residue differential net.
//!
//! Sparsity is a first-class property of a packed panel: the pack stage
//! emits a per-micro-panel [`Occupancy`] bitmap next to the packed floats
//! ([`gemm::PackA::pack_a_occ`] / [`gemm::PackB::pack_b_occ`]), and the
//! tile drain skips dead (all-zero) `A`-row-group × `B`-strip pairs for
//! multipliers that pass the audited zero-identity gate
//! ([`MulKernel::zero_skip_ok`]); all other strategies take the dense
//! path unchanged. Skipping preserves the accumulation contract bit for
//! bit — see the safety argument on [`gemm::PackA::pack_a_occ`] and the
//! [`panel_skip_events`] observability counters; `tests/sparse_gemm.rs`
//! is the differential net.
pub mod gemm;
pub mod im2col;
pub mod matvec;
pub mod pool;
#[cfg(target_arch = "x86_64")]
mod simd;
pub mod transpose_reverse;

use std::cell::Cell;

use crate::amsim::{assert_microtile_shape, AmSim};
use crate::mult::ApproxMul;

/// Register-block ceilings of [`MulBackend::mul_microtile`], defined in
/// [`crate::amsim`] (they bound the stack arrays of its hoisted operand
/// decompositions) and re-exported here next to the trait that carries
/// the contract.
pub use crate::amsim::{MR_MAX, NR_MAX};

/// The runtime SIMD tier (detection + `APPROXTRAIN_SIMD` override),
/// re-exported next to the kernels that dispatch on it.
pub use crate::util::simd::SimdLevel;

/// Multiplication strategy threaded through every kernel.
pub enum MulKernel<'a> {
    /// Native hardware multiplier (`*` operator) — the ATnG
    /// configuration. Panel ops dispatch at the process-wide active
    /// [`SimdLevel`] ([`crate::util::simd::active`]).
    Native,
    /// Native multiplier pinned to a specific [`SimdLevel`] (clamped to
    /// the machine at dispatch) — the forced-level hook for the
    /// differential suites and the per-level bench rows. Bit-identical
    /// to [`MulKernel::Native`] at every level by the vector-arm
    /// contract; the LUT counterpart is [`AmSim::with_simd`].
    NativeAt(SimdLevel),
    /// Direct call into the multiplier functional model (bit manipulation
    /// per multiply) — the ATxC configuration / Fig 6 "C simulation".
    /// Scalar at every SIMD level: the per-multiply virtual call cannot
    /// be vectorized.
    Direct(&'a dyn ApproxMul),
    /// LUT-based AMSim — the ATxG configuration. Carries its own
    /// [`SimdLevel`] (see [`AmSim::simd_level`]).
    Lut(AmSim<'a>),
}

impl<'a> MulKernel<'a> {
    /// Scalar multiply — the *reference semantics* every batched panel op
    /// must reproduce bit-for-bit. Kernel inner loops should use
    /// [`MulBackend`] instead; this stays for scalar call sites, oracles
    /// and tests.
    #[inline(always)]
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        match self {
            MulKernel::Native | MulKernel::NativeAt(_) => a * b,
            MulKernel::Direct(m) => m.mul(a, b),
            MulKernel::Lut(sim) => sim.mul(a, b),
        }
    }

    pub fn describe(&self) -> String {
        match self {
            MulKernel::Native => "native".into(),
            MulKernel::NativeAt(l) => format!("native@{}", l.name()),
            MulKernel::Direct(m) => format!("direct:{}", m.name()),
            MulKernel::Lut(sim) => format!("lut:m{}", sim.mantissa_bits()),
        }
    }

    /// Whether the tiled-GEMM drain may elide dead (all-zero) micro-panel
    /// pairs under this strategy — the per-multiplier zero-identity gate
    /// of the sparse packed GEMM (see [`gemm::PackA::pack_a_occ`] for the
    /// full safety argument).
    ///
    /// - [`MulKernel::Native`] / [`MulKernel::NativeAt`]: **no**. Hardware
    ///   `*` yields NaN for `0 × inf` and `0 × NaN`, so an elided product
    ///   is not provably zero — the dense path is the only correct one.
    /// - [`MulKernel::Direct`]: defers to the model's declared
    ///   [`ApproxMul::zero_identity`] capability, which
    ///   `tests/golden_mults.rs` audits against brute force so the flag
    ///   can never drift from the functional model.
    /// - [`MulKernel::Lut`]: **yes**, structurally — AMSim's Algorithm 2
    ///   returns zero whenever either operand's exponent field is zero,
    ///   *before* any special-case handling ([`AmSim::mul_bits`]), so
    ///   `mul(±0, x) == 0` for every `x` by construction.
    #[inline]
    pub fn zero_skip_ok(&self) -> bool {
        match self {
            MulKernel::Native | MulKernel::NativeAt(_) => false,
            MulKernel::Direct(m) => m.zero_identity(),
            MulKernel::Lut(_) => true,
        }
    }

    /// The SIMD tier the **native** panel arms run at for this kernel:
    /// the active process-wide level for [`MulKernel::Native`], the
    /// machine-clamped pinned level for [`MulKernel::NativeAt`], and
    /// `Scalar` for the non-native strategies (the LUT arm carries its
    /// level inside [`AmSim`]).
    #[inline]
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    fn native_level(&self) -> SimdLevel {
        match self {
            MulKernel::Native => crate::util::simd::active(),
            MulKernel::NativeAt(l) => l.clamp_to_machine(),
            MulKernel::Direct(_) | MulKernel::Lut(_) => SimdLevel::Scalar,
        }
    }
}

/// Batched panel operations over contiguous slices — the interface the
/// GEMM / matvec / im2col-fed convolution inner loops are written against.
///
/// Contract: each operation is **bit-identical** to the corresponding
/// per-element scalar sequence using [`MulKernel::mul`] with strictly
/// sequential FP32 accumulation (`tests/batched_vs_scalar.rs` enforces
/// this for all three strategies). What batching buys is *dispatch
/// amortization*: the strategy `match` runs once per panel, not once per
/// multiply.
///
/// [`MulBackend::dot_panel_acc`] is the blocking-independence primitive:
/// it *continues* an accumulation from a caller-supplied running value,
/// so a dot split across cache blocks of any size reproduces the exact
/// add sequence of an unsplit dot. That is what lets the tiled GEMM claim
/// bit-identity to the scalar oracle at every tile size.
pub trait MulBackend {
    /// `out[i] = mul(a[i], b[i])` over a contiguous panel.
    fn mul_panel(&self, a: &[f32], b: &[f32], out: &mut [f32]);

    /// Continue a sequential FP32 accumulation: returns
    /// `init + mul(a[0], b[0]) + mul(a[1], b[1]) + …` with the adds
    /// applied strictly in ascending index order.
    fn dot_panel_acc(&self, init: f32, a: &[f32], b: &[f32]) -> f32;

    /// `sum_i mul(a[i], b[i])` with sequential FP32 accumulation
    /// starting from `0.0`.
    fn dot_panel(&self, a: &[f32], b: &[f32]) -> f32 {
        self.dot_panel_acc(0.0, a, b)
    }

    /// `acc[j] += mul(x, row[j])` — the rank-1-update inner loop, with the
    /// broadcast operand's decomposition hoisted out of the loop.
    fn fma_row(&self, acc: &mut [f32], x: f32, row: &[f32]);

    /// Register-blocked `mr x nr` micro-tile FMA — the tiled-GEMM drain
    /// primitive (BLIS-style register blocking at the multiplier-simulation
    /// level):
    ///
    /// ```text
    /// for kk in 0..k_len:                       # ascending contraction order
    ///     for r in 0..mr, c in 0..nr:
    ///         acc[r*nr + c] += mul(a[r*k_len + kk], b[kk*nr + c])
    /// ```
    ///
    /// `a` holds `mr` operand rows of `k_len` elements each (row-major, the
    /// tiled `A` panel layout); `b` holds the `k_len x nr` strip
    /// *interleaved k-major* (`b[kk*nr + c]`, the [`gemm::PackB`] strip
    /// layout), so each contraction step reads `nr` contiguous `B`
    /// operands. The `mr*nr` accumulators are **independent chains** —
    /// FP-add latency is hidden behind them — while each individual
    /// accumulator still receives its products strictly in ascending `kk`
    /// order, so the result is bit-identical to the per-element scalar
    /// sequence (the crate-wide accumulation contract). Specialized
    /// implementations additionally decompose each operand **once per
    /// step** instead of once per product, cutting per-MAC decomposition
    /// cost by ~`mr*nr / (mr + nr)`.
    ///
    /// `mr <= MR_MAX`, `nr <= NR_MAX`. The default implementation lowers
    /// to one [`MulBackend::fma_row`] per `(kk, r)` — bit-identical by
    /// `fma_row`'s own contract — so implementors only override it for
    /// speed, never for semantics.
    fn mul_microtile(
        &self,
        acc: &mut [f32],
        a: &[f32],
        b: &[f32],
        mr: usize,
        nr: usize,
        k_len: usize,
    ) {
        assert_microtile_shape(acc, a, b, mr, nr, k_len);
        for kk in 0..k_len {
            let b_step = &b[kk * nr..(kk + 1) * nr];
            for r in 0..mr {
                self.fma_row(&mut acc[r * nr..(r + 1) * nr], a[r * k_len + kk], b_step);
            }
        }
    }
}

impl MulBackend for MulKernel<'_> {
    fn mul_panel(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
        assert!(a.len() == b.len() && a.len() == out.len());
        match self {
            // elementwise `*` is the same single op at every SIMD level
            // and the compiler auto-vectorizes it; no hand-written arm
            MulKernel::Native | MulKernel::NativeAt(_) => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = x * y;
                }
            }
            MulKernel::Direct(m) => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = m.mul(x, y);
                }
            }
            MulKernel::Lut(sim) => sim.mul_slice(a, b, out),
        }
    }

    fn dot_panel_acc(&self, init: f32, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        match self {
            // native: plain sequential FMA loop — the baseline every
            // slowdown ratio is measured against. One accumulator chain,
            // so there is nothing contract-legal to vectorize here (the
            // products are single-op; only the LUT arm's gather/decompose
            // work is worth lifting into lanes for a dot) — identical at
            // every SIMD level by construction.
            MulKernel::Native | MulKernel::NativeAt(_) => {
                let mut acc = init;
                for i in 0..a.len() {
                    acc += a[i] * b[i];
                }
                acc
            }
            // direct: the virtual call per multiply is inherent to the
            // black-box model (the paper's "direct C simulation" cost);
            // unroll 4-wide so the calls pipeline, keep the adds ordered
            MulKernel::Direct(m) => {
                let n = a.len();
                let mut acc = init;
                let mut i = 0;
                while i + 4 <= n {
                    let p0 = m.mul(a[i], b[i]);
                    let p1 = m.mul(a[i + 1], b[i + 1]);
                    let p2 = m.mul(a[i + 2], b[i + 2]);
                    let p3 = m.mul(a[i + 3], b[i + 3]);
                    acc += p0;
                    acc += p1;
                    acc += p2;
                    acc += p3;
                    i += 4;
                }
                while i < n {
                    acc += m.mul(a[i], b[i]);
                    i += 1;
                }
                acc
            }
            MulKernel::Lut(sim) => sim.dot_acc(init, a, b),
        }
    }

    fn fma_row(&self, acc: &mut [f32], x: f32, row: &[f32]) {
        assert_eq!(acc.len(), row.len());
        match self {
            MulKernel::Native | MulKernel::NativeAt(_) => {
                #[cfg(target_arch = "x86_64")]
                {
                    let level = self.native_level();
                    if level >= SimdLevel::Avx2 {
                        // SAFETY: native_level() is clamped to the
                        // machine, so the target features are present
                        unsafe {
                            if level >= SimdLevel::Avx2Fma {
                                simd::native_fma_row_avx2fma(acc, x, row);
                            } else {
                                simd::native_fma_row_avx2(acc, x, row);
                            }
                        }
                        return;
                    }
                }
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += x * r;
                }
            }
            MulKernel::Direct(m) => {
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += m.mul(x, r);
                }
            }
            MulKernel::Lut(sim) => sim.fma_row(acc, x, row),
        }
    }

    fn mul_microtile(
        &self,
        acc: &mut [f32],
        a: &[f32],
        b: &[f32],
        mr: usize,
        nr: usize,
        k_len: usize,
    ) {
        match self {
            // native: mr*nr independent FMA chains per step — the adds on
            // any one accumulator stay in ascending kk order, so this is
            // the same op sequence as the scalar loop, just latency-hidden;
            // at Avx2+ the chains are drained 8 columns per vector op
            MulKernel::Native | MulKernel::NativeAt(_) => {
                assert_microtile_shape(acc, a, b, mr, nr, k_len);
                #[cfg(target_arch = "x86_64")]
                {
                    let level = self.native_level();
                    if level >= SimdLevel::Avx2 {
                        // SAFETY: native_level() is clamped to the
                        // machine, so the target features are present
                        unsafe {
                            if level >= SimdLevel::Avx2Fma {
                                simd::native_microtile_avx2fma(acc, a, b, mr, nr, k_len);
                            } else {
                                simd::native_microtile_avx2(acc, a, b, mr, nr, k_len);
                            }
                        }
                        return;
                    }
                }
                for kk in 0..k_len {
                    let b_step = &b[kk * nr..(kk + 1) * nr];
                    for r in 0..mr {
                        let x = a[r * k_len + kk];
                        for (av, &bv) in acc[r * nr..(r + 1) * nr].iter_mut().zip(b_step) {
                            *av += x * bv;
                        }
                    }
                }
            }
            // direct: the virtual call per multiply is inherent to the
            // black-box model; the win here is purely the independent
            // accumulator chains between the calls
            MulKernel::Direct(m) => {
                assert_microtile_shape(acc, a, b, mr, nr, k_len);
                for kk in 0..k_len {
                    let b_step = &b[kk * nr..(kk + 1) * nr];
                    for r in 0..mr {
                        let x = a[r * k_len + kk];
                        for (av, &bv) in acc[r * nr..(r + 1) * nr].iter_mut().zip(b_step) {
                            *av += m.mul(x, bv);
                        }
                    }
                }
            }
            // the LUT path validates inside AmSim::mul_microtile — no
            // double-check on the hot path
            MulKernel::Lut(sim) => sim.mul_microtile(acc, a, b, mr, nr, k_len),
        }
    }
}

/// Per-micro-panel occupancy bitmap emitted by the packing stage next to
/// the packed floats ([`gemm::PackA::pack_a_occ`] /
/// [`gemm::PackB::pack_b_occ`]): one bit per micro-panel — an `mr`-row
/// group of a packed `A` panel, an `nr`-column strip of a packed `B`
/// panel. A **set** bit means the panel is *live* (holds at least one
/// element with nonzero bits-ignored value, i.e. `v != 0.0`; NaN and
/// subnormals count as live). A **clear** bit means every element is
/// `±0.0` ("dead") and the tile drain may elide the whole micro-panel's
/// products when the multiplier passes [`MulKernel::zero_skip_ok`].
#[derive(Default)]
pub struct Occupancy {
    words: Vec<u64>,
    panels: usize,
}

impl Occupancy {
    /// Re-size for `panels` micro-panels and clear every bit to *dead*.
    /// The backing words are recycled across calls (growth feeds the
    /// [`buffer_growth_events`] counter like the pack buffers do), so
    /// steady-state packing stays allocation-free.
    pub fn reset(&mut self, panels: usize) {
        let words = panels.div_ceil(64);
        if self.words.len() < words {
            self.words.resize(words, 0);
            note_buffer_growth();
        }
        for w in &mut self.words[..words] {
            *w = 0;
        }
        self.panels = panels;
    }

    /// Mark micro-panel `i` live.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.panels);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Is micro-panel `i` live?
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.panels);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Number of micro-panels covered by the last [`Occupancy::reset`].
    pub fn panels(&self) -> usize {
        self.panels
    }

    /// Number of live micro-panels.
    pub fn live(&self) -> usize {
        self.words[..self.panels.div_ceil(64)].iter().map(|w| w.count_ones() as usize).sum()
    }
}

/// Global (a-row-group × b-strip) pair counters of the tiled drain —
/// the observability hooks of the sparse GEMM. `PANEL_PAIRS` counts every
/// pair the drain *considered*; `PANEL_SKIPS` counts the subset it elided
/// as dead under the zero-identity gate. Relaxed atomics, flushed once
/// per tile, so the hot loop only touches thread-locals.
static PANEL_PAIRS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
static PANEL_SKIPS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Process-wide count of (a-row-group × b-strip) micro-panel pairs the
/// tiled GEMM drain has considered. Monotonic; meaningful as a delta
/// around a region (note: other threads running GEMMs concurrently also
/// advance it).
pub fn panel_pair_events() -> u64 {
    PANEL_PAIRS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Process-wide count of micro-panel pairs the tiled GEMM drain *skipped*
/// because both occupancy tests said dead and the multiplier passed
/// [`MulKernel::zero_skip_ok`]. Always 0 for dense-fallback strategies.
pub fn panel_skip_events() -> u64 {
    PANEL_SKIPS.load(std::sync::atomic::Ordering::Relaxed)
}

/// One flush of a tile's locally-accumulated drain counters.
pub(crate) fn note_panel_drain(pairs: u64, skips: u64) {
    if pairs != 0 {
        PANEL_PAIRS.fetch_add(pairs, std::sync::atomic::Ordering::Relaxed);
    }
    if skips != 0 {
        PANEL_SKIPS.fetch_add(skips, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Reusable per-thread packing buffers for the tiled GEMM: one `A`
/// row-panel (`MC x KC`) and one `B` column-panel (`KC x NC`), plus their
/// per-micro-panel [`Occupancy`] bitmaps.
#[derive(Default)]
struct PackBuffers {
    a: Vec<f32>,
    b: Vec<f32>,
    a_occ: Occupancy,
    b_occ: Occupancy,
}

thread_local! {
    /// Every pool worker (and the submitting thread) keeps its own pack
    /// buffers, so tile packing never allocates on the steady-state hot
    /// path. Stored as a takeable `Cell` rather than a `RefCell`: a
    /// re-entrant kernel call on the same thread (a layer running inside
    /// another parallel region) just sees an empty slot and pays one
    /// allocation instead of panicking on a double borrow.
    static PACK_BUFFERS: Cell<Option<Box<PackBuffers>>> = const { Cell::new(None) };
}

thread_local! {
    /// Count of recycled-buffer *growth* events on this thread (pack
    /// buffers or scratch needing a larger allocation). Steady-state hot
    /// paths — e.g. a second conv forward at the same geometry — must not
    /// advance it; `tests/conv_grads.rs` smoke-checks exactly that.
    static BUFFER_GROWTHS: Cell<usize> = const { Cell::new(0) };
}

/// This thread's recycled-buffer growth count (see [`with_pack_buffers`] /
/// [`with_scratch`]). Only meaningful as a *delta* around a single-lane
/// region on the calling thread; pool workers keep their own counters.
pub fn buffer_growth_events() -> usize {
    BUFFER_GROWTHS.with(|c| c.get())
}

fn note_buffer_growth() {
    BUFFER_GROWTHS.with(|c| c.set(c.get() + 1));
}

/// Run `f` with this thread's packing buffers grown to at least
/// (`a_len`, `b_len`) elements. The buffers are recycled across calls on
/// the same thread; contents are unspecified on entry (callers pack
/// before they read).
pub fn with_pack_buffers<R>(
    a_len: usize,
    b_len: usize,
    f: impl FnOnce(&mut [f32], &mut [f32]) -> R,
) -> R {
    let mut bufs = PACK_BUFFERS.with(|c| c.take()).unwrap_or_default();
    if bufs.a.len() < a_len {
        bufs.a.resize(a_len, 0.0);
        note_buffer_growth();
    }
    if bufs.b.len() < b_len {
        bufs.b.resize(b_len, 0.0);
        note_buffer_growth();
    }
    let r = f(&mut bufs.a[..a_len], &mut bufs.b[..b_len]);
    PACK_BUFFERS.with(|c| c.set(Some(bufs)));
    r
}

/// [`with_pack_buffers`] plus the two per-thread [`Occupancy`] bitmaps —
/// the entry point of the zero-skipping tiled drain. The bitmaps are
/// handed over un-reset (the packer calls [`Occupancy::reset`] per
/// panel); like the float buffers they are recycled across calls on the
/// same thread.
pub fn with_pack_buffers_occ<R>(
    a_len: usize,
    b_len: usize,
    f: impl FnOnce(&mut [f32], &mut [f32], &mut Occupancy, &mut Occupancy) -> R,
) -> R {
    let mut bufs = PACK_BUFFERS.with(|c| c.take()).unwrap_or_default();
    if bufs.a.len() < a_len {
        bufs.a.resize(a_len, 0.0);
        note_buffer_growth();
    }
    if bufs.b.len() < b_len {
        bufs.b.resize(b_len, 0.0);
        note_buffer_growth();
    }
    let PackBuffers { a, b, a_occ, b_occ } = &mut *bufs;
    let r = f(&mut a[..a_len], &mut b[..b_len], a_occ, b_occ);
    PACK_BUFFERS.with(|c| c.set(Some(bufs)));
    r
}

thread_local! {
    /// Per-thread scratch for operand transposes (dense-layer fallbacks).
    /// Separate from [`PACK_BUFFERS`]: the transpose is alive *across* a
    /// nested tiled-GEMM call, which takes the pack buffers itself.
    static SCRATCH: Cell<Option<Vec<f32>>> = const { Cell::new(None) };
}

/// Run `f` with this thread's scratch buffer grown to at least `len`
/// elements. Recycled across calls; contents unspecified on entry
/// (callers must fully overwrite what they read). Re-entrant calls fall
/// back to a fresh allocation, like [`with_pack_buffers`].
pub fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    let mut buf = SCRATCH.with(|c| c.take()).unwrap_or_default();
    if buf.len() < len {
        buf.resize(len, 0.0);
        note_buffer_growth();
    }
    let r = f(&mut buf[..len]);
    SCRATCH.with(|c| c.set(Some(buf)));
    r
}

/// Loop-blocking edge of [`transpose_into`]: an 8x8 f32 block is two
/// cache lines on each side, so both the row-strided reads and the
/// column-strided writes of a block stay resident while it is processed.
const TRANSPOSE_BLOCK: usize = 8;

/// `dst[c * rows + r] = src[r * cols + c]` — transpose a row-major
/// `rows x cols` matrix into `dst` (which becomes row-major
/// `cols x rows`). Shared by the dense-kernel fallbacks and (per spatial
/// cell) by [`transpose_reverse::transpose_reverse`]. Cache-blocked in
/// [`TRANSPOSE_BLOCK`]-square tiles: the naive loop pays a full
/// column-stride on every write, so each store touches a new cache line;
/// blocking confines the working set to `2 * 8` lines per tile.
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), rows * cols);
    for r0 in (0..rows).step_by(TRANSPOSE_BLOCK) {
        let r1 = (r0 + TRANSPOSE_BLOCK).min(rows);
        for c0 in (0..cols).step_by(TRANSPOSE_BLOCK) {
            let c1 = (c0 + TRANSPOSE_BLOCK).min(cols);
            for r in r0..r1 {
                for c in c0..c1 {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
}

/// Convolution geometry shared by the kernels: `same`-style explicit
/// padding, square stride.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dGeom {
    pub batch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub k_h: usize,
    pub k_w: usize,
    pub out_c: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k_h) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k_w) / self.stride + 1
    }
    /// rows of the forward im2col matrix
    pub fn col_rows(&self) -> usize {
        self.batch * self.out_h() * self.out_w()
    }
    /// columns of the forward im2col matrix (= GEMM contraction dim)
    pub fn col_cols(&self) -> usize {
        self.k_h * self.k_w * self.in_c
    }
    /// MACs of one forward pass (for roofline estimates)
    pub fn forward_macs(&self) -> usize {
        self.col_rows() * self.col_cols() * self.out_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::MantissaLut;
    use crate::mult::registry;

    #[test]
    fn geometry() {
        let g = Conv2dGeom {
            batch: 2,
            in_h: 28,
            in_w: 28,
            in_c: 1,
            k_h: 5,
            k_w: 5,
            out_c: 6,
            stride: 1,
            pad: 0,
        };
        assert_eq!((g.out_h(), g.out_w()), (24, 24));
        assert_eq!(g.col_rows(), 2 * 24 * 24);
        assert_eq!(g.col_cols(), 25);
        assert_eq!(g.forward_macs(), 2 * 24 * 24 * 25 * 6);
    }

    #[test]
    fn strided_geometry() {
        let g = Conv2dGeom {
            batch: 1,
            in_h: 8,
            in_w: 8,
            in_c: 3,
            k_h: 3,
            k_w: 3,
            out_c: 4,
            stride: 2,
            pad: 1,
        };
        assert_eq!((g.out_h(), g.out_w()), (4, 4));
    }

    #[test]
    fn pack_buffers_recycle_and_nest() {
        // first call sizes the buffers…
        with_pack_buffers(8, 4, |a, b| {
            assert_eq!((a.len(), b.len()), (8, 4));
            a[7] = 1.0;
            // …a nested call on the same thread gets an independent
            // (freshly allocated) pair instead of panicking
            with_pack_buffers(2, 2, |na, nb| {
                assert_eq!((na.len(), nb.len()), (2, 2));
                na[0] = 9.0;
            });
        });
        // the outer pair is recycled: a smaller request reuses it
        with_pack_buffers(4, 2, |a, b| {
            assert_eq!((a.len(), b.len()), (4, 2));
        });
    }

    #[test]
    fn buffer_growth_counter_quiet_at_steady_state() {
        // warm this test thread's buffers…
        with_pack_buffers(64, 32, |_, _| {});
        with_scratch(48, |_| {});
        let before = buffer_growth_events();
        // …then same-or-smaller requests must not grow anything
        with_pack_buffers(64, 32, |_, _| {});
        with_pack_buffers(16, 8, |_, _| {});
        with_scratch(48, |_| {});
        with_scratch(7, |_| {});
        assert_eq!(buffer_growth_events(), before);
        // a larger request is a growth event
        with_scratch(49, |_| {});
        assert_eq!(buffer_growth_events(), before + 1);
    }

    #[test]
    fn scratch_recycles_and_nests() {
        with_scratch(6, |s| {
            assert_eq!(s.len(), 6);
            s[5] = 3.0;
            with_scratch(2, |inner| {
                assert_eq!(inner.len(), 2);
                inner[0] = 1.0;
            });
        });
        with_scratch(3, |s| assert_eq!(s.len(), 3));
    }

    #[test]
    fn occupancy_bitmap_set_get_live_across_word_boundaries() {
        let mut occ = Occupancy::default();
        for panels in [1, 63, 64, 65, 130] {
            occ.reset(panels);
            assert_eq!(occ.panels(), panels);
            assert_eq!(occ.live(), 0, "reset must clear all {panels} bits");
            for i in (0..panels).step_by(3) {
                occ.set(i);
            }
            for i in 0..panels {
                assert_eq!(occ.get(i), i % 3 == 0, "panels={panels} bit {i}");
            }
            assert_eq!(occ.live(), panels.div_ceil(3));
        }
        // shrinking reuses the words and re-clears them
        occ.reset(2);
        assert_eq!((occ.panels(), occ.live()), (2, 0));
        assert!(!occ.get(0) && !occ.get(1));
    }

    #[test]
    fn zero_skip_gate_follows_strategy_and_declared_flag() {
        let afm = registry::by_name("afm16").unwrap();
        let fp32 = registry::by_name("fp32").unwrap();
        let lut = MantissaLut::generate(afm.as_ref());
        assert!(!MulKernel::Native.zero_skip_ok(), "hardware 0*inf is NaN");
        assert!(!MulKernel::NativeAt(SimdLevel::Scalar).zero_skip_ok());
        assert!(MulKernel::Direct(afm.as_ref()).zero_skip_ok());
        assert!(!MulKernel::Direct(fp32.as_ref()).zero_skip_ok(), "IEEE baseline");
        assert!(MulKernel::Lut(crate::amsim::AmSim::new(&lut)).zero_skip_ok());
    }

    #[test]
    fn dot_panel_acc_continues_sequential_accumulation() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let kernels = [
            MulKernel::Native,
            MulKernel::NativeAt(SimdLevel::Scalar),
            MulKernel::NativeAt(SimdLevel::detected()),
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(crate::amsim::AmSim::new(&lut)),
            MulKernel::Lut(crate::amsim::AmSim::with_simd(&lut, SimdLevel::Scalar)),
        ];
        let a: Vec<f32> = (0..13).map(|i| 0.37 * i as f32 - 1.9).collect();
        let b: Vec<f32> = (0..13).map(|i| -0.11 * i as f32 + 0.8).collect();
        for mul in &kernels {
            // splitting the dot at any point must reproduce the unsplit
            // add sequence bit for bit (the tiled-GEMM contract)
            let whole = mul.dot_panel(&a, &b);
            for split in 0..=a.len() {
                let head = mul.dot_panel_acc(0.0, &a[..split], &b[..split]);
                let got = mul.dot_panel_acc(head, &a[split..], &b[split..]);
                assert_eq!(
                    got.to_bits(),
                    whole.to_bits(),
                    "{} split={split}",
                    mul.describe()
                );
            }
        }
    }

    #[test]
    fn transpose_into_blocked_matches_definition() {
        // dimensions straddling the 8x8 blocking on both axes, plus
        // degenerate strips
        for (rows, cols) in [(1, 1), (3, 17), (8, 8), (9, 7), (16, 16), (19, 23)] {
            let src: Vec<f32> = (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0).collect();
            let mut dst = vec![0.0f32; rows * cols];
            transpose_into(&src, rows, cols, &mut dst);
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(
                        dst[c * rows + r].to_bits(),
                        src[r * cols + c].to_bits(),
                        "({rows},{cols}) at ({r},{c})"
                    );
                }
            }
        }
    }

    /// Scalar replay of the `mul_microtile` contract: every accumulator
    /// receives `mul(a[r][kk], b[kk][c])` in ascending `kk` order.
    fn microtile_scalar_ref(
        mul: &MulKernel,
        acc: &mut [f32],
        a: &[f32],
        b: &[f32],
        mr: usize,
        nr: usize,
        k_len: usize,
    ) {
        for kk in 0..k_len {
            for r in 0..mr {
                for c in 0..nr {
                    acc[r * nr + c] += mul.mul(a[r * k_len + kk], b[kk * nr + c]);
                }
            }
        }
    }

    /// A backend that only supplies the required panel ops, so
    /// `mul_microtile` resolves to the trait's default (fma_row-lowered)
    /// implementation — used to pin default == specialized == scalar.
    struct DefaultOnly<'a>(&'a MulKernel<'a>);
    impl MulBackend for DefaultOnly<'_> {
        fn mul_panel(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
            self.0.mul_panel(a, b, out)
        }
        fn dot_panel_acc(&self, init: f32, a: &[f32], b: &[f32]) -> f32 {
            self.0.dot_panel_acc(init, a, b)
        }
        fn fma_row(&self, acc: &mut [f32], x: f32, row: &[f32]) {
            self.0.fma_row(acc, x, row)
        }
    }

    #[test]
    fn mul_microtile_matches_scalar_and_default_impl_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let kernels = [
            MulKernel::Native,
            MulKernel::NativeAt(SimdLevel::Scalar),
            MulKernel::NativeAt(SimdLevel::detected()),
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(crate::amsim::AmSim::new(&lut)),
            MulKernel::Lut(crate::amsim::AmSim::with_simd(&lut, SimdLevel::Scalar)),
        ];
        let mut rng = crate::util::rng::Pcg32::seeded(4100);
        for (mr, nr, k_len) in
            [(1, 1, 5), (4, 8, 0), (4, 8, 13), (3, 5, 7), (MR_MAX, NR_MAX, 9)]
        {
            let mut a: Vec<f32> = (0..mr * k_len).map(|_| rng.range(-2.0, 2.0)).collect();
            let mut b: Vec<f32> = (0..k_len * nr).map(|_| rng.range(-2.0, 2.0)).collect();
            // exercise the zero-operand flush-add paths on both sides
            if !b.is_empty() {
                b[0] = 0.0;
            }
            if a.len() > 1 {
                a[1] = 0.0;
            }
            let init: Vec<f32> = (0..mr * nr).map(|_| rng.range(-1.0, 1.0)).collect();
            for mul in &kernels {
                let mut want = init.clone();
                microtile_scalar_ref(mul, &mut want, &a, &b, mr, nr, k_len);
                let mut got = init.clone();
                mul.mul_microtile(&mut got, &a, &b, mr, nr, k_len);
                let mut via_default = init.clone();
                DefaultOnly(mul).mul_microtile(&mut via_default, &a, &b, mr, nr, k_len);
                for i in 0..mr * nr {
                    assert_eq!(
                        got[i].to_bits(),
                        want[i].to_bits(),
                        "{} {mr}x{nr} k={k_len} idx {i} (specialized)",
                        mul.describe()
                    );
                    assert_eq!(
                        via_default[i].to_bits(),
                        want[i].to_bits(),
                        "{} {mr}x{nr} k={k_len} idx {i} (default impl)",
                        mul.describe()
                    );
                }
            }
        }
    }

    #[test]
    fn mul_kernel_dispatch() {
        let model = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let native = MulKernel::Native;
        let direct = MulKernel::Direct(model.as_ref());
        let lut_k = MulKernel::Lut(crate::amsim::AmSim::new(&lut));
        assert_eq!(native.mul(1.5, 2.0), 3.0);
        assert_eq!(direct.mul(1.5, 2.0), 3.0);
        assert_eq!(lut_k.mul(1.5, 2.0), 3.0);
        assert_eq!(native.describe(), "native");
        assert_eq!(direct.describe(), "direct:bfloat16");
        assert_eq!(lut_k.describe(), "lut:m7");
        let pinned = MulKernel::NativeAt(SimdLevel::Scalar);
        assert_eq!(pinned.mul(1.5, 2.0), 3.0);
        assert_eq!(pinned.describe(), "native@scalar");
        assert_eq!(
            MulKernel::NativeAt(SimdLevel::Avx2Fma).describe(),
            "native@avx2fma",
            "describe reports the requested pin, clamping happens at dispatch"
        );
    }
}
