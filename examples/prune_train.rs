//! Pruning + approximate multipliers (paper §VIII-C / Fig 11): pre-train
//! the MNIST CNN, magnitude-prune to increasing sparsity with brief
//! retraining, under both the exact FP32 and the approximate AFM16
//! multiplier — demonstrating hardware/algorithm co-design through the
//! framework.
//!
//! A second leg runs the sparse story end to end on the pure-Rust stack:
//! block-structured magnitude pruning, mask-enforced data-parallel
//! fine-tuning, then serving the pruned weights through the multi-lane
//! batching server — with the zero-skipping GEMM drain's pair/skip
//! census sampled around the serving run.
//!
//! ```sh
//! make artifacts && cargo run --release --example prune_train
//! ```

use std::path::Path;
use std::time::Duration;

use approxtrain::coordinator::backend::{CpuBackend, MulSpec};
use approxtrain::coordinator::data_parallel::{DpConfig, DpTrainer};
use approxtrain::coordinator::pruning::{magnitude_block_mask, prune_params, reapply_masks};
use approxtrain::coordinator::server::{reply_correct, serve_pool, ServeConfig};
use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
use approxtrain::data::synth::{mnist_like, SynthSpec};
use approxtrain::data::Batcher;
use approxtrain::kernels::{panel_pair_events, panel_skip_events};
use approxtrain::runtime::executor::Engine;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let mut engine = Engine::new(dir)?;
    let ds = mnist_like(&SynthSpec { n: 512, ..SynthSpec::mnist_like_default() });
    let (train, test) = ds.split(128);
    let sparsities = [0.70, 0.80, 0.83, 0.90];

    for (disp, mode, mult) in [("FP32", "custom", "fp32"), ("AFM16", "lut", "afm16")] {
        let cfg = TrainConfig {
            model: "lenet5".into(),
            mode: mode.into(),
            mult: mult.into(),
            epochs: 4,
            lr: 0.05,
            seed: 42,
            eval_every: usize::MAX,
        };
        let mut tr = Trainer::new(&mut engine, cfg.clone(), dir)?;
        tr.fit(&train, &test)?;
        let baseline = tr.evaluate(&test)? * 100.0;
        let pretrained = tr.checkpoint()?;
        println!("\n=== {disp}: dense baseline {baseline:.2}% ===");
        for &s in &sparsities {
            let mut tr = Trainer::new(&mut engine, cfg.clone(), dir)?;
            tr.load_checkpoint(&pretrained)?;
            let masks = prune_params(tr.params_mut(), s, 128);
            // retrain 2 epochs with masks enforced after every step
            for epoch in 0..2u64 {
                for (images, labels) in Batcher::new(&train, tr.batch_size(), 42, 100 + epoch) {
                    tr.step(&images, &labels)?;
                    reapply_masks(tr.params_mut(), &masks)?;
                }
            }
            let acc = tr.evaluate(&test)? * 100.0;
            let delta = acc - baseline;
            println!("  sparsity {:>3.0}% -> test acc {acc:.2}% ({delta:+.2} pp vs dense)",
                     s * 100.0);
        }
    }

    // --- end-to-end: block-structured prune -> fine-tune -> serve -------
    //
    // Flat magnitude masks almost never produce dead micro-panels (the
    // odds of a whole packed mr x kc row-group zeroing out under
    // unstructured pruning are ~0.9^512 even at 90% sparsity), so this
    // leg prunes in 128-element blocks, fine-tunes with the mask
    // enforced inside every data-parallel step, loads the pruned weights
    // into the lane-server backends, and samples the zero-skipping
    // drain's global pair/skip counters around the serving run.
    println!("\n=== end-to-end: block-pruned LeNet-300 on the lane server (AFM16 LUT) ===");
    let mul = MulSpec::parse("lut:afm16")?;
    let dcfg = DpConfig { workers: 2, shard: 16, lr: 0.05 };
    let mut dp = DpTrainer::new("lenet300", mul.clone(), dcfg, 7)?;
    dp.fit(&train, 2, 32, 1, 11)?;
    let dense_acc = dp.evaluate(&test, 32)? * 100.0;
    let mask = magnitude_block_mask(&dp.flat_params(), 0.8, 128);
    let total = mask.keep.len();
    let pruned = mask.keep.iter().filter(|&&k| !k).count();
    dp.set_mask(Some(mask))?;
    dp.fit(&train, 2, 32, 1, 13)?;
    let sparse_acc = dp.evaluate(&test, 32)? * 100.0;
    println!(
        "  block-pruned {pruned}/{total} weights ({:.0}%): dense {dense_acc:.2}% -> \
         pruned+tuned {sparse_acc:.2}%",
        100.0 * pruned as f64 / total as f64
    );

    let batch = 16;
    let mut base = CpuBackend::for_model("lenet300", mul, batch, 7)?;
    base.load_flat_params(&dp.flat_params())?;
    let mut lanes = base.replicas(2);
    let scfg = ServeConfig { max_wait: Duration::from_millis(4), queue_depth: 4 * batch };
    let (pairs0, skips0) = (panel_pair_events(), panel_skip_events());
    let (stats, correct) = serve_pool(&mut lanes, scfg, |client| {
        let mut correct = 0usize;
        for i in 0..test.n {
            // one blocking request in flight: the bounded queue can't fill
            let reply = client.infer(test.image(i).to_vec()).expect("admission");
            if reply_correct(&reply, test.labels[i]) {
                correct += 1;
            }
        }
        correct
    })?;
    let (pairs, skips) = (panel_pair_events() - pairs0, panel_skip_events() - skips0);
    println!(
        "  served {} requests in {} batches over 2 lanes: {correct}/{} correct",
        stats.requests, stats.batches, test.n
    );
    println!(
        "  drain census: {pairs} micro-panel pairs considered, {skips} elided \
         ({:.1}% skip rate)",
        if pairs == 0 { 0.0 } else { 100.0 * skips as f64 / pairs as f64 }
    );
    Ok(())
}
