//! AVX2 / AVX2+FMA specializations of the **native** multiplier panel
//! ops (the ATnG baseline arm of [`super::MulKernel`]). The LUT arm's
//! vector kernels live in [`crate::amsim::simd`]; `Direct` stays scalar
//! at every level (a virtual call per multiply cannot be vectorized —
//! the paper's direct-simulation cost argument in miniature).
//!
//! Bit-exactness: lanes run across independent accumulator chains, one
//! `vaddps` per chain per contraction step, so each chain performs the
//! exact scalar `acc += a * b` sequence. The FMA variants use `vfmadd`
//! **only in product position** with a `-0.0` addend:
//! `fma(a, b, -0.0) == a * b` bitwise for every input — the exact
//! product plus `-0.0` is the exact product (and for an exactly-zero
//! product, `±0.0 + -0.0` keeps the product's sign under
//! round-to-nearest, which a `+0.0` addend would not). FMA in
//! *accumulate* position (`fma(a, b, acc)`) would single-round
//! `a*b + acc` and break the contract — that is the divergence the
//! reassociation teeth test in `tests/microtile.rs` proves the suites
//! can catch.
//!
//! All loads/stores are unaligned; callers guarantee the target
//! features are present (levels are clamped to the machine).
//!
//! Sparsity: the zero-skipping tiled drain (`gemm::tile_into`) elides
//! dead micro-panel pairs *above* this dispatch layer — a skipped pair
//! simply never calls these arms — so scalar and vector arms need no
//! occupancy awareness and their per-arm bit-exactness contract is
//! untouched. (The native arms can never be reached from a skip-enabled
//! drain anyway: hardware `*` fails the zero identity, see
//! [`super::MulKernel::zero_skip_ok`], so native GEMMs always run the
//! dense drain these arms were verified against.)

use core::arch::x86_64::*;

use crate::amsim::MR_MAX;

/// FP32 lanes per AVX2 vector (same width as `amsim::simd::LANES`).
pub const LANES: usize = 8;

/// `a * b` via plain `vmulps` — the AVX2 product op.
///
/// # Safety
/// AVX2 must be available; only reachable through the
/// `#[target_feature]` kernels below, which the runtime probe gates.
#[inline(always)]
unsafe fn prod_mul(a: __m256, b: __m256) -> __m256 {
    _mm256_mul_ps(a, b)
}

/// `a * b` via `vfmadd` with a `-0.0` addend — bit-identical to
/// `vmulps` (see module docs), exercising the FMA unit.
///
/// # Safety
/// FMA must be available; only reachable through the
/// `#[target_feature(enable = "fma")]` kernel arm, which the runtime
/// probe gates.
#[inline(always)]
unsafe fn prod_fma(a: __m256, b: __m256) -> __m256 {
    _mm256_fmadd_ps(a, b, _mm256_set1_ps(-0.0))
}

macro_rules! define_native_kernels {
    ($microtile:ident, $fma_row:ident, $feat:literal, $prod:ident) => {
        /// Vector arm of the native `mul_microtile`: lanes across the
        /// `nr` column chains in 8-wide chunks, `mr` accumulator vectors
        /// hoisted across the whole `kk` loop, `A` operand broadcast per
        /// `(kk, r)`. Remainder columns drain scalar in the same
        /// ascending-`kk` order (independent chains).
        ///
        /// # Safety
        /// The `$feat` CPU feature must be present at runtime (callers
        /// dispatch through the detected/forced `SimdLevel`), and the
        /// slices must satisfy `acc.len() >= mr * nr`,
        /// `a.len() >= mr * k_len`, `b.len() >= k_len * nr`: every
        /// `loadu`/`storeu` below is an unchecked pointer offset inside
        /// those bounds (no alignment requirement).
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn $microtile(
            acc: &mut [f32],
            a: &[f32],
            b: &[f32],
            mr: usize,
            nr: usize,
            k_len: usize,
        ) {
            let full = nr - nr % LANES;
            let mut c0 = 0;
            while c0 < full {
                let mut accv = [_mm256_setzero_ps(); MR_MAX];
                for (r, av) in accv.iter_mut().enumerate().take(mr) {
                    *av = _mm256_loadu_ps(acc.as_ptr().add(r * nr + c0));
                }
                for kk in 0..k_len {
                    let bv = _mm256_loadu_ps(b.as_ptr().add(kk * nr + c0));
                    for (r, av) in accv.iter_mut().enumerate().take(mr) {
                        let va = _mm256_set1_ps(a[r * k_len + kk]);
                        *av = _mm256_add_ps(*av, $prod(va, bv));
                    }
                }
                for (r, av) in accv.iter().enumerate().take(mr) {
                    _mm256_storeu_ps(acc.as_mut_ptr().add(r * nr + c0), *av);
                }
                c0 += LANES;
            }
            if full < nr {
                for kk in 0..k_len {
                    for r in 0..mr {
                        let x = a[r * k_len + kk];
                        for c in full..nr {
                            acc[r * nr + c] += x * b[kk * nr + c];
                        }
                    }
                }
            }
        }

        /// Vector arm of the native `fma_row`: lanes across the `acc[j]`
        /// chains, scalar tail.
        ///
        /// # Safety
        /// The `$feat` CPU feature must be present at runtime and
        /// `row.len() >= acc.len()`: the unaligned vector loads read
        /// `row[i..i + 8]` for every `i + 8 <= acc.len()`.
        #[target_feature(enable = $feat)]
        pub(super) unsafe fn $fma_row(acc: &mut [f32], x: f32, row: &[f32]) {
            let n = acc.len();
            let vx = _mm256_set1_ps(x);
            let mut i = 0;
            while i + LANES <= n {
                let vr = _mm256_loadu_ps(row.as_ptr().add(i));
                let va = _mm256_loadu_ps(acc.as_ptr().add(i));
                _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(va, $prod(vx, vr)));
                i += LANES;
            }
            while i < n {
                acc[i] += x * row[i];
                i += 1;
            }
        }
    };
}

define_native_kernels!(native_microtile_avx2, native_fma_row_avx2, "avx2", prod_mul);
define_native_kernels!(native_microtile_avx2fma, native_fma_row_avx2fma, "avx2,fma", prod_fma);
