"""Layer-1 kernels: Pallas + jnp bit-math + oracle."""
