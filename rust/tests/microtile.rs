//! Micro-kernel remainder-edge acceptance suite.
//!
//! The register-blocked `MR x NR` tile drain ([`MulBackend::mul_microtile`]
//! via `gemm_tiled_*`) must be bit-identical to the per-element scalar
//! oracle `gemm_scalar_reference` at **every** `(m mod MR, n mod NR)`
//! residue — the edges where the drain falls back to narrower micro-tiles
//! (down to `1 x 1`) — for all three simulation strategies and under the
//! pool scheduler; a forced-level matrix repeats the sweep with the SIMD
//! arms pinned per kernel object, and a teeth check proves a
//! reassociated contraction order would be caught, not silently
//! tolerated. A steady-state check also pins that a second
//! micro-kernel GEMM at the same geometry performs no recycled-buffer
//! growth (the micro-tile accumulator block lives on the stack, and the
//! `NR`-strip `B` packing reuses the same `KC x NC` buffer footprint).

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm_scalar_reference, gemm_tiled_with, TileConfig};
use approxtrain::kernels::{buffer_growth_events, MulBackend, MulKernel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::util::rng::Pcg32;
use approxtrain::util::simd;

fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range(-2.0, 2.0)).collect()
}

fn for_each_strategy(f: impl Fn(&MulKernel, &str)) {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    f(&MulKernel::Native, "native");
    f(&MulKernel::Direct(model.as_ref()), "direct");
    f(&MulKernel::Lut(AmSim::new(&lut)), "lut");
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what} idx {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

/// Every `(m mod MR, n mod NR)` residue of the default 4x8 micro-tile, at
/// a tile geometry small enough that the shapes also straddle tile edges
/// and the contraction splits across `KC` blocks with a remainder — for
/// native / direct / LUT, single-lane and pool-threaded.
#[test]
fn every_residue_matches_scalar_oracle_at_default_micro_tile() {
    let cfg = TileConfig { mc: 8, kc: 16, nc: 16, mr: 4, nr: 8 };
    let k = 37; // two full KC blocks + a 5-step remainder
    for_each_strategy(|mul, name| {
        for m in 12..16 {
            // m % 4 covers 0..=3
            for n in 16..24 {
                // n % 8 covers 0..=7
                let mut rng = Pcg32::seeded(8800 + (m * 100 + n) as u64);
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                for threads in [1usize, 8] {
                    let mut got = vec![0.0f32; m * n];
                    gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, threads);
                    assert_bits(
                        &got,
                        &want,
                        &format!("[{name}] ({m},{k},{n}) residue ({},{}) t={threads}", m % 4, n % 8),
                    );
                }
            }
        }
    });
}

/// The same residue sweep at a non-default, odd micro-tile shape (3x5),
/// so remainder handling is not accidentally specialized to the default
/// powers of two.
#[test]
fn every_residue_matches_scalar_oracle_at_odd_micro_tile() {
    let cfg = TileConfig { mc: 6, kc: 11, nc: 10, mr: 3, nr: 5 };
    let k = 23;
    for_each_strategy(|mul, name| {
        for m in 9..12 {
            // m % 3 covers 0..=2
            for n in 10..15 {
                // n % 5 covers 0..=4
                let mut rng = Pcg32::seeded(8900 + (m * 100 + n) as u64);
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                let mut got = vec![0.0f32; m * n];
                gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, 1);
                assert_bits(
                    &got,
                    &want,
                    &format!("[{name}] ({m},{k},{n}) residue ({},{})", m % 3, n % 5),
                );
            }
        }
    });
}

/// Problems smaller than one micro-tile in either dimension (m < MR,
/// n < NR) run entirely on remainder paths.
#[test]
fn degenerate_shapes_smaller_than_the_micro_tile() {
    for_each_strategy(|mul, name| {
        for (m, k, n) in [(1usize, 1usize, 1usize), (2, 9, 3), (1, 40, 7), (3, 17, 1)] {
            let mut rng = Pcg32::seeded(9000 + (m * k * n) as u64);
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut want = vec![0.0f32; m * n];
            gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
            let mut got = vec![0.0f32; m * n];
            gemm_tiled_with(mul, TileConfig::DEFAULT, &a, &b, &mut got, m, k, n, 1);
            assert_bits(&got, &want, &format!("[{name}] tiny ({m},{k},{n})"));
        }
    });
}

/// The default-micro-tile residue sweep repeated with the SIMD level
/// *forced* per kernel object — every machine-executable level (Scalar,
/// Avx2, Avx2Fma when detected) × threads {1, 8} — so the AVX2 gather
/// arm and the FMA arm face the same remainder edges as the portable
/// body, against the same scalar oracle, in one process.
#[test]
fn forced_simd_levels_match_scalar_oracle_at_every_residue() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let cfg = TileConfig { mc: 8, kc: 16, nc: 16, mr: 4, nr: 8 };
    let k = 37;
    for level in simd::available_levels() {
        let kernels = [
            (MulKernel::NativeAt(level), format!("native@{level}")),
            (MulKernel::Lut(AmSim::with_simd(&lut, level)), format!("lut@{level}")),
        ];
        for (mul, name) in &kernels {
            for m in 12..16 {
                for n in 16..24 {
                    let mut rng = Pcg32::seeded(8800 + (m * 100 + n) as u64);
                    let a = rand_vec(&mut rng, m * k);
                    let b = rand_vec(&mut rng, k * n);
                    let mut want = vec![0.0f32; m * n];
                    gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                    for threads in [1usize, 8] {
                        let mut got = vec![0.0f32; m * n];
                        gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, threads);
                        assert_bits(
                            &got,
                            &want,
                            &format!("[{name}] ({m},{k},{n}) residue ({},{}) t={threads}", m % 4, n % 8),
                        );
                    }
                }
            }
        }
    }
}

/// Teeth check for the whole bit-exactness net: an intentionally
/// *reassociated* (descending-k) reference must diverge from the
/// micro-kernel on a construction built to expose reordering, at every
/// forced level. Products `[1.0, 2^-24, 2^-24]` sum to exactly `1.0` in
/// ascending order (`1.0 + 2^-24` is a round-to-even tie, twice) but to
/// `1.0 + 2^-23` when the tiny products are added first — so if any SIMD
/// arm reordered the contraction, the sweeps above could not pass, and
/// this test proves they have the teeth to notice.
#[test]
fn reassociated_k_order_reference_does_diverge() {
    let tiny = f32::from_bits(0x3380_0000); // 2^-24
    let a = [1.0f32, 1.0, 1.0];
    let b = [1.0f32, tiny, tiny];
    for level in simd::available_levels() {
        let mul = MulKernel::NativeAt(level);
        let mut acc = [0.0f32];
        mul.mul_microtile(&mut acc, &a, &b, 1, 1, 3);
        assert_eq!(
            acc[0].to_bits(),
            1.0f32.to_bits(),
            "ascending-k contraction at {level} must absorb both ties"
        );
        // hand-rolled descending-k accumulation: 2^-24 + 2^-24 = 2^-23,
        // which 1.0 + 2^-23 then represents exactly
        let mut desc = 0.0f32;
        for kk in (0..3).rev() {
            desc += mul.mul(a[kk], b[kk]);
        }
        assert_eq!(desc.to_bits(), (1.0f32 + f32::from_bits(0x3400_0000)).to_bits());
        assert_ne!(
            acc[0].to_bits(),
            desc.to_bits(),
            "reassociated reference must be observably different at {level}"
        );
    }
    // the same construction through a full GEMM: a 1x1 output over k=3
    // takes the tiled path end to end and must land on the ascending sum
    let want = {
        let mut c = vec![0.0f32; 1];
        gemm_scalar_reference(&MulKernel::Native, &a, &b, &mut c, 1, 3, 1);
        c
    };
    assert_eq!(want[0].to_bits(), 1.0f32.to_bits());
}

/// Steady-state no-alloc check: after a warm first micro-kernel GEMM, a
/// second run at the same geometry must not grow the recycled
/// thread-local pack buffers (single lane, so this thread's growth
/// counter observes every packing).
#[test]
fn second_micro_kernel_gemm_reuses_recycled_buffers() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mul = MulKernel::Lut(AmSim::new(&lut));
    let (m, k, n) = (21, 65, 19);
    let mut rng = Pcg32::seeded(9100);
    let a = rand_vec(&mut rng, m * k);
    let b = rand_vec(&mut rng, k * n);
    let mut first = vec![0.0f32; m * n];
    gemm_tiled_with(&mul, TileConfig::DEFAULT, &a, &b, &mut first, m, k, n, 1);
    let before = buffer_growth_events();
    let mut second = vec![0.0f32; m * n];
    gemm_tiled_with(&mul, TileConfig::DEFAULT, &a, &b, &mut second, m, k, n, 1);
    assert_eq!(
        buffer_growth_events(),
        before,
        "steady-state micro-kernel GEMM must not grow the recycled buffers"
    );
    assert_bits(&second, &first, "steady-state determinism");
}
