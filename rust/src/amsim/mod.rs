//! AMSim — the LUT-based approximate FP multiplication simulator.
//! Paper §V-B, Algorithm 2.
//!
//! Given two FP32 operands and a mantissa-product LUT:
//! 1. fetch `(carry, mantissa)` from the LUT at the concatenated operand
//!    mantissas;
//! 2. compute sign (XOR) and exponent (sum − bias) exactly;
//! 3. re-assemble, with flush-to-zero for `exp <= 0` / zero operands and
//!    overflow-to-infinity for `exp >= 255`.
//!
//! The panel kernels ([`AmSim::mul_slice`], [`AmSim::dot_acc`],
//! [`AmSim::fma_row`], [`AmSim::mul_microtile`]) each carry an AVX2
//! specialization ([`simd`], x86-64 only) next to their portable scalar
//! body; which one runs is decided by the instance's
//! [`crate::util::simd::SimdLevel`] — runtime-detected by default
//! ([`AmSim::new`]), forceable per instance ([`AmSim::with_simd`]) and
//! process-wide via `APPROXTRAIN_SIMD`. The scalar body is the oracle:
//! every vector arm is bit-identical to it (lanes run across independent
//! accumulator chains, never along one — see [`crate::util::simd`]).
//!
//! One deliberate deviation from the paper's pseudo-code: Algorithm 2
//! checks `Exp >= 255` *before* adding the carry, so `Exp == 254, carry ==
//! 1` would assemble the biased exponent 255 and silently produce
//! Inf/NaN bit patterns. We apply the overflow check after the carry (the
//! behaviour real hardware would implement); `tests::alg2_edge_case_fixed`
//! documents the difference. The same post-carry semantics are used in the
//! Pallas kernel and in `mult::models::mul_via_mantissa`, so all three
//! simulation paths are bit-identical.

use crate::lut::MantissaLut;
use crate::mult::fpbits::{EXP_BIAS, EXP_MASK, MANT_BITS, MANT_MASK, SIGN_MASK};
use crate::util::simd::SimdLevel;

#[cfg(target_arch = "x86_64")]
pub mod simd;

/// Hard ceiling on the micro-kernel's register-block height
/// ([`AmSim::mul_microtile`]'s `mr`). Bounds the stack footprint of the
/// hoisted per-step operand decompositions here and of the GEMM tile
/// drain's accumulator block. Re-exported as `kernels::MR_MAX`.
pub const MR_MAX: usize = 16;

/// Hard ceiling on the micro-kernel's register-block width (`nr`).
/// Re-exported as `kernels::NR_MAX`.
pub const NR_MAX: usize = 16;

/// Validate the shared `mul_microtile` operand contract (register-block
/// bounds + slice shapes). The one home for these checks, used by the
/// `MulBackend` trait default, the `MulKernel` dispatch arms and
/// [`AmSim::mul_microtile`], so the paths cannot drift.
#[inline]
pub fn assert_microtile_shape(
    acc: &[f32],
    a: &[f32],
    b: &[f32],
    mr: usize,
    nr: usize,
    k_len: usize,
) {
    assert!(mr <= MR_MAX && nr <= NR_MAX, "micro-tile {mr}x{nr} exceeds max");
    assert_eq!(acc.len(), mr * nr);
    assert_eq!(a.len(), mr * k_len);
    assert_eq!(b.len(), k_len * nr);
}

/// The simulator: a LUT plus the derived masks/shifts of Algorithm 2's
/// "global variables".
pub struct AmSim<'a> {
    lut: &'a [u32],
    m: u32,
    /// shift that brings a 23-bit mantissa field down to its top `m` bits
    shift: u32,
    /// SIMD tier the panel kernels dispatch at — always clamped to what
    /// this machine can execute, so the unsafe vector arms are only ever
    /// entered with their target features present
    simd: SimdLevel,
}

impl<'a> AmSim<'a> {
    /// Simulator at the process-wide active SIMD level
    /// ([`crate::util::simd::active`]: runtime detection, lowered by
    /// `APPROXTRAIN_SIMD` if set).
    pub fn new(lut: &'a MantissaLut) -> AmSim<'a> {
        Self::with_simd(lut, crate::util::simd::active())
    }

    /// Simulator pinned to a specific SIMD tier — the forced-level hook
    /// the differential suites (`tests/simd_lanes.rs`) and the per-level
    /// bench rows use. `level` is clamped to the machine's capability,
    /// so requesting a tier the CPU lacks degrades to a runnable one
    /// instead of faulting.
    pub fn with_simd(lut: &'a MantissaLut, level: SimdLevel) -> AmSim<'a> {
        // The panel kernels below index the table with
        // `(amnt << m) | bmnt` where both halves are `m`-bit values, and
        // elide the bounds check on the strength of this invariant.
        assert_eq!(
            lut.entries.len(),
            1usize << (2 * lut.m),
            "LUT size must be 2^(2m)"
        );
        AmSim {
            lut: &lut.entries,
            m: lut.m,
            shift: MANT_BITS - lut.m,
            simd: level.clamp_to_machine(),
        }
    }

    /// The SIMD tier this instance dispatches at (post-clamp).
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// Hard re-validation of the LUT-index invariant at panel entry: the
    /// panel kernels (scalar `get_unchecked` and the AVX2 `vpgatherdd`
    /// arm alike) elide per-element bounds checks on the strength of
    /// `lut.len() == 2^(2m)`, so this must hold in release builds too —
    /// a `debug_assert` would let a corrupted simulator turn an
    /// out-of-range gather into UB inside the `unsafe` arms. One check
    /// per panel, not per element, so the cost is noise.
    #[inline]
    fn assert_panel_invariant(&self) {
        assert!(
            self.m <= MANT_BITS
                && self.shift == MANT_BITS - self.m
                && self.lut.len() == 1usize << (2 * self.m),
            "AmSim LUT invariant violated (m={}, shift={}, lut.len()={})",
            self.m,
            self.shift,
            self.lut.len()
        );
    }

    /// Algorithm 2 over raw FP32 bit patterns.
    #[inline]
    pub fn mul_bits(&self, a: u32, b: u32) -> u32 {
        // line 7-8: mantissa extraction and LUT index (A in the high half,
        // B in the low half — equivalent to the paper's fused shifts but
        // valid for the full m = 1..=12 range)
        let amnt = (a & MANT_MASK) >> self.shift;
        let bmnt = (b & MANT_MASK) >> self.shift;
        let entry = self.lut[((amnt << self.m) | bmnt) as usize];
        // lines 9-10: decouple carry and mantissa
        let carry = (entry >> MANT_BITS) & 1;
        let mnt = entry & MANT_MASK;
        // line 11: sign
        let sign = (a ^ b) & SIGN_MASK;
        // line 12: unnormalized exponent
        let ea = (a & EXP_MASK) >> MANT_BITS;
        let eb = (b & EXP_MASK) >> MANT_BITS;
        let exp = ea as i32 + eb as i32 - EXP_BIAS;
        // lines 13-20 (overflow checked after carry, see module docs)
        if exp <= 0 || ea == 0 || eb == 0 {
            return 0;
        }
        let exp = exp + carry as i32;
        if exp >= 255 {
            return sign | EXP_MASK; // +-inf
        }
        sign | ((exp as u32) << MANT_BITS) | mnt
    }

    /// Algorithm 2 over f32 values.
    #[inline]
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        f32::from_bits(self.mul_bits(a.to_bits(), b.to_bits()))
    }

    /// One LUT gather with all Algorithm-2 "global variables" passed in as
    /// locals so the panel loops keep them in registers instead of
    /// re-reading `self` per element.
    ///
    /// # Safety contract (checked in [`AmSim::new`])
    /// `lut.len() == 1 << (2 * m)` and both mantissa halves are `m`-bit,
    /// so the index is always in bounds; the unchecked access removes the
    /// per-multiply bounds test from the innermost loop.
    #[inline(always)]
    fn gather(lut: &[u32], m: u32, shift: u32, a: u32, b: u32) -> u32 {
        let amnt = (a & MANT_MASK) >> shift;
        let bmnt = (b & MANT_MASK) >> shift;
        // SAFETY: amnt, bmnt < 2^m, so (amnt << m | bmnt) < 2^(2m) == lut.len().
        let entry = unsafe { *lut.get_unchecked(((amnt << m) | bmnt) as usize) };
        let carry = (entry >> MANT_BITS) & 1;
        let mnt = entry & MANT_MASK;
        let sign = (a ^ b) & SIGN_MASK;
        let ea = (a & EXP_MASK) >> MANT_BITS;
        let eb = (b & EXP_MASK) >> MANT_BITS;
        let exp = ea as i32 + eb as i32 - EXP_BIAS;
        if exp <= 0 || ea == 0 || eb == 0 {
            return 0;
        }
        let exp = exp + carry as i32;
        if exp >= 255 {
            return sign | EXP_MASK; // +-inf
        }
        sign | ((exp as u32) << MANT_BITS) | mnt
    }

    /// Vectorized front-end: `out[i] = amsim(a[i], b[i])` — a tight
    /// LUT-gather loop, bit-identical to calling [`AmSim::mul`] per
    /// element. Dispatches to the AVX2 elementwise arm at
    /// [`SimdLevel::Avx2`]+.
    pub fn mul_slice(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
        assert!(a.len() == b.len() && a.len() == out.len());
        self.assert_panel_invariant();
        #[cfg(target_arch = "x86_64")]
        if self.simd >= SimdLevel::Avx2 {
            // SAFETY: simd is clamped to the machine, so AVX2 is present;
            // the gather invariant was just hard-asserted.
            unsafe { simd::lut_mul_slice_avx2(self.lut, self.m, self.shift, a, b, out) };
            return;
        }
        let (lut, m, shift) = (self.lut, self.m, self.shift);
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = f32::from_bits(Self::gather(lut, m, shift, x.to_bits(), y.to_bits()));
        }
    }

    /// Multiply-accumulate over two slices with FP32 accumulation — the
    /// paper's mixed-precision rule (§VII *Datatype*: "all accumulation
    /// operations are performed in FP32").
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        self.dot_acc(0.0, a, b)
    }

    /// [`AmSim::dot`] continued from a running accumulator `init`.
    ///
    /// This is the GEMM/matvec inner loop: shift/mask hoisted into
    /// registers, LUT gathers unrolled 4-wide so the address computations
    /// pipeline, accumulation kept strictly sequential so the result is
    /// bit-identical to the scalar `acc += amsim(a[i], b[i])` reference —
    /// and, because the accumulator is threaded through, independent of
    /// how callers split a long dot across cache blocks.
    ///
    /// At [`SimdLevel::Avx2`]+ the *product* computation (decomposition,
    /// gather, assembly — exact integer ops) runs 8 lanes wide while the
    /// adds stay strictly serial: a dot is a single accumulator chain,
    /// and only the products are order-free.
    pub fn dot_acc(&self, init: f32, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        self.assert_panel_invariant();
        #[cfg(target_arch = "x86_64")]
        if self.simd >= SimdLevel::Avx2 {
            // SAFETY: simd is clamped to the machine; invariant asserted.
            return unsafe { simd::lut_dot_acc_avx2(self.lut, self.m, self.shift, init, a, b) };
        }
        let (lut, m, shift) = (self.lut, self.m, self.shift);
        let n = a.len();
        let mut acc = init;
        let mut i = 0;
        while i + 4 <= n {
            // the four gathers are independent (ILP); the four adds are
            // ordered (bit-exactness)
            let p0 = Self::gather(lut, m, shift, a[i].to_bits(), b[i].to_bits());
            let p1 = Self::gather(lut, m, shift, a[i + 1].to_bits(), b[i + 1].to_bits());
            let p2 = Self::gather(lut, m, shift, a[i + 2].to_bits(), b[i + 2].to_bits());
            let p3 = Self::gather(lut, m, shift, a[i + 3].to_bits(), b[i + 3].to_bits());
            acc += f32::from_bits(p0);
            acc += f32::from_bits(p1);
            acc += f32::from_bits(p2);
            acc += f32::from_bits(p3);
            i += 4;
        }
        while i < n {
            acc += f32::from_bits(Self::gather(lut, m, shift, a[i].to_bits(), b[i].to_bits()));
            i += 1;
        }
        acc
    }

    /// Row-FMA: `acc[j] += amsim(x, row[j])`, with `x`'s mantissa half and
    /// exponent hoisted out of the loop (the dense weight-gradient inner
    /// loop). Bit-identical to the per-element scalar sequence, including
    /// the `+= 0.0` flush-adds (which normalize `-0.0` accumulators the
    /// same way the scalar path does).
    ///
    /// At [`SimdLevel::Avx2`]+ the lanes run across the independent
    /// `acc[j]` chains (one ordered add per chain per call), so the
    /// vector arm is bit-identical by construction.
    pub fn fma_row(&self, acc: &mut [f32], x: f32, row: &[f32]) {
        assert_eq!(acc.len(), row.len());
        self.assert_panel_invariant();
        #[cfg(target_arch = "x86_64")]
        if self.simd >= SimdLevel::Avx2 {
            // SAFETY: simd is clamped to the machine; invariant asserted.
            unsafe { simd::lut_fma_row_avx2(self.lut, self.m, self.shift, acc, x, row) };
            return;
        }
        let (lut, m, shift) = (self.lut, self.m, self.shift);
        let xb = x.to_bits();
        let ea = (xb & EXP_MASK) >> MANT_BITS;
        if ea == 0 {
            // x is zero/subnormal: every product flushes to +0.0; keep the
            // adds so accumulator bit patterns match the scalar path
            for a in acc.iter_mut() {
                *a += 0.0;
            }
            return;
        }
        let xrow = (xb & MANT_MASK) >> shift << m; // pre-shifted LUT row base
        let xsign = xb & SIGN_MASK;
        for (a, &r) in acc.iter_mut().zip(row) {
            let rb = r.to_bits();
            let bmnt = (rb & MANT_MASK) >> shift;
            // SAFETY: same invariant as `gather` (see AmSim::new).
            let entry = unsafe { *lut.get_unchecked((xrow | bmnt) as usize) };
            let eb = (rb & EXP_MASK) >> MANT_BITS;
            let exp = ea as i32 + eb as i32 - EXP_BIAS;
            let bits = if exp <= 0 || eb == 0 {
                0
            } else {
                let sign = (xsign ^ rb) & SIGN_MASK;
                let exp = exp + ((entry >> MANT_BITS) & 1) as i32;
                if exp >= 255 {
                    sign | EXP_MASK
                } else {
                    sign | ((exp as u32) << MANT_BITS) | (entry & MANT_MASK)
                }
            };
            *a += f32::from_bits(bits);
        }
    }

    /// Register-blocked `mr x nr` micro-tile FMA — the
    /// [`crate::kernels::MulBackend::mul_microtile`] hot path. `a` holds
    /// `mr` rows of `k_len` operands (row-major), `b` the `k_len x nr`
    /// strip interleaved k-major (`b[kk*nr + c]`).
    ///
    /// Per contraction step the `mr` `A` operands and `nr` `B` operands
    /// are decomposed **once** — pre-shifted LUT row bases, hoisted
    /// exponents and signs, exactly what [`AmSim::fma_row`] does for its
    /// single broadcast operand — and then feed `mr * nr` LUT gathers
    /// into `mr * nr` *independent* FP32 accumulator chains. Relative to
    /// draining each output element with its own [`AmSim::dot_acc`], this
    /// cuts per-MAC decomposition cost by ~`mr*nr / (mr + nr)` and hides
    /// the FP-add latency the single serial chain exposes. Each
    /// accumulator still receives its products strictly in ascending `kk`
    /// order, so the result is bit-identical to the scalar
    /// `acc += amsim(a, b)` sequence (including the `+= 0.0` flush-adds
    /// for zero/subnormal operands and underflow).
    ///
    /// At [`SimdLevel::Avx2`]+ the lanes run across the `nr` column
    /// chains in 8-wide chunks (`vpgatherdd` LUT gathers, vectorized
    /// decomposition, accumulator vectors hoisted across the `kk` loop);
    /// remainder columns drain through the scalar gather. Every chain
    /// still receives exactly one add per `kk`, ascending, so all arms
    /// are bit-identical.
    pub fn mul_microtile(
        &self,
        acc: &mut [f32],
        a: &[f32],
        b: &[f32],
        mr: usize,
        nr: usize,
        k_len: usize,
    ) {
        assert_microtile_shape(acc, a, b, mr, nr, k_len);
        self.assert_panel_invariant();
        #[cfg(target_arch = "x86_64")]
        if self.simd >= SimdLevel::Avx2 {
            // SAFETY: simd is clamped to the machine; invariant asserted.
            unsafe {
                simd::lut_microtile_avx2(
                    self.lut, self.m, self.shift, acc, a, b, mr, nr, k_len,
                )
            };
            return;
        }
        let (lut, m, shift) = (self.lut, self.m, self.shift);
        // hoisted per-step operand decompositions (Algorithm 2 lines 7-8
        // and 11-12, paid once per operand instead of once per product)
        let mut a_row = [0u32; MR_MAX]; // pre-shifted LUT row base
        let mut a_sign = [0u32; MR_MAX];
        let mut a_exp = [0i32; MR_MAX];
        let mut b_mnt = [0u32; NR_MAX];
        let mut b_sign = [0u32; NR_MAX];
        let mut b_exp = [0i32; NR_MAX];
        for kk in 0..k_len {
            for r in 0..mr {
                let bits = a[r * k_len + kk].to_bits();
                a_row[r] = (bits & MANT_MASK) >> shift << m;
                a_sign[r] = bits & SIGN_MASK;
                a_exp[r] = ((bits & EXP_MASK) >> MANT_BITS) as i32;
            }
            let b_step = &b[kk * nr..(kk + 1) * nr];
            for (c, bv) in b_step.iter().enumerate() {
                let bits = bv.to_bits();
                b_mnt[c] = (bits & MANT_MASK) >> shift;
                b_sign[c] = bits & SIGN_MASK;
                b_exp[c] = ((bits & EXP_MASK) >> MANT_BITS) as i32;
            }
            for r in 0..mr {
                let (ea, ar, asg) = (a_exp[r], a_row[r], a_sign[r]);
                let acc_row = &mut acc[r * nr..(r + 1) * nr];
                for (c, av) in acc_row.iter_mut().enumerate() {
                    let eb = b_exp[c];
                    let exp = ea + eb - EXP_BIAS;
                    // SAFETY: same invariant as `gather` (see AmSim::new).
                    let entry = unsafe { *lut.get_unchecked((ar | b_mnt[c]) as usize) };
                    let bits = if exp <= 0 || ea == 0 || eb == 0 {
                        0
                    } else {
                        let sign = asg ^ b_sign[c];
                        let exp = exp + ((entry >> MANT_BITS) & 1) as i32;
                        if exp >= 255 {
                            sign | EXP_MASK
                        } else {
                            sign | ((exp as u32) << MANT_BITS) | (entry & MANT_MASK)
                        }
                    };
                    // mr*nr independent chains; each chain's adds stay in
                    // ascending kk order (bit-exactness)
                    *av += f32::from_bits(bits);
                }
            }
        }
    }

    pub fn mantissa_bits(&self) -> u32 {
        self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::MantissaLut;
    use crate::mult::fpbits::quantize_mantissa;
    use crate::mult::registry;
    use crate::util::prop::for_all;

    /// The core contract (paper §VI footnote 2 validates GPU against CPU the
    /// same way): AMSim through the LUT must be bit-identical to the direct
    /// functional model for operands representable in m bits.
    #[test]
    fn amsim_equals_direct_model_for_all_m7_designs() {
        for name in ["bfloat16", "afm16", "mit16", "realm16", "trunc16", "comp16"] {
            let model = registry::by_name(name).unwrap();
            let lut = MantissaLut::generate(model.as_ref());
            let sim = AmSim::new(&lut);
            for_all(
                &format!("amsim-eq-direct-{name}"),
                42,
                20_000,
                |r| {
                    (
                        quantize_mantissa(r.finite_f32(), 7),
                        quantize_mantissa(r.finite_f32(), 7),
                    )
                },
                |&(a, b)| {
                    let via_lut = sim.mul(a, b);
                    let direct = model.mul(a, b);
                    // AMSim returns unsigned 0 (Alg 2 line 14) where the
                    // direct model keeps the sign; compare through bits
                    // modulo the zero sign.
                    let eq = via_lut.to_bits() == direct.to_bits()
                        || (via_lut == 0.0 && direct == 0.0);
                    if eq {
                        Ok(())
                    } else {
                        Err(format!("{a} * {b}: lut {via_lut} != direct {direct}"))
                    }
                },
            );
        }
    }

    #[test]
    fn zero_inputs_give_zero() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        assert_eq!(sim.mul(0.0, 123.5).to_bits(), 0);
        assert_eq!(sim.mul(-4.0, 0.0).to_bits(), 0);
        assert_eq!(sim.mul(f32::MIN_POSITIVE / 4.0, 2.0).to_bits(), 0); // subnormal
    }

    #[test]
    fn underflow_flushes_overflow_saturates() {
        let model = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        assert_eq!(sim.mul(1e-30, 1e-30), 0.0);
        assert_eq!(sim.mul(1e30, 1e30), f32::INFINITY);
        assert_eq!(sim.mul(-1e30, 1e30), f32::NEG_INFINITY);
    }

    /// Exp == 254 with a mantissa carry must overflow to infinity, not
    /// assemble a NaN pattern (the Algorithm-2 edge case, see module docs).
    #[test]
    fn alg2_edge_case_fixed() {
        let model = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        // exponent sum 190 + 191 - 127 = 254; mantissa product of
        // 1.984375^2 ~ 3.94 carries -> post-carry exponent 255 -> +inf
        let a = 1.984375f32 * 2f32.powi(63);
        let b = 1.984375f32 * 2f32.powi(64);
        let c = sim.mul(a, b);
        assert!(c.is_infinite() && c > 0.0, "got {c}");
        assert!(!c.is_nan());
    }

    #[test]
    fn dot_accumulates_in_fp32() {
        let model = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        let a: Vec<f32> = (0..100).map(|i| quantize_mantissa(0.01 * i as f32, 7)).collect();
        let b = vec![1.0f32; 100];
        let got = sim.dot(&a, &b);
        let want: f32 = a.iter().sum();
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    /// The batched panel ops (unrolled dot, hoisted-operand fma_row) must
    /// reproduce the scalar `mul` + sequential-add reference bit for bit —
    /// the contract the GEMM/matvec kernels rely on.
    #[test]
    fn panel_ops_match_scalar_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        let mk = |seed: u64, n: usize| {
            let mut r = crate::util::rng::Pcg32::seeded(seed);
            (0..n).map(|_| quantize_mantissa(r.range(-3.0, 3.0), 7)).collect::<Vec<f32>>()
        };
        for n in [0usize, 1, 3, 4, 5, 7, 8, 64, 129] {
            let a = mk(100 + n as u64, n);
            let b = mk(200 + n as u64, n);
            // dot: unrolled vs strictly scalar
            let mut want = 0.0f32;
            for i in 0..n {
                want += sim.mul(a[i], b[i]);
            }
            assert_eq!(sim.dot(&a, &b).to_bits(), want.to_bits(), "dot n={n}");
            // fma_row: hoisted vs scalar, including a zero multiplicand
            for x in [1.7f32, -0.625, 0.0] {
                let mut acc = mk(300 + n as u64, n);
                let mut acc_ref = acc.clone();
                sim.fma_row(&mut acc, x, &b);
                for i in 0..n {
                    acc_ref[i] += sim.mul(x, b[i]);
                }
                for i in 0..n {
                    assert_eq!(
                        acc[i].to_bits(),
                        acc_ref[i].to_bits(),
                        "fma_row x={x} n={n} idx {i}"
                    );
                }
            }
        }
    }

    /// The hoisted-decomposition micro-tile must reproduce the scalar
    /// `acc += mul(a, b)` sequence bit for bit — for full and remainder
    /// block shapes, with zero operands on both sides, and continuing
    /// non-zero incoming accumulators (the tiled-GEMM drain contract).
    #[test]
    fn mul_microtile_matches_scalar_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        let mk = |seed: u64, n: usize| {
            let mut r = crate::util::rng::Pcg32::seeded(seed);
            (0..n).map(|_| quantize_mantissa(r.range(-3.0, 3.0), 7)).collect::<Vec<f32>>()
        };
        for (mr, nr, k_len) in [(1usize, 1usize, 9usize), (4, 8, 16), (3, 7, 5), (8, 8, 0)] {
            let mut a = mk(500 + mr as u64, mr * k_len);
            let mut b = mk(600 + nr as u64, k_len * nr);
            if !a.is_empty() {
                a[0] = 0.0;
            }
            if b.len() > 1 {
                b[1] = -0.0;
            }
            let init = mk(700 + k_len as u64, mr * nr);
            let mut got = init.clone();
            sim.mul_microtile(&mut got, &a, &b, mr, nr, k_len);
            let mut want = init;
            for kk in 0..k_len {
                for r in 0..mr {
                    for c in 0..nr {
                        want[r * nr + c] += sim.mul(a[r * k_len + kk], b[kk * nr + c]);
                    }
                }
            }
            for i in 0..mr * nr {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "{mr}x{nr} k={k_len} idx {i}"
                );
            }
        }
    }

    /// Every panel op at every machine-executable SIMD level must be
    /// bit-identical to the scalar-forced instance (the oracle). This is
    /// the in-crate smoke of the contract; the full forced-level ×
    /// multiplier × residue matrix lives in `tests/simd_lanes.rs`.
    #[test]
    fn forced_simd_levels_match_scalar_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let oracle = AmSim::with_simd(&lut, SimdLevel::Scalar);
        assert_eq!(oracle.simd_level(), SimdLevel::Scalar);
        let mk = |seed: u64, n: usize| {
            let mut r = crate::util::rng::Pcg32::seeded(seed);
            (0..n).map(|_| quantize_mantissa(r.range(-4.0, 4.0), 7)).collect::<Vec<f32>>()
        };
        for level in crate::util::simd::available_levels() {
            let sim = AmSim::with_simd(&lut, level);
            assert_eq!(sim.simd_level(), level, "clamp must keep executable levels");
            // sizes straddling the 8-lane width, incl. tails and empties
            for n in [0usize, 1, 7, 8, 9, 16, 23, 64] {
                let a = mk(10 + n as u64, n);
                let b = mk(20 + n as u64, n);
                let mut out = vec![0.0f32; n];
                let mut out_ref = vec![0.0f32; n];
                sim.mul_slice(&a, &b, &mut out);
                oracle.mul_slice(&a, &b, &mut out_ref);
                for i in 0..n {
                    assert_eq!(out[i].to_bits(), out_ref[i].to_bits(), "{level} slice n={n}");
                }
                assert_eq!(
                    sim.dot_acc(0.5, &a, &b).to_bits(),
                    oracle.dot_acc(0.5, &a, &b).to_bits(),
                    "{level} dot n={n}"
                );
                let mut acc = mk(30 + n as u64, n);
                let mut acc_ref = acc.clone();
                sim.fma_row(&mut acc, -1.75, &b);
                oracle.fma_row(&mut acc_ref, -1.75, &b);
                for i in 0..n {
                    assert_eq!(acc[i].to_bits(), acc_ref[i].to_bits(), "{level} fma n={n}");
                }
            }
            // micro-tiles across nr residues 1..=9 (every lane tail + one
            // full chunk + chunk-plus-tail)
            for nr in 1..=9usize {
                let (mr, k_len) = (4usize, 13usize);
                let a = mk(40 + nr as u64, mr * k_len);
                let b = mk(50 + nr as u64, k_len * nr);
                let init = mk(60 + nr as u64, mr * nr);
                let mut got = init.clone();
                let mut want = init.clone();
                sim.mul_microtile(&mut got, &a, &b, mr, nr, k_len);
                oracle.mul_microtile(&mut want, &a, &b, mr, nr, k_len);
                for i in 0..mr * nr {
                    assert_eq!(got[i].to_bits(), want[i].to_bits(), "{level} tile nr={nr}");
                }
            }
        }
    }

    /// A level the machine cannot execute degrades instead of faulting.
    #[test]
    fn with_simd_clamps_to_machine() {
        let model = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::with_simd(&lut, SimdLevel::Avx2Fma);
        assert!(sim.simd_level() <= SimdLevel::detected());
        assert_eq!(sim.mul(1.5, 2.0), 3.0);
    }

    #[test]
    fn mul_slice_matches_scalar() {
        let model = registry::by_name("mit16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        let a: Vec<f32> = (1..64).map(|i| quantize_mantissa(i as f32 * 0.37, 7)).collect();
        let b: Vec<f32> = (1..64).map(|i| quantize_mantissa(i as f32 * -1.91, 7)).collect();
        let mut out = vec![0.0f32; a.len()];
        sim.mul_slice(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i].to_bits(), sim.mul(a[i], b[i]).to_bits());
        }
    }
}
