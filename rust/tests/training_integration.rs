//! End-to-end training integration: the full L3 -> PJRT -> artifact path
//! must learn, match its CPU oracle, and keep multiplier runs comparable.
//! Skipped when `artifacts/` has not been built.

use std::path::{Path, PathBuf};

use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
use approxtrain::data::synth::{mnist_like, SynthSpec};
use approxtrain::runtime::executor::Engine;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn cfg(model: &str, mode: &str, mult: &str, epochs: usize) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        mode: mode.into(),
        mult: mult.into(),
        epochs,
        lr: 0.05,
        seed: 42,
        eval_every: 1,
    }
}

#[test]
fn lenet300_trains_with_approximate_multiplier() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let ds = mnist_like(&SynthSpec { n: 320, ..SynthSpec::mnist_like_default() });
    let (train, test) = ds.split(64);
    let mut tr = Trainer::new(&mut engine, cfg("lenet300", "lut", "afm16", 3), &dir).unwrap();
    let log = tr.fit(&train, &test).unwrap();
    let first = log.epochs.first().unwrap();
    let last = log.epochs.last().unwrap();
    assert!(
        last.train_loss < first.train_loss * 0.7,
        "loss did not fall: {} -> {}",
        first.train_loss,
        last.train_loss
    );
    assert!(last.test_acc > 0.5, "test acc {:.3}", last.test_acc);
}

/// The paper's core claim in miniature: approximate-multiplier training
/// converges like exact training from the same seed (Fig 10).
#[test]
fn approximate_convergence_tracks_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let ds = mnist_like(&SynthSpec { n: 320, ..SynthSpec::mnist_like_default() });
    let (train, test) = ds.split(64);
    let mut accs = Vec::new();
    for (mode, mult) in [("custom", "fp32"), ("lut", "afm16"), ("lut", "bfloat16")] {
        let mut tr = Trainer::new(&mut engine, cfg("lenet300", mode, mult, 3), &dir).unwrap();
        let log = tr.fit(&train, &test).unwrap();
        accs.push(log.final_test_acc());
    }
    let (fp32, afm16, bf16) = (accs[0], accs[1], accs[2]);
    assert!((afm16 - fp32).abs() < 0.15, "AFM16 {afm16} vs FP32 {fp32}");
    assert!((bf16 - fp32).abs() < 0.15, "bf16 {bf16} vs FP32 {fp32}");
}

/// Checkpoint round trip through a second trainer (cross-format machinery).
#[test]
fn checkpoint_transfers_across_modes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let ds = mnist_like(&SynthSpec { n: 256, ..SynthSpec::mnist_like_default() });
    let (train, test) = ds.split(64);
    let mut tr = Trainer::new(&mut engine, cfg("lenet300", "lut", "afm16", 2), &dir).unwrap();
    tr.fit(&train, &test).unwrap();
    let acc_lut = tr.evaluate(&test).unwrap();
    let ckpt = tr.checkpoint().unwrap();
    // evaluate the same weights under the exact-multiplier artifact
    let mut tr2 = Trainer::new(&mut engine, cfg("lenet300", "custom", "fp32", 0), &dir).unwrap();
    tr2.load_checkpoint(&ckpt).unwrap();
    let acc_exact = tr2.evaluate(&test).unwrap();
    assert!(
        (acc_lut - acc_exact).abs() < 0.1,
        "cross-format eval diverged: {acc_lut} vs {acc_exact} (Table IV claim)"
    );
}

/// `evaluate` must cover 100% of the test set: a test set that is not a
/// multiple of the batch size (including one *smaller than a single
/// batch*, which used to panic in `Batcher::new`) evaluates every sample
/// exactly once via a padded final batch (padding cycles the real
/// samples), and only an empty test set is an error.
#[test]
fn evaluate_covers_partial_batches_and_errors_only_when_empty() {
    use approxtrain::data::Dataset;
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let ds = mnist_like(&SynthSpec { n: 256, ..SynthSpec::mnist_like_default() });
    let mut tr = Trainer::new(&mut engine, cfg("lenet300", "lut", "afm16", 0), &dir).unwrap();
    let batch = tr.batch_size();

    // a test set of batch + 3 samples: the 3 trailing samples must count
    let (_, test_odd) = ds.clone().split(batch + 3);
    let acc = tr.evaluate(&test_odd).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    // accuracy is a per-sample ratio over n, so acc * n is integral
    let scaled = acc * test_odd.n as f32;
    assert!((scaled - scaled.round()).abs() < 1e-3, "acc {acc} not k/{}", test_odd.n);

    // smaller than one batch: one padded batch, no panic, same invariant
    let (_, test_small) = ds.clone().split(batch / 2 + 1);
    let acc_small = tr.evaluate(&test_small).unwrap();
    let scaled = acc_small * test_small.n as f32;
    assert!((scaled - scaled.round()).abs() < 1e-3);

    // evaluation is deterministic (unshuffled, padding never scored)
    assert_eq!(tr.evaluate(&test_odd).unwrap(), acc);

    // empty test set: a proper error, not a panic
    let empty = Dataset {
        name: "empty".into(),
        images: Vec::new(),
        labels: Vec::new(),
        n: 0,
        h: ds.h,
        w: ds.w,
        c: ds.c,
        classes: ds.classes,
    };
    let err = tr.evaluate(&empty).unwrap_err();
    assert!(err.to_string().contains("empty"), "{err}");
}

/// The batching server answers every request exactly once with sane
/// logits through the engine (compiled-artifact) backend, served from
/// the caller's thread (the PJRT client is not `Send`).
#[test]
fn server_round_trip() {
    use approxtrain::coordinator::backend::{EngineBackend, InferBackend};
    use approxtrain::coordinator::server::{serve_on_caller, ServeConfig};
    use approxtrain::lut::MantissaLut;
    use approxtrain::nn::init::init_params;
    use approxtrain::util::json::Json;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let art = engine.manifest().find("lenet300", "fwd", "lut").unwrap().clone();
    let raw = Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap()).unwrap();
    let params = init_params(&art, 1, &raw).unwrap();
    let lut = MantissaLut::load(&dir.join("luts/afm16.lut")).unwrap();
    let mut backend = EngineBackend::new(engine, &art.name, params, Some(lut.entries)).unwrap();
    let image_elems = backend.image_elems();
    let classes = backend.classes();
    let answered = AtomicUsize::new(0);
    let n_requests = 10;
    let cfg = ServeConfig { max_wait: Duration::from_millis(2), queue_depth: 32 };
    let (stats, ()) = serve_on_caller(&mut backend, cfg, |client| {
        std::thread::scope(|s| {
            for _ in 0..2 {
                let client = client.clone();
                let answered = &answered;
                s.spawn(move || {
                    for _ in 0..n_requests / 2 {
                        let reply = client.infer(vec![0.5; image_elems]).unwrap();
                        assert_eq!(reply.logits.len(), classes);
                        assert!(reply.logits.iter().all(|v| v.is_finite()));
                        answered.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
    })
    .unwrap();
    assert_eq!(answered.load(Ordering::SeqCst), n_requests);
    assert_eq!(stats.requests, n_requests);
    assert!(stats.batches <= n_requests);
    assert_eq!(stats.rejected, 0);
}
