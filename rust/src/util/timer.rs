//! Micro-benchmark harness. The vendored dependency set has no `criterion`,
//! so the paper-table benches (`rust/benches/paper_benches.rs`) use this:
//! warmup, repeated timed runs, median/mean/stddev reporting.

use std::time::Instant;

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// seconds per iteration
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn stddev_s(&self) -> f64 {
        stats::stddev(&self.samples)
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples }
}

/// Adaptive version: keeps a minimum number of iterations but stops once
/// `budget_s` of measured time has been spent, so cheap and expensive cases
/// can share one harness.
pub fn bench_budget<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    budget_s: f64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let mut spent = 0.0;
    while samples.len() < min_iters || (spent < budget_s && samples.len() < 1000) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        spent += dt;
        if spent >= budget_s && samples.len() >= min_iters {
            break;
        }
    }
    BenchResult { name: name.to_string(), samples }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u32;
        let r = bench("t", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.samples.len(), 5);
        assert!(r.median_s() >= 0.0);
    }

    #[test]
    fn bench_budget_respects_min() {
        let r = bench_budget("t", 0, 3, 0.0, || {});
        assert!(r.samples.len() >= 3);
    }
}
