"""LeNet-300-100 (MLP) and LeNet-5 (CNN) — paper §VII *Neural Network
Architectures*. Dense layers use AMDENSE, convolutions AMCONV2D; pooling
and activations are exact (no multiplies, paper Table I)."""

from __future__ import annotations

from .. import layers
from .base import Model, conv_spec, dense_specs


def lenet300(input_shape=(28, 28, 1), classes=10) -> Model:
    """784-300-100-10 multi-layer perceptron."""
    h, w, c = input_shape
    n_in = h * w * c
    params = (dense_specs("fc1", n_in, 300)
              + dense_specs("fc2", 300, 100)
              + dense_specs("fc3", 100, classes))

    def apply(cfg, p, x, lut):
        x = x.reshape(x.shape[0], -1)
        x = layers.relu(layers.amdense(cfg, x, p["fc1/w"], p["fc1/b"], lut))
        x = layers.relu(layers.amdense(cfg, x, p["fc2/w"], p["fc2/b"], lut))
        return layers.amdense(cfg, x, p["fc3/w"], p["fc3/b"], lut)

    return Model("lenet300", input_shape, classes, params, apply)


def lenet5(input_shape=(28, 28, 1), classes=10) -> Model:
    """Two conv layers (6@5x5, 16@5x5) + three dense layers (120, 84, out).
    28x28 input with pad-2 first conv, 2x2 max-pools after each conv."""
    h, w, c = input_shape
    assert h % 4 == 0 and w % 4 == 0, "lenet5 needs /4 spatial dims"
    flat = (h // 4 - 2) * (w // 4 - 2) * 16  # 5x5 valid conv shrinks by 4
    params = ([conv_spec("conv1/w", 5, 5, c, 6), conv_spec("conv2/w", 5, 5, 6, 16)]
              + dense_specs("fc1", flat, 120)
              + dense_specs("fc2", 120, 84)
              + dense_specs("fc3", 84, classes))

    def apply(cfg, p, x, lut):
        x = layers.relu(layers.amconv2d(cfg, x, p["conv1/w"], 1, 2, lut))
        x = layers.maxpool2x2(x)
        x = layers.relu(layers.amconv2d(cfg, x, p["conv2/w"], 1, 0, lut))
        x = layers.maxpool2x2(x)
        x = x.reshape(x.shape[0], -1)
        x = layers.relu(layers.amdense(cfg, x, p["fc1/w"], p["fc1/b"], lut))
        x = layers.relu(layers.amdense(cfg, x, p["fc2/w"], p["fc2/b"], lut))
        return layers.amdense(cfg, x, p["fc3/w"], p["fc3/b"], lut)

    return Model("lenet5", input_shape, classes, params, apply)
