"""L1 Pallas kernels vs the pure-jnp oracle (``ref.py``) — the core
correctness signal, swept over shapes/modes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import lutgen, mults
from compile.fp_bits import quantize_mantissa
from compile.kernels import ref
from compile.kernels.amsim_gemm import am_gemm, pick_block, vmem_footprint_bytes
from compile.kernels.amsim_matvec import am_matvec

LUT_AFM16 = jnp.asarray(lutgen.generate(mults.by_name("afm16")))
LUT_MIT16 = jnp.asarray(lutgen.generate(mults.by_name("mit16")))


def rand_q(rng, shape, m=7, scale=2.0):
    return jnp.asarray(quantize_mantissa(
        rng.uniform(-scale, scale, shape).astype(np.float32), m))


MODES = ["native", "lut", "direct:afm16", "direct:mit16", "direct:realm16",
         "direct:bfloat16"]


@pytest.mark.parametrize("mode", MODES)
def test_gemm_kernel_matches_ref(mode):
    rng = np.random.default_rng(1)
    a = rand_q(rng, (57, 33))
    b = rand_q(rng, (33, 29))
    got = am_gemm(a, b, mode, LUT_AFM16 if mode == "lut" else None, 7)
    want = ref.gemm_ref(a, b, mode, LUT_AFM16 if mode == "lut" else None, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 70), st.integers(1, 70), st.integers(1, 70),
       st.sampled_from(["native", "lut", "direct:mit16"]),
       st.integers(0, 2**31 - 1))
def test_gemm_shape_sweep(m, k, n, mode, seed):
    """Hypothesis sweep over GEMM shapes incl. non-multiples of the block
    sizes (exercises the padding path)."""
    rng = np.random.default_rng(seed)
    a = rand_q(rng, (m, k))
    b = rand_q(rng, (k, n))
    lut = LUT_MIT16 if mode == "lut" else None
    got = am_gemm(a, b, mode, lut, 7, block=(16, 16, 16))
    want = ref.gemm_ref(a, b, mode, lut, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gemm_special_values():
    """Zeros and large magnitudes: AMSim flush/overflow semantics survive
    the kernel path."""
    a = jnp.asarray(np.array([[0.0, 1e30], [-1e30, 2.0]], dtype=np.float32))
    b = jnp.asarray(np.array([[0.0, 1.0], [1.0, 0.0]], dtype=np.float32))
    got = np.asarray(am_gemm(a, b, "lut", LUT_AFM16, 7))
    want = np.asarray(ref.gemm_ref(a, b, "lut", LUT_AFM16, 7))
    assert np.array_equal(got, want, equal_nan=True)


def test_gemm_lut_vs_direct_bitwise():
    """LUT simulation and direct bit math of the same design agree exactly
    (same contract as the Rust amsim tests)."""
    rng = np.random.default_rng(3)
    a = rand_q(rng, (40, 24))
    b = rand_q(rng, (24, 40))
    via_lut = np.asarray(am_gemm(a, b, "lut", LUT_AFM16, 7))
    direct = np.asarray(am_gemm(a, b, "direct:afm16"))
    np.testing.assert_array_equal(via_lut, direct)


@pytest.mark.parametrize("mode", ["native", "lut", "direct:realm16"])
def test_matvec_kernel_matches_ref(mode):
    rng = np.random.default_rng(4)
    w = rand_q(rng, (45, 37))
    x = rand_q(rng, (37,))
    got = am_matvec(w, x, mode, LUT_AFM16 if mode == "lut" else None, 7,
                    block_out=16)
    want = ref.matvec_ref(w, x, mode, LUT_AFM16 if mode == "lut" else None, 7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pick_block_respects_budget():
    from compile.kernels.amsim_gemm import ELEMWISE_BLOCK_BUDGET
    for (m, k, n) in [(25088, 25, 6), (32, 400, 120), (4096, 4096, 4096)]:
        bm, bk, bn = pick_block(m, k, n, "lut")
        assert bm * bk * bn <= ELEMWISE_BLOCK_BUDGET * 1.01
        assert bm >= 1 and bk >= 1 and bn >= 1


def test_vmem_footprint_model():
    native = vmem_footprint_bytes("native", block=(64, 64, 64))
    lut = vmem_footprint_bytes("lut", 7, block=(64, 64, 64))
    assert lut > native  # LUT residency + product block
    assert lut - native >= 65536  # at least the LUT itself
