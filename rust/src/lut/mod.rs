//! Mantissa-product lookup tables — paper §V-A, Algorithm 1.
//!
//! A LUT stores, for every pair of `m`-bit mantissas `(k, j)`, the 23-bit
//! mantissa field of the approximate product plus a carry bit at bit 23
//! (the "stored as 4 bytes" trick of the paper's footnote 1: entries are
//! pre-shifted to the FP32 mantissa position so simulation needs no shift).
//!
//! Generation probes the *black-box* functional model exactly as Algorithm 1
//! does: fixed non-special exponents, mantissas swept by the nested loop,
//! carry recovered by comparing the product's exponent against the
//! unnormalized sum of the operand exponents.

pub mod format;

use crate::mult::fpbits::{compose, decompose, FpParts, EXP_BIAS, MANT_BITS};
use crate::mult::ApproxMul;

/// Maximum tabulatable mantissa width (paper §V-B: 1..=12 bits; 12 bits is
/// a 2^24-entry, 64 MiB table).
pub const MAX_LUT_M: u32 = 12;

/// A generated mantissa-product LUT.
#[derive(Clone, Debug, PartialEq)]
pub struct MantissaLut {
    /// multiplier name this table was generated from
    pub mult_name: String,
    /// mantissa bit-width `m`
    pub m: u32,
    /// `2^(2m)` entries: `(carry << 23) | mantissa23`
    pub entries: Vec<u32>,
}

impl MantissaLut {
    /// Algorithm 1: generate the LUT by probing `model` as a black box.
    ///
    /// Panics if the model's mantissa width exceeds [`MAX_LUT_M`] (the paper
    /// simulates such designs directly instead).
    pub fn generate(model: &dyn ApproxMul) -> MantissaLut {
        let m = model.mantissa_bits();
        assert!(
            m <= MAX_LUT_M,
            "mantissa width {m} not tabulatable (max {MAX_LUT_M}); use direct simulation"
        );
        // Alg. 1 lines 2-4: arbitrary signs, non-special exponents with a
        // non-special unnormalized product exponent.
        let exp_a: u32 = 127; // N
        let exp_b: u32 = 127; // K; N + K - 127 = 127, all in [1, 254]
        let size = 1usize << (2 * m);
        let mut entries = vec![0u32; size];
        for k in 0..(1u32 << m) {
            for j in 0..(1u32 << m) {
                let a = compose(FpParts { sign: 0, exp: exp_a, mant: k << (MANT_BITS - m) });
                let b = compose(FpParts { sign: 0, exp: exp_b, mant: j << (MANT_BITS - m) });
                let c = model.mul(a, b); // line 8: black-box probe
                let pc = decompose(c);
                // lines 9-13: carry detection from the exponent
                let un_normalized = exp_a as i32 + exp_b as i32 - EXP_BIAS;
                let carry = if (pc.exp as i32) > un_normalized { 1u32 } else { 0 };
                entries[(k << m | j) as usize] = (carry << MANT_BITS) | pc.mant;
            }
        }
        MantissaLut { mult_name: model.name().to_string(), m, entries }
    }

    /// Number of entries (`2^(2m)`).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Size in bytes of the payload (paper quotes 65.53 kB for m=7).
    pub fn payload_bytes(&self) -> usize {
        self.entries.len() * 4
    }

    /// Validate structural invariants: correct size, carry bit only at bit
    /// 23, mantissa bits only in the top `m` positions.
    pub fn validate(&self) -> Result<(), String> {
        if self.m == 0 || self.m > MAX_LUT_M {
            return Err(format!("bad mantissa width {}", self.m));
        }
        if self.entries.len() != 1usize << (2 * self.m) {
            return Err(format!(
                "size {} != 2^(2*{})",
                self.entries.len(),
                self.m
            ));
        }
        let low_mask = (1u32 << (MANT_BITS - self.m)) - 1;
        for (i, &e) in self.entries.iter().enumerate() {
            if e >> (MANT_BITS + 1) != 0 {
                return Err(format!("entry {i} has bits above the carry: {e:#x}"));
            }
            if e & low_mask != 0 {
                return Err(format!("entry {i} has sub-m mantissa bits: {e:#x}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::registry;

    #[test]
    fn sizes_match_paper() {
        // paper: bfloat16 (m=7) -> 2^7 * 2^7 * 4 bytes = 65.53 kB
        let bf16 = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(bf16.as_ref());
        assert_eq!(lut.len(), 128 * 128);
        assert_eq!(lut.payload_bytes(), 65536);
        lut.validate().unwrap();
    }

    #[test]
    fn exact_lut_matches_direct_product() {
        // for the exact bfloat16 model the LUT entry must equal the RNE
        // mantissa product computed directly
        let bf16 = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(bf16.as_ref());
        for k in 0..128u32 {
            for j in 0..128u32 {
                let (carry, mant) = bf16.mantissa_product(k << 16, j << 16);
                let want = (carry << 23) | mant;
                assert_eq!(lut.entries[(k << 7 | j) as usize], want, "k={k} j={j}");
            }
        }
    }

    #[test]
    fn carry_detection_works_for_all_m7_models() {
        // black-box probing (Alg 1) must recover mantissa_product exactly
        for name in ["mit16", "afm16", "realm16", "trunc16", "comp16"] {
            let m = registry::by_name(name).unwrap();
            let lut = MantissaLut::generate(m.as_ref());
            lut.validate().unwrap();
            for k in (0..128u32).step_by(7) {
                for j in (0..128u32).step_by(5) {
                    let (carry, mant) = m.mantissa_product(k << 16, j << 16);
                    assert_eq!(
                        lut.entries[(k << 7 | j) as usize],
                        (carry << 23) | mant,
                        "{name} k={k} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "not tabulatable")]
    fn wide_mantissa_rejected() {
        let afm32 = registry::by_name("afm32").unwrap();
        MantissaLut::generate(afm32.as_ref());
    }

    #[test]
    fn validate_rejects_corruption() {
        let bf16 = registry::by_name("bfloat16").unwrap();
        let mut lut = MantissaLut::generate(bf16.as_ref());
        lut.entries[5] |= 1 << 30;
        assert!(lut.validate().is_err());
    }
}
