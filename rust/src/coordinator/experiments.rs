//! Experiment harness — regenerates every table and figure of the paper.
//! Each function returns a markdown section (recorded into EXPERIMENTS.md)
//! and writes CSV series under `results/`.
//!
//! The `quick` flag shrinks datasets/epochs for CI-speed runs; the full
//! settings are what EXPERIMENTS.md reports.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::report::{fmt_ratio, fmt_time, write_result, Table};
use super::trainer::{TrainConfig, Trainer};
use crate::data::synth::{self, SynthSpec};
use crate::data::Dataset;
use crate::hwmodel;
use crate::lut::MantissaLut;
use crate::mult::registry;
use crate::nn::cpu_lenet::{Lenet300, Lenet5};
use crate::runtime::executor::{Engine, Value};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use crate::util::stats;
use crate::util::timer::bench_budget;

/// The four multiplier columns of Fig 10 / Table III mapped to artifact
/// modes: (display name, artifact mode, multiplier for the LUT).
pub const TABLE3_MULTS: [(&str, &str, &str); 4] = [
    ("FP32", "custom", "fp32"),
    ("AFM32", "direct:afm32", "afm32"),
    ("bfloat16", "lut", "bfloat16"),
    ("AFM16", "lut", "afm16"),
];

/// Dataset/architecture combos of the paper (first two columns of
/// Table III), scaled per DESIGN.md §Substitutions.
pub fn combos(quick: bool) -> Vec<(&'static str, &'static str, usize, usize)> {
    // (dataset, model, train_n, epochs)
    if quick {
        vec![("mnist", "lenet300", 256, 2), ("mnist", "lenet5", 256, 2)]
    } else {
        vec![
            ("mnist", "lenet300", 1024, 6),
            ("mnist", "lenet5", 1024, 8),
            ("cifar10", "resnet18", 512, 5),
            ("cifar10", "resnet34", 512, 5),
            ("cifar10", "resnet50", 512, 5),
            ("imagenet", "resnet50i", 512, 4),
        ]
    }
}

pub fn dataset_for(name: &str, n: usize, seed: u64) -> Dataset {
    let mut spec = match name {
        "mnist" => SynthSpec::mnist_like_default(),
        "cifar10" => SynthSpec::cifar_like_default(),
        "imagenet" => SynthSpec::imagenet_like_default(),
        other => panic!("unknown dataset {other}"),
    };
    spec.n = n + n / 4; // test split = 20% of train size
    spec.seed = seed;
    synth::generate(name, &spec)
}

// ---------------------------------------------------------------------------
// Fig 1 — multiplier resource efficiency
// ---------------------------------------------------------------------------

pub fn fig1(results_dir: &Path) -> Result<String> {
    let mut t = Table::new(
        "Fig 1 — resource efficiency normalized to FP32 (higher is better)",
        &["multiplier", "area efficiency", "power efficiency"],
    );
    for (name, area, power) in hwmodel::fig1_series() {
        t.row(vec![name, format!("{area:.1}"), format!("{power:.1}")]);
    }
    write_result(results_dir, "fig1.csv", &t.to_csv())?;
    Ok(t.to_markdown())
}

// ---------------------------------------------------------------------------
// BENCH_gemm — CPU GEMM perf record: native vs direct vs LUT (à la Fig 6)
// ---------------------------------------------------------------------------

/// Benchmark the CPU GEMM kernel under the three simulation strategies and
/// emit the `BENCH_gemm.json` perf record (the repo's bench trajectory).
///
/// Rows per size — the panel (1D row-sliced, PR 1) and tiled (2D
/// cache-blocked packed, micro-kernel-drained) kernels for each strategy:
/// * `native` / `native_tiled` — hardware `*` (the ATnG baseline);
/// * `direct_afm16` / `direct_afm16_tiled` — per-multiply
///   functional-model calls (ATxC / "direct C simulation");
/// * `lut_afm16` / `lut_afm16_tiled` — batched AMSim LUT-gather panels
///   (ATxG), single lane; the tiled row drains through the default
///   `MR x NR` register-blocked micro-kernel;
/// * `lut_afm16_tiled_mr1nr1` — the tiled kernel with the micro-kernel
///   degenerated to `1 x 1` (the pre-micro-kernel per-element drain),
///   isolating the register-blocking win;
/// * `lut_scalar_dispatch` — the per-element-dispatch naive-loop oracle
///   ([`crate::kernels::gemm::gemm_scalar_reference`]), measuring the
///   dispatch + cache-blocking headroom the batched kernels close;
/// * `lut_pool` / `lut_tiled_pool` — the LUT paths over the persistent
///   worker pool's full width (row-blocks vs the 2D tile queue);
/// * `lut_afm16_tiled_simd_<level>` / `native_tiled_simd_<level>` — the
///   tiled micro-kernel path with the SIMD tier **forced per kernel
///   object** (`AmSim::with_simd` / `MulKernel::NativeAt`) for every
///   machine-executable [`crate::util::simd::SimdLevel`], isolating the
///   vector-arm win; the unsuffixed rows run at the process-wide active
///   level (detection, lowered by `APPROXTRAIN_SIMD`).
///
/// The record carries a top-level `simd` object (detected level, raw env
/// override, resolved active level) so a committed BENCH_gemm.json says
/// what silicon/tier produced it.
///
/// At the largest size an autotune probe times the LUT tiled path over
/// [`crate::kernels::gemm::TileConfig::AUTOTUNE_CANDIDATES`] — sweeping
/// the micro-tile shape `(mr, nr)` alongside the cache-tile shape — and
/// records the winner.
///
/// Before timing, every optimized path (panel, tiled at each probed
/// geometry, pool-threaded tiled) is asserted bit-identical to the scalar
/// `AmSim::mul`-per-element reference (the paper's §VI footnote 2
/// methodology), so the record can never report a fast-but-wrong kernel.
/// The schema-v5 sparsity sweep extends the same policy to structured
/// 0/50/90 % sparse operands: zero-skipping rows (multipliers with the
/// audited zero-identity flag) are gated against their own strategy's
/// scalar reference on the sparse inputs, and the native row doubles as a
/// check that a non-gated strategy never elides a pair.
///
/// Runs without artifacts — pure CPU path. Unlike the figure experiments
/// it never touches the PJRT engine.
pub fn bench_gemm(
    results_dir: &Path,
    max_size: usize,
    quick: bool,
    record_root: bool,
) -> Result<String> {
    use crate::amsim::AmSim;
    use crate::kernels::gemm::{
        gemm_panel, gemm_panel_threaded, gemm_scalar_reference, gemm_tiled_threaded,
        gemm_tiled_with, TileConfig,
    };
    use crate::kernels::{panel_pair_events, panel_skip_events, MulKernel};
    use crate::util::json::Json;
    use crate::util::simd::{self, SimdLevel};
    use crate::util::threads;

    let budget = if quick { 0.15 } else { 1.0 };
    let mut sizes: Vec<usize> = if quick { vec![32, 64] } else { vec![64, 128] };
    sizes.retain(|&s| s < max_size); // max_size is a hard ceiling (CI smoke relies on it)
    sizes.push(max_size);
    sizes.sort_unstable();
    sizes.dedup();

    let model = registry::by_name("afm16").ok_or_else(|| anyhow!("afm16 not registered"))?;
    let lut = MantissaLut::generate(model.as_ref());
    lut.validate().map_err(|e| anyhow!("generated afm16 LUT failed validation: {e}"))?;
    let lanes = threads::global().width();

    let mut table = Table::new(
        "BENCH_gemm — CPU GEMM simulation strategies (panel vs micro-kernel tiled)",
        &["size", "strategy", "time", "vs native", "vs scalar-dispatch LUT"],
    );
    let mut records: Vec<Json> = Vec::new();
    let mut autotune: Vec<Json> = Vec::new();
    let mut sparsity_rows: Vec<Json> = Vec::new();
    let mut best_cfg: Option<(f64, TileConfig)> = None;
    let mut headline_speedup = 0.0f64;
    let mut tiled_vs_panel = 0.0f64;
    let mut micro_vs_scalar_drain = 0.0f64;
    let mut simd_scalar_to_best = 0.0f64;
    let mut sparse_speedup = 0.0f64;
    // the default tile geometry with the micro-kernel degenerated to the
    // per-element drain — the ablation partner for the micro-kernel rows
    let cfg_mr1 = TileConfig { mr: 1, nr: 1, ..TileConfig::DEFAULT };
    let last_size = *sizes.last().unwrap();
    for &n in &sizes {
        let mut rng = Pcg32::seeded(2600 + n as u64);
        let a: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut c = vec![0.0f32; n * n];

        // correctness gate: every optimized LUT path == scalar AmSim::mul
        // applied elementwise with sequential accumulation, bit for bit
        let mut c_ref = vec![0.0f32; n * n];
        gemm_scalar_reference(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_ref, n, n, n);
        let gate = |label: &str, got: &[f32]| -> Result<()> {
            for i in 0..n * n {
                if got[i].to_bits() != c_ref[i].to_bits() {
                    return Err(anyhow!(
                        "bench aborted: {label} LUT GEMM diverged from scalar reference \
                         at n={n} idx {i}"
                    ));
                }
            }
            Ok(())
        };
        gemm_panel(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c, n, n, n);
        gate("panel", &c)?;
        gemm_tiled_with(
            &MulKernel::Lut(AmSim::new(&lut)),
            TileConfig::DEFAULT,
            &a,
            &b,
            &mut c,
            n,
            n,
            n,
            1,
        );
        gate("tiled (micro-kernel)", &c)?;
        gemm_tiled_with(&MulKernel::Lut(AmSim::new(&lut)), cfg_mr1, &a, &b, &mut c, n, n, n, 1);
        gate("tiled mr1nr1", &c)?;
        gemm_tiled_threaded(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c, n, n, n, lanes);
        gate("tiled_pool", &c)?;

        let timed = |strategy: &str, f: &mut dyn FnMut()| -> f64 {
            let r = bench_budget(strategy, 1, 3, budget, f);
            r.median_s()
        };
        let t_native = timed("native", &mut || {
            gemm_panel(&MulKernel::Native, &a, &b, &mut c, n, n, n);
        });
        let t_direct = timed("direct_afm16", &mut || {
            gemm_panel(&MulKernel::Direct(model.as_ref()), &a, &b, &mut c, n, n, n);
        });
        let t_lut = timed("lut_afm16", &mut || {
            gemm_panel(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c, n, n, n);
        });
        let t_scalar = timed("lut_scalar_dispatch", &mut || {
            gemm_scalar_reference(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c, n, n, n);
        });
        let t_pool = timed("lut_pool", &mut || {
            gemm_panel_threaded(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c, n, n, n, lanes);
        });
        let t_native_tiled = timed("native_tiled", &mut || {
            gemm_tiled_with(&MulKernel::Native, TileConfig::DEFAULT, &a, &b, &mut c, n, n, n, 1);
        });
        let t_direct_tiled = timed("direct_afm16_tiled", &mut || {
            gemm_tiled_with(
                &MulKernel::Direct(model.as_ref()),
                TileConfig::DEFAULT,
                &a,
                &b,
                &mut c,
                n,
                n,
                n,
                1,
            );
        });
        let t_lut_tiled = timed("lut_afm16_tiled", &mut || {
            gemm_tiled_with(
                &MulKernel::Lut(AmSim::new(&lut)),
                TileConfig::DEFAULT,
                &a,
                &b,
                &mut c,
                n,
                n,
                n,
                1,
            );
        });
        let t_lut_tiled_mr1 = timed("lut_afm16_tiled_mr1nr1", &mut || {
            gemm_tiled_with(&MulKernel::Lut(AmSim::new(&lut)), cfg_mr1, &a, &b, &mut c, n, n, n, 1);
        });
        let t_tiled_pool = timed("lut_tiled_pool", &mut || {
            gemm_tiled_threaded(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c, n, n, n, lanes);
        });

        // per-SIMD-level rows: the tiled micro-kernel path with the tier
        // forced per kernel object, every row behind the same
        // bit-exactness gate as the unsuffixed rows. The native rows are
        // gated against the native scalar-dispatch reference.
        let mut c_nat_ref = vec![0.0f32; n * n];
        gemm_scalar_reference(&MulKernel::Native, &a, &b, &mut c_nat_ref, n, n, n);
        let mut level_rows: Vec<(String, f64)> = Vec::new();
        let mut t_lut_level_scalar = f64::NAN;
        let mut t_lut_level_best = f64::INFINITY;
        for level in simd::available_levels() {
            let lut_label = format!("lut_afm16_tiled_simd_{}", level.name());
            gemm_tiled_with(
                &MulKernel::Lut(AmSim::with_simd(&lut, level)),
                TileConfig::DEFAULT,
                &a,
                &b,
                &mut c,
                n,
                n,
                n,
                1,
            );
            gate(&lut_label, &c)?;
            let t_l = timed(&lut_label, &mut || {
                gemm_tiled_with(
                    &MulKernel::Lut(AmSim::with_simd(&lut, level)),
                    TileConfig::DEFAULT,
                    &a,
                    &b,
                    &mut c,
                    n,
                    n,
                    n,
                    1,
                );
            });
            let nat_label = format!("native_tiled_simd_{}", level.name());
            gemm_tiled_with(
                &MulKernel::NativeAt(level),
                TileConfig::DEFAULT,
                &a,
                &b,
                &mut c,
                n,
                n,
                n,
                1,
            );
            for i in 0..n * n {
                if c[i].to_bits() != c_nat_ref[i].to_bits() {
                    return Err(anyhow!(
                        "bench aborted: {nat_label} diverged from native scalar reference \
                         at n={n} idx {i}"
                    ));
                }
            }
            let t_n = timed(&nat_label, &mut || {
                gemm_tiled_with(
                    &MulKernel::NativeAt(level),
                    TileConfig::DEFAULT,
                    &a,
                    &b,
                    &mut c,
                    n,
                    n,
                    n,
                    1,
                );
            });
            if level == SimdLevel::Scalar {
                t_lut_level_scalar = t_l;
            }
            t_lut_level_best = t_lut_level_best.min(t_l);
            level_rows.push((lut_label, t_l));
            level_rows.push((nat_label, t_n));
        }
        if n == last_size {
            simd_scalar_to_best = t_lut_level_scalar / t_lut_level_best;
        }

        for (strategy, t) in [
            ("native", t_native),
            ("direct_afm16", t_direct),
            ("lut_afm16", t_lut),
            ("lut_scalar_dispatch", t_scalar),
            ("lut_pool", t_pool),
            ("native_tiled", t_native_tiled),
            ("direct_afm16_tiled", t_direct_tiled),
            ("lut_afm16_tiled", t_lut_tiled),
            ("lut_afm16_tiled_mr1nr1", t_lut_tiled_mr1),
            ("lut_tiled_pool", t_tiled_pool),
        ] {
            table.row(vec![
                format!("{n}x{n}x{n}"),
                strategy.into(),
                fmt_time(t),
                fmt_ratio(t / t_native),
                fmt_ratio(t / t_scalar),
            ]);
            records.push(Json::obj(vec![
                ("m", Json::num(n as f64)),
                ("k", Json::num(n as f64)),
                ("n", Json::num(n as f64)),
                ("strategy", Json::str(strategy)),
                ("seconds_median", Json::num(t)),
                ("vs_native", Json::num(t / t_native)),
            ]));
        }
        for (strategy, t) in &level_rows {
            table.row(vec![
                format!("{n}x{n}x{n}"),
                strategy.clone(),
                fmt_time(*t),
                fmt_ratio(t / t_native),
                fmt_ratio(t / t_scalar),
            ]);
            records.push(Json::obj(vec![
                ("m", Json::num(n as f64)),
                ("k", Json::num(n as f64)),
                ("n", Json::num(n as f64)),
                ("strategy", Json::str(strategy)),
                ("seconds_median", Json::num(*t)),
                ("vs_native", Json::num(t / t_native)),
            ]));
        }
        if n == last_size {
            headline_speedup = t_scalar / t_lut;
            tiled_vs_panel = t_lut / t_lut_tiled;
            micro_vs_scalar_drain = t_lut_tiled_mr1 / t_lut_tiled;
            // tile + micro-tile autotune probe (LUT path, single lane):
            // gate each candidate geometry bit-exactly, then time it.
            // DEFAULT and the mr1nr1 ablation geometry were already gated
            // and timed above, so their measurements are reused.
            for cfg in TileConfig::AUTOTUNE_CANDIDATES {
                let t = if cfg == TileConfig::DEFAULT {
                    t_lut_tiled
                } else if cfg == cfg_mr1 {
                    t_lut_tiled_mr1
                } else {
                    let label = format!(
                        "tiled mc{} kc{} nc{} mr{} nr{}",
                        cfg.mc, cfg.kc, cfg.nc, cfg.mr, cfg.nr
                    );
                    gemm_tiled_with(
                        &MulKernel::Lut(AmSim::new(&lut)),
                        cfg,
                        &a,
                        &b,
                        &mut c,
                        n,
                        n,
                        n,
                        1,
                    );
                    gate(&label, &c)?;
                    timed(&format!("autotune {label}"), &mut || {
                        gemm_tiled_with(
                            &MulKernel::Lut(AmSim::new(&lut)),
                            cfg,
                            &a,
                            &b,
                            &mut c,
                            n,
                            n,
                            n,
                            1,
                        );
                    })
                };
                autotune.push(Json::obj(vec![
                    ("mc", Json::num(cfg.mc as f64)),
                    ("kc", Json::num(cfg.kc as f64)),
                    ("nc", Json::num(cfg.nc as f64)),
                    ("mr", Json::num(cfg.mr as f64)),
                    ("nr", Json::num(cfg.nr as f64)),
                    ("seconds_median", Json::num(t)),
                ]));
                if best_cfg.map_or(true, |(bt, _)| t < bt) {
                    best_cfg = Some((t, cfg));
                }
            }

            // pruning-aware sparsity sweep (schema v5): structured-sparse
            // operands — mr-aligned dead A row-groups and nr-aligned dead
            // B column bands, the shapes `magnitude_block_mask` produces —
            // at 0/50/90 %, per strategy. Gated strategies (direct/LUT
            // afm16) elide dead micro-panel pairs in the tile drain;
            // native has no zero identity and provably runs dense. Every
            // row is gated bit-exact against its own strategy's scalar
            // reference on the *sparse* inputs before timing, and the
            // drain counters are sampled around the gated run so each row
            // records its measured pair/skip census.
            let scfg = TileConfig::DEFAULT;
            let mut t_sparse_dense = f64::NAN;
            for &sparsity in &[0.0f32, 0.5, 0.9] {
                let mut srng = Pcg32::seeded(4800 + (sparsity * 100.0) as u64);
                let mut sa: Vec<f32> = (0..n * n).map(|_| srng.range(-1.0, 1.0)).collect();
                let mut sb: Vec<f32> = (0..n * n).map(|_| srng.range(-1.0, 1.0)).collect();
                let mut r0 = 0;
                while r0 < n {
                    let r1 = (r0 + scfg.mr).min(n);
                    if srng.range(0.0, 1.0) < sparsity {
                        sa[r0 * n..r1 * n].fill(0.0);
                    }
                    r0 = r1;
                }
                let mut j0 = 0;
                while j0 < n {
                    let j1 = (j0 + scfg.nr).min(n);
                    if srng.range(0.0, 1.0) < sparsity {
                        for kk in 0..n {
                            sb[kk * n + j0..kk * n + j1].fill(0.0);
                        }
                    }
                    j0 = j1;
                }
                for (strategy, mul) in [
                    ("native_tiled", MulKernel::Native),
                    ("direct_afm16_tiled", MulKernel::Direct(model.as_ref())),
                    ("lut_afm16_tiled", MulKernel::Lut(AmSim::new(&lut))),
                ] {
                    let mut sref = vec![0.0f32; n * n];
                    gemm_scalar_reference(&mul, &sa, &sb, &mut sref, n, n, n);
                    let (p0, s0) = (panel_pair_events(), panel_skip_events());
                    gemm_tiled_with(&mul, scfg, &sa, &sb, &mut c, n, n, n, 1);
                    let (pairs, skips) =
                        (panel_pair_events() - p0, panel_skip_events() - s0);
                    for i in 0..n * n {
                        if c[i].to_bits() != sref[i].to_bits() {
                            return Err(anyhow!(
                                "bench aborted: sparse {strategy} (sparsity {sparsity}) \
                                 diverged from its scalar reference at n={n} idx {i}"
                            ));
                        }
                    }
                    if !mul.zero_skip_ok() && skips != 0 {
                        return Err(anyhow!(
                            "bench aborted: {strategy} has no zero identity but the \
                             drain elided {skips} pairs"
                        ));
                    }
                    let t = timed(&format!("sparse_{strategy}_s{sparsity}"), &mut || {
                        gemm_tiled_with(&mul, scfg, &sa, &sb, &mut c, n, n, n, 1);
                    });
                    if strategy == "lut_afm16_tiled" {
                        if sparsity == 0.0 {
                            t_sparse_dense = t;
                        } else if sparsity == 0.9 {
                            sparse_speedup = t_sparse_dense / t;
                        }
                    }
                    table.row(vec![
                        format!("{n}x{n}x{n} s={:.0}%", sparsity * 100.0),
                        format!("sparse_{strategy}"),
                        fmt_time(t),
                        fmt_ratio(t / t_native),
                        fmt_ratio(t / t_scalar),
                    ]);
                    sparsity_rows.push(Json::obj(vec![
                        ("m", Json::num(n as f64)),
                        ("k", Json::num(n as f64)),
                        ("n", Json::num(n as f64)),
                        ("strategy", Json::str(strategy)),
                        ("sparsity", Json::num(sparsity as f64)),
                        ("zero_skip", Json::Bool(mul.zero_skip_ok())),
                        ("panel_pairs", Json::num(pairs as f64)),
                        ("panel_skips", Json::num(skips as f64)),
                        (
                            "skip_rate",
                            Json::num(if pairs == 0 {
                                0.0
                            } else {
                                skips as f64 / pairs as f64
                            }),
                        ),
                        ("seconds_median", Json::num(t)),
                    ]));
                }
            }
        }
    }

    let (best_t, best) = best_cfg.expect("autotune probed at least one config");
    let record = Json::obj(vec![
        ("schema", Json::str("approxtrain/bench_gemm/v5")),
        (
            "description",
            Json::str(
                "CPU GEMM time per call: native vs direct functional-model vs AMSim LUT \
                 (paper Fig 6 configurations on the ATxC substrate), panel vs tiled \
                 kernels; tiled rows drain through the MRxNR register-blocked \
                 micro-kernel (mr1nr1 row = per-element drain ablation; \
                 *_simd_<level> rows = forced SimdLevel, isolating the AVX2 \
                 vpgatherdd/FMA vector arms); sparsity_records sweep structured \
                 0/50/90% sparse operands per strategy with occupancy-bitmap \
                 zero-skipping active for multipliers carrying the audited \
                 zero-identity flag (native runs dense), each row gated bit-exact \
                 against its strategy's scalar reference and annotated with the \
                 drain's measured pair/skip census",
            ),
        ),
        (
            "simd",
            Json::obj(vec![
                ("detected", Json::str(SimdLevel::detected().name())),
                ("active", Json::str(simd::active().name())),
                (
                    "env",
                    match std::env::var(simd::ENV_KNOB) {
                        Ok(v) => Json::str(&v),
                        Err(_) => Json::Null,
                    },
                ),
                (
                    "levels",
                    Json::arr(simd::available_levels().iter().map(|l| Json::str(l.name()))),
                ),
            ]),
        ),
        ("multiplier", Json::str("afm16")),
        (
            "provenance",
            Json::str("measured in-process by approxtrain bench_gemm on this machine"),
        ),
        ("pool_lanes", Json::num(lanes as f64)),
        ("quick", Json::Bool(quick)),
        (
            "sizes",
            Json::arr(sizes.iter().map(|&s| Json::num(s as f64))),
        ),
        ("lut_batched_speedup_vs_scalar_dispatch", Json::num(headline_speedup)),
        ("lut_tiled_speedup_vs_panel", Json::num(tiled_vs_panel)),
        ("lut_micro_speedup_vs_scalar_drain", Json::num(micro_vs_scalar_drain)),
        ("lut_simd_speedup_scalar_to_best", Json::num(simd_scalar_to_best)),
        ("lut_sparse_speedup_90_vs_dense", Json::num(sparse_speedup)),
        ("sparsity_records", Json::Arr(sparsity_rows)),
        (
            "autotune",
            Json::obj(vec![
                ("size", Json::num(last_size as f64)),
                ("simd_level", Json::str(simd::active().name())),
                ("candidates", Json::Arr(autotune)),
                (
                    "best",
                    Json::obj(vec![
                        ("mc", Json::num(best.mc as f64)),
                        ("kc", Json::num(best.kc as f64)),
                        ("nc", Json::num(best.nc as f64)),
                        ("mr", Json::num(best.mr as f64)),
                        ("nr", Json::num(best.nr as f64)),
                        ("seconds_median", Json::num(best_t)),
                    ]),
                ),
            ]),
        ),
        ("records", Json::Arr(records)),
    ]);
    let payload = record.to_string();
    write_result(results_dir, "BENCH_gemm.json", &payload)?;
    if record_root {
        super::report::write_root_record("BENCH_gemm.json", &payload)?;
    }
    let mut md = table.to_markdown();
    md.push_str(&format!(
        "Batched LUT panels vs per-element dispatch at {last_size}: {headline_speedup:.2}x\n"
    ));
    md.push_str(&format!(
        "MRxNR micro-kernel vs per-element tile drain at {last_size}: \
         {micro_vs_scalar_drain:.2}x\n"
    ));
    md.push_str(&format!(
        "LUT tiled SIMD vector arm vs forced-scalar at {last_size}: \
         {simd_scalar_to_best:.2}x (detected {}, active {})\n",
        SimdLevel::detected().name(),
        simd::active().name()
    ));
    md.push_str(&format!(
        "Zero-skipping LUT tiled at 90% structured sparsity vs dense operands \
         at {last_size}: {sparse_speedup:.2}x\n"
    ));
    md.push_str(&format!(
        "Tiled vs panel LUT kernel at {last_size}: {tiled_vs_panel:.2}x \
         (autotune best: mc={} kc={} nc={} mr={} nr={})\n\n",
        best.mc, best.kc, best.nc, best.mr, best.nr
    ));
    Ok(md)
}

// ---------------------------------------------------------------------------
// BENCH_conv — implicit-GEMM conv vs materialized im2col (§VI-B fusion,
// completed: not even the fused-index cols matrix is materialized)
// ---------------------------------------------------------------------------

/// Benchmark the three conv GEMMs (forward, weight-grad, preceding-layer
/// grad) through the **implicit-GEMM** path — tiled panels packed
/// straight from the NHWC tensors via the fused im2col indexing — against
/// the kept materialized-im2col route, for the native and LUT strategies,
/// emitting the `BENCH_conv.json` perf record.
///
/// Before any timing, every (geometry, strategy, op) is gated bit-exact:
/// the implicit result must equal the materialized result to the last
/// bit, so the record can never report a fast-but-wrong kernel (same
/// policy as [`bench_gemm`]).
pub fn bench_conv(results_dir: &Path, quick: bool, record_root: bool) -> Result<String> {
    use crate::amsim::AmSim;
    use crate::kernels::{Conv2dGeom, MulKernel};
    use crate::layers::amconv2d;
    use crate::util::json::Json;

    let budget = if quick { 0.1 } else { 0.75 };
    let geoms: Vec<(&str, Conv2dGeom)> = if quick {
        vec![
            (
                "14x14x8_s1",
                Conv2dGeom {
                    batch: 4,
                    in_h: 14,
                    in_w: 14,
                    in_c: 8,
                    k_h: 3,
                    k_w: 3,
                    out_c: 16,
                    stride: 1,
                    pad: 1,
                },
            ),
            (
                "14x14x8_s2",
                Conv2dGeom {
                    batch: 4,
                    in_h: 14,
                    in_w: 14,
                    in_c: 8,
                    k_h: 3,
                    k_w: 3,
                    out_c: 16,
                    stride: 2,
                    pad: 1,
                },
            ),
        ]
    } else {
        vec![
            (
                "28x28x8_s1",
                Conv2dGeom {
                    batch: 16,
                    in_h: 28,
                    in_w: 28,
                    in_c: 8,
                    k_h: 3,
                    k_w: 3,
                    out_c: 16,
                    stride: 1,
                    pad: 1,
                },
            ),
            (
                "28x28x8_s2",
                Conv2dGeom {
                    batch: 16,
                    in_h: 28,
                    in_w: 28,
                    in_c: 8,
                    k_h: 3,
                    k_w: 3,
                    out_c: 16,
                    stride: 2,
                    pad: 1,
                },
            ),
        ]
    };

    let model = registry::by_name("afm16").ok_or_else(|| anyhow!("afm16 not registered"))?;
    let lut = MantissaLut::generate(model.as_ref());
    lut.validate().map_err(|e| anyhow!("generated afm16 LUT failed validation: {e}"))?;

    let mut table = Table::new(
        "BENCH_conv — implicit-GEMM conv vs materialized im2col",
        &["geometry", "op", "strategy", "implicit", "materialized", "materialized/implicit"],
    );
    let mut records: Vec<Json> = Vec::new();
    let mut lut_speedups: Vec<f64> = Vec::new();
    for (glabel, g) in &geoms {
        let mut rng = Pcg32::seeded(2700);
        let x = Tensor::from_vec(
            &[g.batch, g.in_h, g.in_w, g.in_c],
            (0..g.batch * g.in_h * g.in_w * g.in_c).map(|_| rng.range(-1.0, 1.0)).collect(),
        );
        let w = Tensor::from_vec(
            &[g.k_h, g.k_w, g.in_c, g.out_c],
            (0..g.k_h * g.k_w * g.in_c * g.out_c).map(|_| rng.range(-1.0, 1.0)).collect(),
        );
        let dy_len = g.batch * g.out_h() * g.out_w() * g.out_c;
        let dy = Tensor::from_vec(
            &[g.batch, g.out_h(), g.out_w(), g.out_c],
            (0..dy_len).map(|_| rng.range(-1.0, 1.0)).collect(),
        );
        for (sname, mul) in [
            ("native", MulKernel::Native),
            ("lut_afm16", MulKernel::Lut(AmSim::new(&lut))),
        ] {
            // correctness gate: implicit == materialized, bit for bit
            #[allow(clippy::type_complexity)]
            let ops: [(&str, Box<dyn Fn() -> Tensor + '_>, Box<dyn Fn() -> Tensor + '_>); 3] = [
                (
                    "forward",
                    Box::new(|| amconv2d::forward(&mul, &x, &w, g.stride, g.pad)),
                    Box::new(|| amconv2d::forward_materialized(&mul, &x, &w, g.stride, g.pad)),
                ),
                (
                    "weight_grad",
                    Box::new(|| amconv2d::weight_grad(&mul, &x, &dy, &w.shape, g.stride, g.pad)),
                    Box::new(|| {
                        amconv2d::weight_grad_materialized(&mul, &x, &dy, &w.shape, g.stride, g.pad)
                    }),
                ),
                (
                    "input_grad",
                    Box::new(|| amconv2d::input_grad(&mul, &dy, &w, &x.shape, g.stride, g.pad)),
                    Box::new(|| {
                        amconv2d::input_grad_materialized(&mul, &dy, &w, &x.shape, g.stride, g.pad)
                    }),
                ),
            ];
            for (op, implicit, materialized) in &ops {
                let got = implicit();
                let want = materialized();
                for i in 0..want.len() {
                    if got.data[i].to_bits() != want.data[i].to_bits() {
                        return Err(anyhow!(
                            "bench aborted: implicit {op} diverged from materialized \
                             at {glabel}/{sname} idx {i}"
                        ));
                    }
                }
                let t_imp = bench_budget(&format!("{op}/implicit"), 1, 3, budget, || {
                    std::hint::black_box(implicit());
                })
                .median_s();
                let t_mat = bench_budget(&format!("{op}/materialized"), 1, 3, budget, || {
                    std::hint::black_box(materialized());
                })
                .median_s();
                table.row(vec![
                    (*glabel).into(),
                    (*op).into(),
                    sname.into(),
                    fmt_time(t_imp),
                    fmt_time(t_mat),
                    fmt_ratio(t_mat / t_imp),
                ]);
                for (route, t) in [("implicit", t_imp), ("materialized", t_mat)] {
                    records.push(Json::obj(vec![
                        ("geometry", Json::str(glabel)),
                        ("batch", Json::num(g.batch as f64)),
                        ("stride", Json::num(g.stride as f64)),
                        ("pad", Json::num(g.pad as f64)),
                        ("op", Json::str(op)),
                        ("strategy", Json::str(sname)),
                        ("route", Json::str(route)),
                        ("seconds_median", Json::num(t)),
                    ]));
                }
                if sname == "lut_afm16" {
                    lut_speedups.push(t_mat / t_imp);
                }
            }
        }
    }
    let headline = stats::geomean(&lut_speedups);
    let record = Json::obj(vec![
        ("schema", Json::str("approxtrain/bench_conv/v1")),
        (
            "description",
            Json::str(
                "conv forward/weight-grad/input-grad time per call: implicit-GEMM \
                 (im2col fused into tiled-GEMM packing, no cols matrix) vs the \
                 materialized im2col route (paper §VI-B fusion, completed)",
            ),
        ),
        ("multiplier", Json::str("afm16")),
        (
            "provenance",
            Json::str("measured in-process by approxtrain bench_conv on this machine"),
        ),
        ("quick", Json::Bool(quick)),
        ("materialized_over_implicit_geomean_lut", Json::num(headline)),
        ("records", Json::Arr(records)),
    ]);
    let payload = record.to_string();
    write_result(results_dir, "BENCH_conv.json", &payload)?;
    if record_root {
        super::report::write_root_record("BENCH_conv.json", &payload)?;
    }
    let mut md = table.to_markdown();
    md.push_str(&format!(
        "Materialized-over-implicit geomean (LUT strategy, all ops): {headline:.2}x\n\n"
    ));
    Ok(md)
}

// ---------------------------------------------------------------------------
// BENCH_serve — multi-lane batching inference server sweep
// ---------------------------------------------------------------------------

/// Benchmark the multi-lane batching server over the pure-Rust executor
/// backend (`lenet300`, no artifacts needed): offered-load sweep × lanes
/// {1, 2, 4} × simulation strategy (native / direct / LUT), emitting the
/// `BENCH_serve.json` perf record (schema v2).
///
/// Load is closed-loop: `clients` threads each submit their share of the
/// request stream and block for the reply (or count a typed rejection —
/// the admission queue is bounded, so the top load level exercises
/// backpressure). Per run the record keeps throughput, p50/p99 latency,
/// mean batch fill and the reject rate.
///
/// **Correctness gates** (same fast-but-wrong policy as [`bench_gemm`]):
/// (1) every accepted reply of every run — any lane count, any load —
/// must be **bit-identical** to a single-lane full-batch reference
/// forward of the same image (pins N-lane ≡ 1-lane); (2) a dedicated
/// padding regression gate serves a partial batch on a
/// batch-statistics-batchnorm resnet backend — where pad contents reach
/// every real reply, unlike the row-independent lenet — and requires it
/// to be bit-identical to the same images in a full batch of themselves,
/// with a zero-pad-must-differ teeth check. A single differing bit
/// aborts the bench.
/// With `include_net`, a second sweep drives the same workload through
/// the networked tier ([`super::net`]) over loopback sockets —
/// connections × lanes × a mixed-priority request stream — recording
/// throughput, latency percentiles, shed rate, and deadline-miss rate
/// per run (schema v2's `net_records`). The same bit-gate applies:
/// every accepted networked reply must match the reference bits.
pub fn bench_serve(
    results_dir: &Path,
    quick: bool,
    record_root: bool,
    include_net: bool,
) -> Result<String> {
    use std::time::{Duration, Instant};

    use super::backend::{CpuBackend, InferBackend, MulSpec};
    use super::server::{serve_pool, InferError, Reply, ServeConfig};
    use crate::util::json::Json;

    const MODEL: &str = "lenet300";
    const SEED: u64 = 4242;
    let batch = 16usize;
    // depth 16 = one batch: the 32-client load level overruns it and
    // exercises typed rejection; the low levels never do
    let queue_depth = 16usize;
    let max_wait = Duration::from_millis(3);
    let lanes_sweep: [usize; 3] = [1, 2, 4];
    let modes: [&str; 3] = ["native", "direct:afm16", "lut:afm16"];
    let clients_sweep: &[usize] = if quick { &[2, 32] } else { &[2, 8, 32] };
    // deliberately NOT a multiple of `batch`, so the reference forward
    // exercises the trailing-batch cycle padding on every run
    let n_req = if quick { 61 } else { 250 };

    let ds = dataset_for("mnist", n_req, SEED);
    crate::kernels::gemm::warm_tiled();

    // Padding regression gate (the headline bugfix) — lenet300's rows
    // are batch-independent, so the throughput sweep below cannot see
    // pad contents; this gate runs a batch-statistics batchnorm model
    // where they reach every real reply. A partially-filled served
    // batch must be bit-identical to the same images in a full batch of
    // themselves (cycled), and zero-row padding must NOT be (else the
    // gate has no teeth).
    {
        use super::server::Reply as SReply;
        let base = CpuBackend::for_model("resnet18", MulSpec::Native, 4, SEED)?;
        let sz = base.image_elems();
        let classes = base.classes();
        let mut rng = Pcg32::seeded(SEED ^ 0xBA7);
        let imgs: Vec<Vec<f32>> =
            (0..2).map(|_| (0..sz).map(|_| rng.uniform()).collect()).collect();
        let mut lane = base.replicas(1);
        let cfg = ServeConfig { max_wait: Duration::from_millis(400), queue_depth: 8 };
        let imgs_ref = &imgs;
        let (pstats, (r0, r1)): (_, (SReply, SReply)) =
            serve_pool(&mut lane, cfg, |client| {
                std::thread::scope(|s| {
                    let c0 = client.clone();
                    let first = s.spawn(move || c0.infer(imgs_ref[0].clone()).expect("pad req 0"));
                    std::thread::sleep(Duration::from_millis(40));
                    let c1 = client.clone();
                    let second =
                        s.spawn(move || c1.infer(imgs_ref[1].clone()).expect("pad req 1"));
                    (first.join().unwrap(), second.join().unwrap())
                })
            })?;
        if pstats.batches != 1 || r0.batch_fill != 2 {
            return Err(anyhow!(
                "bench aborted: padding gate could not form one partial batch \
                 (batches {}, fill {})",
                pstats.batches,
                r0.batch_fill
            ));
        }
        let mut reference = base.replicas(1).pop().unwrap();
        let mut full = Vec::with_capacity(4 * sz);
        for k in 0..4 {
            full.extend_from_slice(&imgs[k % 2]);
        }
        let want = reference.run_batch(&full)?;
        let same = |got: &[f32], want: &[f32]| {
            got.len() == want.len()
                && got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits())
        };
        if !same(&r0.logits, &want[..classes]) || !same(&r1.logits, &want[classes..2 * classes]) {
            return Err(anyhow!(
                "bench aborted: partial-batch replies diverge from the same images \
                 served in a full batch of themselves — the cycle-padding policy broke"
            ));
        }
        let mut zeroed = Vec::with_capacity(4 * sz);
        for img in &imgs {
            zeroed.extend_from_slice(img);
        }
        zeroed.resize(4 * sz, 0.0);
        let corrupted = reference.run_batch(&zeroed)?;
        if same(&corrupted[..2 * classes], &want[..2 * classes]) {
            return Err(anyhow!(
                "bench aborted: zero-row padding did not perturb the batch-stats \
                 batchnorm — the padding gate has no teeth on this model"
            ));
        }
    }

    let mut table = Table::new(
        "BENCH_serve — multi-lane batching server (CPU executor backend, lenet300)",
        &["mode", "lanes", "clients", "throughput", "p50", "p99", "mean fill", "reject rate"],
    );
    let mut records: Vec<Json> = Vec::new();
    let mut lut_thr_by_lanes: Vec<(usize, f64)> = Vec::new();
    let top_clients = *clients_sweep.last().unwrap();

    for mode in modes {
        let spec = MulSpec::parse(mode)?;
        let base = CpuBackend::for_model(MODEL, spec, batch, SEED)?;
        // single-lane reference: direct full-batch forwards over the
        // request stream, trailing batch padded by cycling (the serving
        // lanes' policy) — row independence makes this the canonical
        // answer for every batching schedule
        let mut reference = base.clone();
        let classes = reference.classes();
        let sz = reference.image_elems();
        let mut ref_logits: Vec<Vec<f32>> = Vec::with_capacity(n_req);
        let mut pos = 0usize;
        while pos < n_req {
            let real = (n_req - pos).min(batch);
            let mut images = Vec::with_capacity(batch * sz);
            for i in 0..real {
                images.extend_from_slice(ds.image(pos + i));
            }
            crate::data::pad_batch_by_cycling(&mut images, real, batch, sz);
            let logits = reference.run_batch(&images)?;
            for i in 0..real {
                ref_logits.push(logits[i * classes..(i + 1) * classes].to_vec());
            }
            pos += real;
        }

        for lanes in lanes_sweep {
            for &clients in clients_sweep {
                let mut backends = base.replicas(lanes);
                let cfg = ServeConfig { max_wait, queue_depth };
                let t0 = Instant::now();
                let (stats, outcomes) = serve_pool(&mut backends, cfg, |client| {
                    std::thread::scope(|s| {
                        let handles: Vec<_> = (0..clients)
                            .map(|t| {
                                let client = client.clone();
                                let ds = &ds;
                                s.spawn(move || {
                                    let mut out: Vec<(usize, Result<Reply, InferError>)> =
                                        Vec::new();
                                    let mut i = t;
                                    while i < n_req {
                                        out.push((i, client.infer(ds.image(i).to_vec())));
                                        i += clients;
                                    }
                                    out
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .flat_map(|h| h.join().expect("client thread panicked"))
                            .collect::<Vec<_>>()
                    })
                })?;
                let wall = t0.elapsed().as_secs_f64();

                let run = format!("{mode} lanes={lanes} clients={clients}");
                let (mut accepted, mut rejected) = (0u64, 0u64);
                for (idx, outcome) in &outcomes {
                    match outcome {
                        Ok(reply) => {
                            accepted += 1;
                            let want = &ref_logits[*idx];
                            let same = reply.logits.len() == want.len()
                                && reply
                                    .logits
                                    .iter()
                                    .zip(want)
                                    .all(|(a, b)| a.to_bits() == b.to_bits());
                            if !same {
                                return Err(anyhow!(
                                    "bench aborted: {run}: reply for request {idx} diverged \
                                     from the single-lane reference bits"
                                ));
                            }
                        }
                        Err(InferError::Rejected { .. }) => rejected += 1,
                        Err(e) => return Err(anyhow!("bench aborted: {run}: {e}")),
                    }
                }
                if accepted as usize != stats.requests || rejected != stats.rejected {
                    return Err(anyhow!(
                        "{run}: stats disagree with client outcomes \
                         ({}/{} vs {accepted}/{rejected})",
                        stats.requests,
                        stats.rejected
                    ));
                }
                let throughput = accepted as f64 / wall.max(1e-9);
                if mode == "lut:afm16" && clients == top_clients {
                    lut_thr_by_lanes.push((lanes, throughput));
                }
                table.row(vec![
                    mode.into(),
                    lanes.to_string(),
                    clients.to_string(),
                    format!("{throughput:.0} req/s"),
                    fmt_time(stats.latency_percentile_s(50.0)),
                    fmt_time(stats.latency_percentile_s(99.0)),
                    format!("{:.1}/{batch}", stats.mean_fill()),
                    format!("{:.1}%", stats.reject_rate() * 100.0),
                ]);
                records.push(Json::obj(vec![
                    ("mode", Json::str(mode)),
                    ("lanes", Json::num(lanes as f64)),
                    ("clients", Json::num(clients as f64)),
                    ("offered", Json::num(n_req as f64)),
                    ("accepted", Json::num(accepted as f64)),
                    ("rejected", Json::num(rejected as f64)),
                    ("reject_rate", Json::num(stats.reject_rate())),
                    ("wall_s", Json::num(wall)),
                    ("throughput_rps", Json::num(throughput)),
                    ("p50_ms", Json::num(stats.latency_percentile_s(50.0) * 1e3)),
                    ("p99_ms", Json::num(stats.latency_percentile_s(99.0) * 1e3)),
                    ("mean_latency_ms", Json::num(stats.mean_latency_s() * 1e3)),
                    ("mean_fill", Json::num(stats.mean_fill())),
                    ("batches", Json::num(stats.batches as f64)),
                ]));
            }
        }
    }

    // networked-tier sweep (schema v2): same workload over loopback
    // TCP through coordinator::net, mixed priorities, per-run shed and
    // deadline-miss accounting, same bit-gate
    let (net_md, net_records) =
        if include_net { bench_serve_net_sweep(&ds, quick)? } else { (String::new(), Vec::new()) };

    let thr_at = |lanes: usize| {
        lut_thr_by_lanes.iter().find(|(l, _)| *l == lanes).map(|(_, t)| *t).unwrap_or(0.0)
    };
    let headline = thr_at(4) / thr_at(1).max(1e-9);
    let record = Json::obj(vec![
        ("schema", Json::str("approxtrain/bench_serve/v2")),
        (
            "description",
            Json::str(
                "multi-lane batching inference server over the pure-Rust executor \
                 backend: closed-loop offered-load sweep x lanes x simulation \
                 strategy; every accepted reply bit-exactness-gated against a \
                 single-lane full-batch reference forward",
            ),
        ),
        ("model", Json::str(MODEL)),
        ("multiplier", Json::str("afm16")),
        (
            "provenance",
            Json::str("measured in-process by approxtrain bench_serve on this machine"),
        ),
        ("quick", Json::Bool(quick)),
        ("batch", Json::num(batch as f64)),
        ("queue_depth", Json::num(queue_depth as f64)),
        ("max_wait_ms", Json::num(max_wait.as_secs_f64() * 1e3)),
        ("requests_per_run", Json::num(n_req as f64)),
        ("lanes_swept", Json::arr(lanes_sweep.iter().map(|&l| Json::num(l as f64)))),
        ("clients_swept", Json::arr(clients_sweep.iter().map(|&c| Json::num(c as f64)))),
        ("lut_lanes4_speedup_vs_lanes1", Json::num(headline)),
        ("records", Json::Arr(records)),
        ("net_included", Json::Bool(include_net)),
        ("net_records", Json::Arr(net_records)),
    ]);
    let payload = record.to_string();
    write_result(results_dir, "BENCH_serve.json", &payload)?;
    if record_root {
        super::report::write_root_record("BENCH_serve.json", &payload)?;
    }
    let mut md = table.to_markdown();
    md.push_str(&format!(
        "LUT serving throughput, 4 lanes vs 1 lane at {top_clients} clients: {headline:.2}x\n\n"
    ));
    md.push_str(&net_md);
    Ok(md)
}

/// The networked half of [`bench_serve`]: a loopback TCP sweep through
/// the fault-tolerant serving tier — `connections x lanes x strategy`
/// with a mixed-priority request stream and per-request deadlines on the
/// wire. Emits one record per run with throughput, server-side latency
/// percentiles, shed rate (by the priority-aware admission limits), and
/// deadline-miss rate.
///
/// **Correctness gates**: (1) every accepted reply must be bit-identical
/// to the full-batch cycle-padded reference forward of its image —
/// crossing a socket must never change a single logit bit; (2) the
/// server's exact accounting must agree with the client-observed
/// outcomes (accepted replies = `replied_ok`).
fn bench_serve_net_sweep(
    ds: &crate::data::Dataset,
    quick: bool,
) -> Result<(String, Vec<crate::util::json::Json>)> {
    use std::time::{Duration, Instant};

    use super::backend::{CpuBackend, InferBackend, MulSpec};
    use super::net::{spawn, NetClient, NetConfig, NetRegistry, RetryPolicy, TenantSpec};
    use super::server::{InferError, ServeConfig};
    use super::wire::Priority;
    use crate::util::json::Json;

    const SEED: u64 = 4242;
    let batch = 8usize;
    let queue_depth = 16usize;
    let lanes_sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let conns_sweep: &[usize] = if quick { &[2, 8] } else { &[2, 8, 24] };
    let modes: [&str; 2] = ["native", "lut:afm16"];
    // NOT a multiple of `batch`: the trailing batch exercises cycle
    // padding on every run, keeping the bit-gate honest
    let n_req = (if quick { 45 } else { 189 }).min(ds.n);
    let deadline = Duration::from_secs(10); // carried on the wire; ~never missed
    let retry = RetryPolicy {
        max_attempts: 3,
        base_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(16),
        sleep: true,
    };

    let mut table = Table::new(
        "BENCH_serve net tier — loopback TCP, mixed priorities, per-request deadlines",
        &["mode", "lanes", "conns", "throughput", "p50", "p99", "shed rate", "ddl miss"],
    );
    let mut records: Vec<Json> = Vec::new();
    for mode in modes {
        let base = CpuBackend::for_model("lenet300", MulSpec::parse(mode)?, batch, SEED)?;
        // reference bits: full-batch forwards with the lanes' cycle
        // padding (row independence makes this canonical for every
        // batching schedule the race produces)
        let mut reference = base.clone();
        let classes = reference.classes();
        let sz = reference.image_elems();
        let mut ref_logits: Vec<Vec<f32>> = Vec::with_capacity(n_req);
        let mut pos = 0usize;
        while pos < n_req {
            let real = (n_req - pos).min(batch);
            let mut images = Vec::with_capacity(batch * sz);
            for i in 0..real {
                images.extend_from_slice(ds.image(pos + i));
            }
            crate::data::pad_batch_by_cycling(&mut images, real, batch, sz);
            let logits = reference.run_batch(&images)?;
            for i in 0..real {
                ref_logits.push(logits[i * classes..(i + 1) * classes].to_vec());
            }
            pos += real;
        }

        for &lanes in lanes_sweep {
            for &conns in conns_sweep {
                let run = format!("net {mode} lanes={lanes} conns={conns}");
                let mut reg = NetRegistry::new();
                reg.add("bench", base.clone(), TenantSpec { lanes, quota: 0 })?;
                let cfg = NetConfig {
                    serve: ServeConfig { max_wait: Duration::from_millis(3), queue_depth },
                    ..NetConfig::default()
                };
                let handle = spawn("127.0.0.1:0", reg, cfg, super::faults::FaultPlan::none())?;
                let addr = handle.addr();
                let t0 = Instant::now();
                // closed-loop load: `conns` persistent connections, each
                // a synchronous client cycling the priority classes
                let outcomes: Vec<(usize, Result<Vec<f32>, InferError>)> =
                    std::thread::scope(|s| {
                        let handles: Vec<_> = (0..conns)
                            .map(|t| {
                                s.spawn(move || {
                                    let mut client = NetClient::connect(addr, "bench", retry)
                                        .expect("bench client connect");
                                    let mut out = Vec::new();
                                    let mut i = t;
                                    while i < n_req {
                                        let prio = Priority::ALL[i % 3];
                                        let r = client
                                            .infer(ds.image(i), prio, Some(deadline))
                                            .map(|rep| rep.logits);
                                        out.push((i, r));
                                        i += conns;
                                    }
                                    out
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .flat_map(|h| h.join().expect("bench client panicked"))
                            .collect()
                    });
                let wall = t0.elapsed().as_secs_f64();
                let report = handle.shutdown()?;
                if !report.lane_errors.is_empty() {
                    return Err(anyhow!("bench aborted: {run}: {:?}", report.lane_errors));
                }

                let (mut accepted, mut shed_final, mut missed) = (0u64, 0u64, 0u64);
                for (idx, outcome) in &outcomes {
                    match outcome {
                        Ok(logits) => {
                            accepted += 1;
                            let want = &ref_logits[*idx];
                            let same = logits.len() == want.len()
                                && logits.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
                            if !same {
                                return Err(anyhow!(
                                    "bench aborted: {run}: networked reply for request {idx} \
                                     diverged from the reference bits"
                                ));
                            }
                        }
                        Err(InferError::Shed { .. }) | Err(InferError::Overloaded) => {
                            shed_final += 1
                        }
                        Err(InferError::DeadlineExceeded) => missed += 1,
                        Err(e) => return Err(anyhow!("bench aborted: {run}: {e}")),
                    }
                }
                let c = &report.counts;
                if accepted != c.replied_ok {
                    return Err(anyhow!(
                        "{run}: client saw {accepted} accepted replies, server counted {}",
                        c.replied_ok
                    ));
                }
                // admission attempts: accepted (which later resolves to a
                // reply or an in-queue/post-compute expiry) + the pre-queue
                // terminal outcomes; expired_queue/expired_reply are already
                // inside `accepted`, so only the admission-site expiry adds
                let offered =
                    (c.accepted + c.shed_total() + c.overflow + c.expired_admission) as f64;
                let shed_rate = (c.shed_total() + c.overflow) as f64 / offered.max(1.0);
                let miss_rate = c.deadline_expired_total() as f64 / offered.max(1.0);
                let throughput = accepted as f64 / wall.max(1e-9);
                table.row(vec![
                    mode.into(),
                    lanes.to_string(),
                    conns.to_string(),
                    format!("{throughput:.0} req/s"),
                    fmt_time(report.stats.latency_percentile_s(50.0)),
                    fmt_time(report.stats.latency_percentile_s(99.0)),
                    format!("{:.1}%", shed_rate * 100.0),
                    format!("{:.1}%", miss_rate * 100.0),
                ]);
                records.push(Json::obj(vec![
                    ("mode", Json::str(mode)),
                    ("lanes", Json::num(lanes as f64)),
                    ("connections", Json::num(conns as f64)),
                    ("requests", Json::num(n_req as f64)),
                    ("accepted", Json::num(accepted as f64)),
                    ("shed", Json::num(c.shed_total() as f64)),
                    ("overflow", Json::num(c.overflow as f64)),
                    ("shed_rate", Json::num(shed_rate)),
                    ("deadline_missed", Json::num(c.deadline_expired_total() as f64)),
                    ("deadline_miss_rate", Json::num(miss_rate)),
                    ("client_shed_after_retries", Json::num(shed_final as f64)),
                    ("client_deadline_errors", Json::num(missed as f64)),
                    ("wall_s", Json::num(wall)),
                    ("throughput_rps", Json::num(throughput)),
                    ("p50_ms", Json::num(report.stats.latency_percentile_s(50.0) * 1e3)),
                    ("p99_ms", Json::num(report.stats.latency_percentile_s(99.0) * 1e3)),
                    ("mean_fill", Json::num(report.stats.mean_fill())),
                ]));
            }
        }
    }
    Ok((table.to_markdown(), records))
}

// ---------------------------------------------------------------------------
// BENCH_train — data-parallel deterministic training sweep
// ---------------------------------------------------------------------------

/// Benchmark deterministic data-parallel training
/// (`coordinator::data_parallel`): worker counts {1, 2, 4} × simulation
/// strategy (native / direct / LUT) × model, emitting the
/// `BENCH_train.json` perf record (schema v1) with steps/sec and scaling
/// efficiency per run.
///
/// **Correctness gate** (same fast-but-wrong policy as the other
/// benches): every multi-worker run must produce a per-step
/// (loss, accuracy) curve *and* final flat parameters **bit-identical**
/// to the 1-worker run of the same configuration — the module's N≡1
/// determinism contract. A single differing bit aborts the bench before
/// any record is written.
pub fn bench_train(results_dir: &Path, quick: bool, record_root: bool) -> Result<String> {
    use std::time::Instant;

    use super::backend::MulSpec;
    use super::data_parallel::{DpConfig, DpTrainer};
    use crate::data::Batcher;
    use crate::util::json::Json;

    const SEED: u64 = 1717;
    let workers_sweep: [usize; 3] = [1, 2, 4];
    let modes: [&str; 3] = ["native", "direct:afm16", "lut:afm16"];
    let models: &[&str] = if quick { &["lenet300"] } else { &["lenet300", "lenet5"] };
    let batch = if quick { 16usize } else { 32 };
    let shard = 4usize;
    let steps = if quick { 3usize } else { 10 };
    let lr = 0.05f32;

    // pool spawn + tiled-GEMM warmup outside every timed region
    crate::kernels::gemm::warm_tiled();
    let _ = crate::util::threads::global();

    let mut table = Table::new(
        "BENCH_train — data-parallel deterministic training (fixed-order reduction tree)",
        &["model", "mode", "workers", "steps/s", "samples/s", "speedup", "efficiency"],
    );
    let mut records: Vec<Json> = Vec::new();
    let mut headline = 0.0f64;

    for &model in models {
        let ds = dataset_for(dataset_of(model), batch * steps, SEED);
        let stream: Vec<(Vec<f32>, Vec<u32>)> =
            Batcher::new(&ds, batch, SEED, 0).take(steps).collect();
        if stream.len() < steps {
            return Err(anyhow!("{model}: dataset yielded {} of {steps} batches", stream.len()));
        }
        for mode in modes {
            let spec = MulSpec::parse(mode)?;
            let mut reference: Option<(Vec<(u32, u32)>, Vec<u32>, f64)> = None;
            for &workers in &workers_sweep {
                let cfg = DpConfig { workers, shard, lr };
                let mut tr = DpTrainer::new(model, spec.clone(), cfg, SEED)?;
                let t0 = Instant::now();
                let mut curve = Vec::with_capacity(steps);
                for (images, labels) in &stream {
                    curve.push(tr.step(images, labels)?);
                }
                let wall = t0.elapsed().as_secs_f64();
                let run = format!("{model} {mode} workers={workers}");

                let curve_bits: Vec<(u32, u32)> =
                    curve.iter().map(|s| (s.loss.to_bits(), s.acc.to_bits())).collect();
                let param_bits: Vec<u32> =
                    tr.flat_params().iter().map(|v| v.to_bits()).collect();
                let thr = steps as f64 / wall.max(1e-9);
                let (speedup, efficiency) = match &reference {
                    None => {
                        reference = Some((curve_bits, param_bits, thr));
                        (1.0, 1.0)
                    }
                    Some((ref_curve, ref_params, thr1)) => {
                        // the bit-gate: N-worker ≡ 1-worker, whole curve
                        // and final parameters
                        if curve_bits != *ref_curve {
                            return Err(anyhow!(
                                "bench aborted: {run}: loss/accuracy curve diverged from \
                                 the 1-worker bits — the reduction tree is not \
                                 worker-count-invariant"
                            ));
                        }
                        if param_bits != *ref_params {
                            return Err(anyhow!(
                                "bench aborted: {run}: final parameters diverged from \
                                 the 1-worker bits"
                            ));
                        }
                        let speedup = thr / thr1.max(1e-9);
                        (speedup, speedup / workers as f64)
                    }
                };
                if model == "lenet300" && mode == "lut:afm16" && workers == 4 {
                    headline = speedup;
                }
                table.row(vec![
                    model.into(),
                    mode.into(),
                    workers.to_string(),
                    format!("{thr:.2}"),
                    format!("{:.0}", thr * batch as f64),
                    fmt_ratio(speedup),
                    format!("{:.0}%", efficiency * 100.0),
                ]);
                let last = curve.last().unwrap();
                records.push(Json::obj(vec![
                    ("model", Json::str(model)),
                    ("mode", Json::str(mode)),
                    ("workers", Json::num(workers as f64)),
                    ("steps", Json::num(steps as f64)),
                    ("batch", Json::num(batch as f64)),
                    ("shard", Json::num(shard as f64)),
                    ("wall_s", Json::num(wall)),
                    ("steps_per_s", Json::num(thr)),
                    ("samples_per_s", Json::num(thr * batch as f64)),
                    ("speedup_vs_workers1", Json::num(speedup)),
                    ("scaling_efficiency", Json::num(efficiency)),
                    ("bit_identical_to_workers1", Json::Bool(true)),
                    ("final_loss", Json::num(last.loss as f64)),
                    ("final_acc", Json::num(last.acc as f64)),
                ]));
            }
        }
    }

    let record = Json::obj(vec![
        ("schema", Json::str("approxtrain/bench_train/v1")),
        (
            "description",
            Json::str(
                "deterministic data-parallel training over the pure-Rust executors: \
                 worker counts x simulation strategy x model; minibatches cut into \
                 fixed leaf shards reduced through a fixed-order binary tree; every \
                 multi-worker run bit-exactness-gated (loss curve + final params) \
                 against the 1-worker run",
            ),
        ),
        ("multiplier", Json::str("afm16")),
        (
            "provenance",
            Json::str("measured in-process by approxtrain bench_train on this machine"),
        ),
        ("quick", Json::Bool(quick)),
        ("batch", Json::num(batch as f64)),
        ("shard", Json::num(shard as f64)),
        ("steps_per_run", Json::num(steps as f64)),
        ("lr", Json::num(lr as f64)),
        ("models", Json::arr(models.iter().map(|&m| Json::str(m)))),
        ("workers_swept", Json::arr(workers_sweep.iter().map(|&w| Json::num(w as f64)))),
        ("lut_lenet300_workers4_speedup_vs_workers1", Json::num(headline)),
        ("records", Json::Arr(records)),
    ]);
    let payload = record.to_string();
    write_result(results_dir, "BENCH_train.json", &payload)?;
    if record_root {
        super::report::write_root_record("BENCH_train.json", &payload)?;
    }
    let mut md = table.to_markdown();
    md.push_str(&format!(
        "Every row bit-gated against its 1-worker run (loss curve + final params). \
         LUT lenet300 training, 4 workers vs 1: {headline:.2}x\n\n"
    ));
    Ok(md)
}

// ---------------------------------------------------------------------------
// Fig 6 — GEMM: AMSim vs direct simulation vs native
// ---------------------------------------------------------------------------

pub fn fig6(engine: &mut Engine, results_dir: &Path, size: usize, quick: bool) -> Result<String> {
    let budget = if quick { 0.3 } else { 2.0 };
    let n = size;
    let mut rng = Pcg32::seeded(606);
    let a: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let lut = MantissaLut::load(&engine.manifest().dir.join("luts/afm16.lut"))
        .map_err(|e| anyhow!("{e}"))?;
    lut.validate().map_err(|e| anyhow!("loaded afm16 LUT failed validation: {e}"))?;

    let time_artifact = |engine: &mut Engine, name: &str, with_lut: bool| -> Result<f64> {
        engine.prepare(name)?;
        let mut inputs = vec![Value::F32(a.clone()), Value::F32(b.clone())];
        if with_lut {
            inputs.push(Value::U32(lut.entries.clone()));
        }
        let r = bench_budget(name, 1, 3, budget, || {
            engine.run(name, &inputs).unwrap();
        });
        Ok(r.median_s())
    };

    let t_tf = time_artifact(engine, &format!("gemm{n}_tf"), false)?;
    let t_native = time_artifact(engine, &format!("gemm{n}_native"), false)?;
    let t_lut = time_artifact(engine, &format!("gemm{n}_lut"), true)?;
    let mut t = Table::new(
        &format!("Fig 6 — GEMM {n}x{n} simulation strategies (XLA artifact path)"),
        &["configuration", "time", "vs native FP32"],
    );
    t.row(vec!["native FP32 (stock XLA)".into(), fmt_time(t_tf), fmt_ratio(1.0)]);
    t.row(vec!["custom kernel, native mult".into(), fmt_time(t_native), fmt_ratio(t_native / t_tf)]);
    t.row(vec!["AMSim LUT (any multiplier)".into(), fmt_time(t_lut), fmt_ratio(t_lut / t_tf)]);
    for mult in ["afm16", "mit16", "realm16", "bfloat16"] {
        let name = format!("gemm{n}_d_{mult}");
        let td = time_artifact(engine, &name, false)?;
        t.row(vec![format!("direct sim: {mult}"), fmt_time(td), fmt_ratio(td / t_tf)]);
    }
    // the paper's headline: AMSim cost is *independent of the multiplier*;
    // direct simulation varies per design. Also include the CPU (ATxC)
    // scalar path for scale.
    let model = registry::by_name("afm16").unwrap();
    let mut c = vec![0.0f32; n * n];
    let r = bench_budget("cpu_direct", 0, 1, budget, || {
        crate::kernels::gemm::gemm(
            &crate::kernels::MulKernel::Direct(model.as_ref()),
            &a,
            &b,
            &mut c,
            n,
            n,
            n,
        );
    });
    t.row(vec!["CPU direct C-style sim (ATxC)".into(), fmt_time(r.median_s()),
               fmt_ratio(r.median_s() / t_tf)]);
    write_result(results_dir, "fig6.csv", &t.to_csv())?;
    Ok(t.to_markdown())
}

// ---------------------------------------------------------------------------
// Fig 10 + Table III — training convergence and test accuracy
// ---------------------------------------------------------------------------

pub fn fig10_table3(
    engine: &mut Engine,
    artifacts_dir: &Path,
    results_dir: &Path,
    quick: bool,
) -> Result<String> {
    let mut table = Table::new(
        "Table III — test accuracy (%) after training with each multiplier",
        &["dataset", "network", "FP32", "AFM32", "diff32", "bfloat16", "AFM16", "diff16"],
    );
    let mut all_csv = String::from("label,epoch,train_loss,train_acc,test_acc,seconds\n");
    let mut md_curves = String::new();
    for (ds_name, model, train_n, epochs) in combos(quick) {
        let ds = dataset_for(ds_name, train_n, 77);
        let (train, test) = ds.split(train_n / 4);
        let mut accs = Vec::new();
        for (disp, mode, mult) in TABLE3_MULTS {
            let cfg = TrainConfig {
                model: model.to_string(),
                mode: mode.to_string(),
                mult: mult.to_string(),
                epochs,
                lr: 0.05,
                seed: 42, // same seed across multipliers (paper §VIII-A)
                eval_every: 1,
            };
            let mut tr = Trainer::new(engine, cfg, artifacts_dir)?;
            let log = tr.fit(&train, &test)?;
            let csv = log.to_csv();
            all_csv.push_str(csv.split_once('\n').unwrap().1);
            md_curves.push_str(&format!(
                "* {ds_name}/{model} {disp}: final test acc {:.2}% (train acc {:.2}%)\n",
                log.final_test_acc() * 100.0,
                log.epochs.last().unwrap().train_acc * 100.0
            ));
            accs.push(log.final_test_acc() * 100.0);
        }
        table.row(vec![
            ds_name.into(),
            model.into(),
            format!("{:.2}", accs[0]),
            format!("{:.2}", accs[1]),
            format!("{:+.2}", accs[1] - accs[0]),
            format!("{:.2}", accs[2]),
            format!("{:.2}", accs[3]),
            format!("{:+.2}", accs[3] - accs[2]),
        ]);
    }
    write_result(results_dir, "fig10_curves.csv", &all_csv)?;
    write_result(results_dir, "table3.csv", &table.to_csv())?;
    let mut md = table.to_markdown();
    md.push_str("\nFig 10 curve endpoints:\n\n");
    md.push_str(&md_curves);
    md.push('\n');
    Ok(md)
}

// ---------------------------------------------------------------------------
// Table IV — cross-format train/test
// ---------------------------------------------------------------------------

pub fn table4(
    engine: &mut Engine,
    artifacts_dir: &Path,
    results_dir: &Path,
    quick: bool,
) -> Result<String> {
    // the paper uses ResNet50/ImageNet; quick mode falls back to lenet5
    let (ds_name, model, train_n, epochs) =
        if quick { ("mnist", "lenet5", 256, 2) } else { ("imagenet", "resnet50i", 512, 3) };
    let ds = dataset_for(ds_name, train_n, 78);
    let (train, test) = ds.split(train_n / 4);
    let mut table = Table::new(
        &format!("Table IV — cross-format testing, {model}/{ds_name} (test acc %)"),
        &["trained \\ tested", "FP32", "AFM32", "bfloat16", "AFM16"],
    );
    // train once per multiplier, checkpoint, then evaluate under all four
    let mut checkpoints = Vec::new();
    for (disp, mode, mult) in TABLE3_MULTS {
        let cfg = TrainConfig {
            model: model.to_string(),
            mode: mode.to_string(),
            mult: mult.to_string(),
            epochs,
            lr: 0.05,
            seed: 42,
            eval_every: usize::MAX,
        };
        let mut tr = Trainer::new(engine, cfg, artifacts_dir)?;
        tr.fit(&train, &test)?;
        checkpoints.push((disp, tr.checkpoint()?));
    }
    for (train_disp, ckpt) in &checkpoints {
        let mut row = vec![train_disp.to_string()];
        for (_, mode, mult) in TABLE3_MULTS {
            let cfg = TrainConfig {
                model: model.to_string(),
                mode: mode.to_string(),
                mult: mult.to_string(),
                epochs: 0,
                lr: 0.0,
                seed: 42,
                eval_every: 1,
            };
            let mut tr = Trainer::new(engine, cfg, artifacts_dir)?;
            tr.load_checkpoint(ckpt)?;
            row.push(format!("{:.2}", tr.evaluate(&test)? * 100.0));
        }
        table.row(row);
    }
    write_result(results_dir, "table4.csv", &table.to_csv())?;
    Ok(table.to_markdown())
}

// ---------------------------------------------------------------------------
// Fig 11 — pruning + approximate multipliers
// ---------------------------------------------------------------------------

pub fn fig11(
    engine: &mut Engine,
    artifacts_dir: &Path,
    results_dir: &Path,
    quick: bool,
) -> Result<String> {
    use super::pruning::{prune_params, reapply_masks};
    let (train_n, pre_epochs, retrain_epochs) = if quick { (256, 2, 1) } else { (1024, 5, 2) };
    let ds = dataset_for("mnist", train_n, 79);
    let (train, test) = ds.split(train_n / 4);
    let sparsities = if quick { vec![0.7, 0.83] } else { vec![0.70, 0.75, 0.80, 0.83, 0.86, 0.90] };
    let mut table = Table::new(
        "Fig 11 — pruned test accuracy (%) vs sparsity (MNIST CNN)",
        &["multiplier", "baseline"]
            .into_iter()
            .map(String::from)
            .chain(sparsities.iter().map(|s| format!("{:.0}%", s * 100.0)))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    );
    for (disp, mode, mult) in [
        ("FP32", "custom", "fp32"),
        ("bfloat16", "lut", "bfloat16"),
        ("AFM16", "lut", "afm16"),
    ] {
        // pre-train
        let cfg = TrainConfig {
            model: "lenet5".into(),
            mode: mode.into(),
            mult: mult.into(),
            epochs: pre_epochs,
            lr: 0.05,
            seed: 42,
            eval_every: usize::MAX,
        };
        let mut tr = Trainer::new(engine, cfg.clone(), artifacts_dir)?;
        tr.fit(&train, &test)?;
        let baseline = tr.evaluate(&test)? * 100.0;
        let pretrained = tr.checkpoint()?;
        let mut row = vec![disp.to_string(), format!("{baseline:.2}")];
        for &s in &sparsities {
            let mut tr = Trainer::new(engine, cfg.clone(), artifacts_dir)?;
            tr.load_checkpoint(&pretrained)?;
            let masks = prune_params(tr.params_mut(), s, 128);
            // brief retraining with masks re-applied after each epoch
            for epoch in 0..retrain_epochs {
                for (images, labels) in
                    crate::data::Batcher::new(&train, tr.batch_size(), 42, 1000 + epoch as u64)
                {
                    tr.step(&images, &labels)?;
                    reapply_masks(tr.params_mut(), &masks)?;
                }
            }
            row.push(format!("{:.2}", tr.evaluate(&test)? * 100.0));
        }
        table.row(row);
    }
    write_result(results_dir, "fig11.csv", &table.to_csv())?;
    Ok(table.to_markdown())
}

// ---------------------------------------------------------------------------
// Tables V & VI — runtime per batch, four system configurations
// ---------------------------------------------------------------------------

fn time_train_step(
    engine: &mut Engine,
    artifacts_dir: &Path,
    model: &str,
    mode: &str,
    mult: &str,
    budget: f64,
) -> Result<f64> {
    let cfg = TrainConfig {
        model: model.into(),
        mode: mode.into(),
        mult: mult.into(),
        epochs: 1,
        lr: 0.05,
        seed: 1,
        eval_every: 1,
    };
    let mut tr = Trainer::new(engine, cfg, artifacts_dir)?;
    let batch = tr.batch_size();
    let art = tr.cfg.model.clone();
    let ds = dataset_for(dataset_of(&art), batch * 2, 80);
    let (images, labels) = crate::data::Batcher::new(&ds, batch, 1, 0).next().unwrap();
    let r = bench_budget(&format!("{model}/{mode}/train"), 1, 3, budget, || {
        tr.step(&images, &labels).unwrap();
    });
    Ok(r.median_s())
}

fn time_fwd(
    engine: &mut Engine,
    artifacts_dir: &Path,
    model: &str,
    mode: &str,
    mult: &str,
    budget: f64,
) -> Result<f64> {
    let cfg = TrainConfig {
        model: model.into(),
        mode: mode.into(),
        mult: mult.into(),
        epochs: 0,
        lr: 0.0,
        seed: 1,
        eval_every: 1,
    };
    let mut tr = Trainer::new(engine, cfg, artifacts_dir)?;
    let batch = tr.batch_size();
    let ds = dataset_for(dataset_of(model), batch + batch / 2, 81);
    let (test, _) = ds.split(batch / 4);
    let r = bench_budget(&format!("{model}/{mode}/fwd"), 1, 3, budget, || {
        tr.evaluate(&test).unwrap();
    });
    // evaluate covers the whole test set in ceil(n / batch) fixed-shape
    // batches (trailing batch padded); normalize to one batch
    let batches = test.n.div_ceil(batch).max(1) as f64;
    Ok(r.median_s() / batches)
}

pub fn dataset_of(model: &str) -> &'static str {
    match model {
        "lenet300" | "lenet5" => "mnist",
        "resnet18" | "resnet34" | "resnet50" => "cifar10",
        _ => "imagenet",
    }
}

/// ATxC per-batch time (pure-Rust direct simulation). Implemented for the
/// LeNets; ResNets are extrapolated from their MAC ratio (documented in
/// EXPERIMENTS.md).
fn cpu_direct_time(model: &str, batch: usize, train: bool, budget: f64) -> Result<f64> {
    let mult = registry::by_name("afm16").unwrap();
    let mul = crate::kernels::MulKernel::Direct(mult.as_ref());
    let mut rng = Pcg32::seeded(82);
    match model {
        "lenet300" => {
            let x = Tensor::from_vec(
                &[batch, 784],
                (0..batch * 784).map(|_| rng.uniform()).collect(),
            );
            let labels: Vec<u32> = (0..batch as u32).map(|i| i % 10).collect();
            let mut net = Lenet300::init(784, 10, 1);
            let r = bench_budget("cpu/lenet300", 0, 2, budget, || {
                if train {
                    net.train_step(&mul, &x, &labels, 0.05);
                } else {
                    net.forward(&mul, &x);
                }
            });
            Ok(r.median_s())
        }
        "lenet5" => {
            let x = Tensor::from_vec(
                &[batch, 28, 28, 1],
                (0..batch * 784).map(|_| rng.uniform()).collect(),
            );
            let labels: Vec<u32> = (0..batch as u32).map(|i| i % 10).collect();
            let mut net = Lenet5::init(1);
            let r = bench_budget("cpu/lenet5", 0, 2, budget, || {
                if train {
                    net.train_step(&mul, &x, &labels, 0.05);
                } else {
                    net.forward(&mul, &x);
                }
            });
            Ok(r.median_s())
        }
        "resnet18" | "resnet34" | "resnet50" | "resnet50i" => {
            use crate::nn::cpu_resnet::{CpuResnet, Depth};
            let (depth, shape, classes) = match model {
                "resnet18" => (Depth::R18, (16usize, 16usize, 3usize), 10),
                "resnet34" => (Depth::R34, (16, 16, 3), 10),
                "resnet50" => (Depth::R50, (16, 16, 3), 10),
                _ => (Depth::R50, (32, 32, 3), 20),
            };
            let mut net = CpuResnet::init(depth, shape, classes, 8, 1);
            let n = batch * shape.0 * shape.1 * shape.2;
            let x = Tensor::from_vec(
                &[batch, shape.0, shape.1, shape.2],
                (0..n).map(|_| rng.uniform()).collect(),
            );
            let labels: Vec<u32> = (0..batch as u32).map(|i| i % classes as u32).collect();
            // direct simulation is slow by nature (that is the point of the
            // paper); a single measured step suffices
            let r = bench_budget(&format!("cpu/{model}"), 0, 1, budget.min(1.0), || {
                if train {
                    net.train_step(&mul, &x, &labels, 0.05);
                } else {
                    net.forward(&mul, &x);
                }
            });
            Ok(r.median_s())
        }
        other => anyhow::bail!("no CPU-direct implementation for {other}"),
    }
}

pub fn table5_6(
    engine: &mut Engine,
    artifacts_dir: &Path,
    results_dir: &Path,
    train: bool,
    quick: bool,
) -> Result<String> {
    let budget = if quick { 0.5 } else { 3.0 };
    let which = if train { "V (training)" } else { "VI (inference)" };
    let mut table = Table::new(
        &format!("Table {which} — time per batch"),
        &["dataset", "network", "TFnG", "ATnG", "ATxG", "ATxC", "ATnG/TFnG", "ATxG/TFnG",
          "ATxC/ATxG"],
    );
    let models: Vec<&str> = if quick {
        vec!["lenet300", "lenet5"]
    } else {
        vec!["lenet300", "lenet5", "resnet18", "resnet34", "resnet50", "resnet50i"]
    };
    let mut atn_ratios = Vec::new();
    let mut atx_ratios = Vec::new();
    let mut cpu_ratios = Vec::new();
    for model in models {
        let timer: &dyn Fn(&mut Engine, &str, &str) -> Result<f64> = if train {
            &|e, mo, mu| time_train_step(e, artifacts_dir, model, mo, mu, budget)
        } else {
            &|e, mo, mu| time_fwd(e, artifacts_dir, model, mo, mu, budget)
        };
        let t_tf = timer(engine, "tf", "fp32")?;
        let t_custom = timer(engine, "custom", "fp32")?;
        let t_lut = timer(engine, "lut", "afm16")?;
        let batch = engine
            .manifest()
            .find(model, "train", "lut")
            .map(|a| a.inputs.iter().find(|t| t.name == "x").unwrap().shape[0])
            .unwrap_or(32);
        // ATxC: measured for LeNets, MAC-extrapolated for ResNets
        let (t_cpu, cpu_note) = match cpu_direct_time(model, batch, train, budget) {
            Ok(t) => (t, String::new()),
            Err(_) => {
                let lenet5_cpu = cpu_direct_time("lenet5", batch, train, budget * 0.5)?;
                let lenet5_lut = if train {
                    time_train_step(engine, artifacts_dir, "lenet5", "lut", "afm16", budget * 0.5)?
                } else {
                    time_fwd(engine, artifacts_dir, "lenet5", "lut", "afm16", budget * 0.5)?
                };
                (lenet5_cpu / lenet5_lut * t_lut, " (est)".to_string())
            }
        };
        atn_ratios.push(t_custom / t_tf);
        atx_ratios.push(t_lut / t_tf);
        cpu_ratios.push(t_cpu / t_lut);
        table.row(vec![
            dataset_of(model).into(),
            model.into(),
            fmt_time(t_tf),
            fmt_time(t_custom),
            fmt_time(t_lut),
            format!("{}{}", fmt_time(t_cpu), cpu_note),
            fmt_ratio(t_custom / t_tf),
            fmt_ratio(t_lut / t_tf),
            fmt_ratio(t_cpu / t_lut),
        ]);
    }
    let fname = if train { "table5.csv" } else { "table6.csv" };
    write_result(results_dir, fname, &table.to_csv())?;
    let mut md = table.to_markdown();
    md.push_str(&format!(
        "Geomeans: ATnG/TFnG {} | ATxG/TFnG {} | ATxC/ATxG {}\n\n",
        fmt_ratio(stats::geomean(&atn_ratios)),
        fmt_ratio(stats::geomean(&atx_ratios)),
        fmt_ratio(stats::geomean(&cpu_ratios)),
    ));
    Ok(md)
}

// ---------------------------------------------------------------------------
// Fig 12 — ApproxTrain vs a TFapprox-style comparator
// ---------------------------------------------------------------------------

pub fn fig12(engine: &mut Engine, results_dir: &Path, quick: bool) -> Result<String> {
    // TFapprox stores the *full product* LUT of an 8-bit integer multiplier
    // (256x256 entries); ApproxTrain stores the mantissa-product LUT of an
    // FP multiplier. Both reduce every multiply to one table lookup, so the
    // paper finds near-identical inference cost. We reproduce the
    // comparison on the GEMM inner loops the conv ops reduce to (see
    // DESIGN.md §Substitutions #6).
    let budget = if quick { 0.3 } else { 2.0 };
    let n = if quick { 128 } else { 256 };
    let mut rng = Pcg32::seeded(1212);
    let a: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let lut = MantissaLut::load(&engine.manifest().dir.join("luts/afm16.lut"))
        .map_err(|e| anyhow!("{e}"))?;
    lut.validate().map_err(|e| anyhow!("loaded afm16 LUT failed validation: {e}"))?;

    // ApproxTrain path: mantissa-LUT artifact
    let name = format!("gemm{n}_lut");
    engine.prepare(&name)?;
    let inputs =
        vec![Value::F32(a.clone()), Value::F32(b.clone()), Value::U32(lut.entries.clone())];
    let r_at = bench_budget("approxtrain", 1, 3, budget, || {
        engine.run(&name, &inputs).unwrap();
    });

    // TFapprox-style path: same CPU GEMM harness with a full 8-bit integer
    // product LUT (the tf-approximate approach, inference only)
    let int8_lut: Vec<f32> = (0..256 * 256)
        .map(|i| {
            let (qa, qb) = ((i / 256) as i32 - 128, (i % 256) as i32 - 128);
            (qa * qb) as f32
        })
        .collect();
    let scale = 1.0 / 127.0;
    let mut c = vec![0.0f32; n * n];
    let r_tfa = bench_budget("tfapprox", 1, 3, budget, || {
        // TFapprox inference recipe: quantize each tensor once (its int8
        // ops receive already-quantized tensors), then pure LUT-gather GEMM
        let qa: Vec<u32> = a
            .iter()
            .map(|&v| ((v / scale).round().clamp(-128.0, 127.0) as i32 + 128) as u32)
            .collect();
        let qb: Vec<u32> = b
            .iter()
            .map(|&v| ((v / scale).round().clamp(-128.0, 127.0) as i32 + 128) as u32)
            .collect();
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0f32;
                for k in 0..n {
                    acc += int8_lut[(qa[i * n + k] * 256 + qb[k * n + j]) as usize];
                }
                c[i * n + j] = acc * scale * scale;
            }
        }
    });
    // normalize both to per-MAC cost and compare against the same CPU
    // baseline GEMM so substrate differences cancel
    let mut c2 = vec![0.0f32; n * n];
    let r_cpu_native = bench_budget("cpu_native", 1, 3, budget, || {
        crate::kernels::gemm::gemm(&crate::kernels::MulKernel::Native, &a, &b, &mut c2, n, n, n);
    });
    let model = registry::by_name("afm16").unwrap();
    let sim = crate::amsim::AmSim::new(&lut);
    let _ = model;
    let mut c3 = vec![0.0f32; n * n];
    let r_cpu_amsim = bench_budget("cpu_amsim", 1, 3, budget, || {
        crate::kernels::gemm::gemm(&crate::kernels::MulKernel::Lut(
            crate::amsim::AmSim::new(&lut)), &a, &b, &mut c3, n, n, n);
    });
    drop(sim);
    let mut t = Table::new(
        &format!("Fig 12 — LUT-simulation cost parity, GEMM {n}x{n}"),
        &["approach", "time", "vs native CPU GEMM"],
    );
    t.row(vec!["ApproxTrain AMSim (XLA artifact)".into(), fmt_time(r_at.median_s()),
               fmt_ratio(r_at.median_s() / r_cpu_native.median_s())]);
    t.row(vec!["ApproxTrain AMSim (CPU kernel)".into(), fmt_time(r_cpu_amsim.median_s()),
               fmt_ratio(r_cpu_amsim.median_s() / r_cpu_native.median_s())]);
    t.row(vec!["TFapprox-style full-product int8 LUT (CPU)".into(),
               fmt_time(r_tfa.median_s()),
               fmt_ratio(r_tfa.median_s() / r_cpu_native.median_s())]);
    write_result(results_dir, "fig12.csv", &t.to_csv())?;
    Ok(t.to_markdown())
}
