//! Planted R1 violation: an `unsafe` block whose preceding comment does
//! not state a SAFETY invariant. Fixture data for `rust/tests/lint.rs`
//! — never compiled, never scanned by approxlint itself.

pub fn deref(p: *const f32) -> f32 {
    // reads the pointer
    unsafe { *p }
}
