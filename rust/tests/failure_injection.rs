//! Failure-injection tests: the coordinator must fail loudly and
//! informatively on corrupt or mismatched inputs, never silently train on
//! garbage.

use std::path::{Path, PathBuf};

use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::runtime::artifact::Manifest;
use approxtrain::runtime::executor::{Engine, Value};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn wrong_input_count_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let err = engine.run("gemm128_native", &[Value::F32(vec![0.0; 128 * 128])]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
}

#[test]
fn wrong_shape_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let err = engine
        .run("gemm128_native", &[Value::F32(vec![0.0; 10]), Value::F32(vec![0.0; 128 * 128])])
        .unwrap_err();
    assert!(err.to_string().contains("elements"), "{err}");
}

#[test]
fn wrong_dtype_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let err = engine
        .run(
            "gemm128_native",
            &[Value::I32(vec![0; 128 * 128]), Value::F32(vec![0.0; 128 * 128])],
        )
        .unwrap_err();
    assert!(err.to_string().contains("dtype"), "{err}");
}

#[test]
fn unknown_artifact_lists_alternatives() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let err = engine.run("nonexistent", &[]).unwrap_err();
    assert!(err.to_string().contains("not in manifest"), "{err}");
}

#[test]
fn corrupt_manifest_rejected() {
    let tmp = std::env::temp_dir().join("approxtrain_bad_manifest");
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(tmp.join("manifest.json"), "{not json").unwrap();
    assert!(Engine::new(&tmp).is_err());
    std::fs::write(tmp.join("manifest.json"), r#"{"artifacts":[{"name":"x"}]}"#).unwrap();
    assert!(Engine::new(&tmp).is_err());
}

#[test]
fn corrupt_hlo_file_fails_at_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join("approxtrain_bad_hlo");
    std::fs::create_dir_all(&tmp).unwrap();
    // valid manifest pointing at garbage HLO
    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    std::fs::write(tmp.join("manifest.json"), &manifest).unwrap();
    let m = Manifest::load(&tmp).unwrap();
    let art = m.artifacts.values().next().unwrap().clone();
    std::fs::write(tmp.join(&art.file), "HloModule garbage\n\nENTRY {}").unwrap();
    let mut engine = Engine::new(&tmp).unwrap();
    assert!(engine.prepare(&art.name).is_err());
}

#[test]
fn truncated_lut_file_detected() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let bytes = lut.to_bytes();
    for cut in [1, 16, bytes.len() / 2, bytes.len() - 1] {
        assert!(MantissaLut::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
    }
}

#[test]
fn trainer_rejects_untabulatable_multiplier() {
    use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let cfg = TrainConfig {
        model: "lenet300".into(),
        mode: "lut".into(),
        mult: "afm32".into(), // m=23 cannot be tabulated
        epochs: 1,
        lr: 0.05,
        seed: 1,
        eval_every: 1,
    };
    let err = Trainer::new(&mut engine, cfg, &dir).err().expect("must fail");
    assert!(err.to_string().contains("not tabulatable"), "{err}");
}
