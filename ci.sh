#!/usr/bin/env bash
# CI pipeline: format check (advisory), release build, tests, bench smoke.
# Usage: ./ci.sh
set -uo pipefail

cd "$(dirname "$0")"

fail=0
step() { echo; echo "==> $*"; }

step "cargo fmt --check (advisory)"
if command -v rustfmt >/dev/null 2>&1 || cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        # advisory only: formatting drift is reported but does not gate the
        # build/test/bench pipeline (tier-1 is build + test)
        echo "WARNING: formatting drift detected (run 'cargo fmt')"
    fi
else
    echo "rustfmt not installed; skipping format check"
fi

step "cargo build --release"
cargo build --release || fail=1

step "cargo test -q"
cargo test -q || fail=1

step "bench smoke (tiny sizes; does not touch the committed BENCH_gemm.json)"
cargo bench --bench paper_benches -- gemm --smoke || fail=1

echo
if [ "$fail" -ne 0 ]; then
    echo "CI: FAILED"
    exit 1
fi
echo "CI: OK"
