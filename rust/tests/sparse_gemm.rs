//! Differential net for the zero-skipping sparse packed GEMM
//! (`kernels::gemm`), the teeth of this crate's occupancy-bitmap drain.
//!
//! What is proven here, strategy by strategy:
//!
//! * **Bit-identity under skipping** — for every gated multiplier (LUT
//!   kernels structurally, direct kernels by their audited
//!   `zero_identity` flag), the tiled GEMM over structured-sparse
//!   operands is bit-identical to the dense per-element scalar oracle at
//!   every occupancy residue (short row-groups, short strips), sparsity
//!   level (0 / 50 / 90 %), forced SIMD level and thread count.
//! * **Dense fallback** — non-gated strategies (native hardware `*`)
//!   provably run the dense drain: their `0 × inf = NaN` semantics
//!   survive (a skipped pair would have silently produced `+0.0`), and
//!   the skip counter never moves while the pair counter does.
//! * **The sign-of-zero edge** — the `+0.0`-seeded accumulator premise
//!   in the skip-safety argument (`PackA::pack_a_occ` docs) is
//!   load-bearing: `-0.0 + 0.0` flips bits, so the suite pins that dead
//!   output rows come out as exactly `+0.0` on both the skipped and the
//!   dense path.
//! * **Skipping actually happens** — a multiplier that *lies* about the
//!   zero identity visibly changes output bits versus its own scalar
//!   reference, and the elided-pair counters match a closed-form count
//!   on an aligned geometry. Without these, the whole net could pass
//!   with the skip branch dead code.
//!
//! The drain's pair/skip counters are process-global; every test that
//! reads them (or whose GEMMs would advance them mid-read) serializes on
//! a file-local mutex so the deltas are attributable.

use std::sync::Mutex;

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm_scalar_reference, gemm_tiled_with, TileConfig};
use approxtrain::kernels::{panel_pair_events, panel_skip_events, MulKernel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::{registry, ApproxMul};
use approxtrain::util::rng::Pcg32;
use approxtrain::util::simd;

/// Serializes every GEMM-running test in this binary: the drain counters
/// are process-global, so concurrent tests would smear each other's
/// deltas.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

/// Tile geometries exercising the skip branch at every drain shape:
/// the micro-kernel path, the degenerate `1 x 1` per-element path, and
/// a wide register block.
const CONFIGS: [TileConfig; 3] = [
    TileConfig { mc: 8, kc: 16, nc: 8, mr: 2, nr: 4 },
    TileConfig { mc: 8, kc: 16, nc: 8, mr: 1, nr: 1 },
    TileConfig { mc: 16, kc: 8, nc: 16, mr: 4, nr: 8 },
];

/// Structured-sparse operand pair: whole `A` rows and whole `B` column
/// bands (width 4) are killed by per-row / per-band coin flips at
/// `sparsity`, alternating `+0.0` / `-0.0` fills so both dead encodings
/// are packed and scanned.
fn structured_sparse(
    seed: u64,
    m: usize,
    k: usize,
    n: usize,
    sparsity: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::seeded(seed);
    let mut a: Vec<f32> = (0..m * k).map(|_| rng.range(-2.0, 2.0)).collect();
    let mut b: Vec<f32> = (0..k * n).map(|_| rng.range(-2.0, 2.0)).collect();
    for r in 0..m {
        if rng.range(0.0, 1.0) < sparsity {
            a[r * k..(r + 1) * k].fill(if r % 2 == 0 { 0.0 } else { -0.0 });
        }
    }
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + 4).min(n);
        if rng.range(0.0, 1.0) < sparsity {
            for kk in 0..k {
                for j in j0..j1 {
                    b[kk * n + j] = if kk % 2 == 0 { -0.0 } else { 0.0 };
                }
            }
        }
        j0 = j1;
    }
    (a, b)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what} idx {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

// ---------------------------------------------------------------------------
// The headline matrix: residue × sparsity × multiplier × SIMD × threads
// ---------------------------------------------------------------------------

/// For gated multipliers (LUT afm16 at every machine level, direct afm16
/// and mit16) *and* the dense-fallback strategies (native at every
/// level), the tiled GEMM over structured-sparse operands is
/// bit-identical to the dense scalar oracle — at a residue-heavy shape
/// (short last row-group, short last strip) and an aligned one, at
/// sparsity 0 / 50 / 90 %, at every tile geometry in [`CONFIGS`] and at
/// 1 and 4 threads. At 90 % sparsity the gated runs must actually have
/// skipped pairs (otherwise this net is testing dead code).
#[test]
fn sparse_tiled_gemm_matches_dense_scalar_oracle_bitwise() {
    let _g = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let afm = registry::by_name("afm16").unwrap();
    let mit = registry::by_name("mit16").unwrap();
    let lut = MantissaLut::generate(afm.as_ref());
    let skips_before = panel_skip_events();
    for &(m, k, n) in &[(13usize, 21usize, 11usize), (24, 32, 16)] {
        for sparsity in [0.0f32, 0.5, 0.9] {
            let (a, b) =
                structured_sparse(9000 + (m * n) as u64 + (sparsity * 10.0) as u64, m, k, n, sparsity);
            let mut kernels: Vec<(MulKernel, String)> = vec![
                (MulKernel::Direct(afm.as_ref()), "direct:afm16".into()),
                (MulKernel::Direct(mit.as_ref()), "direct:mit16".into()),
            ];
            for level in simd::available_levels() {
                kernels.push((MulKernel::NativeAt(level), format!("native@{}", level.name())));
                kernels.push((
                    MulKernel::Lut(AmSim::with_simd(&lut, level)),
                    format!("lut@{}", level.name()),
                ));
            }
            for (mul, label) in &kernels {
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                for cfg in CONFIGS {
                    for threads in [1usize, 4] {
                        let mut got = vec![0.0f32; m * n];
                        gemm_tiled_with(mul, cfg, &a, &b, &mut got, m, k, n, threads);
                        assert_bits_eq(
                            &got,
                            &want,
                            &format!("{label} ({m},{k},{n}) s={sparsity} {cfg:?} t={threads}"),
                        );
                    }
                }
            }
        }
    }
    assert!(
        panel_skip_events() > skips_before,
        "no micro-panel pair was ever skipped across the whole sparse matrix — \
         the zero-skipping drain is dead code and this suite proved nothing"
    );
}

// ---------------------------------------------------------------------------
// The sign-of-zero edge the safety argument hinges on
// ---------------------------------------------------------------------------

/// The skip-safety argument (see `PackA::pack_a_occ`) rests on the
/// accumulator never being `-0.0` when an add is elided. This test first
/// pins the FP32 facts that make that both *necessary* and *sufficient*:
/// adding `+0.0` to `-0.0` is NOT a bitwise no-op (so if a `-0.0`
/// accumulator could occur, skipping would change bits), while a
/// `+0.0`-seeded chain can never reach `-0.0` under round-to-nearest
/// (`-0.0` only comes out of `(-0.0) + (-0.0)`; even `x + (-x)` is
/// `+0.0`). It then pins the consequence end to end: output elements
/// whose entire contraction is dead (including `-0.0` operands and
/// negative-signed products) come out as exactly `+0.0` bits on the
/// dense oracle, the skipped drain, and the never-scanned native drain
/// alike.
#[test]
fn minus_zero_accumulator_is_the_load_bearing_edge() {
    let _g = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // the FP32 premises, pinned
    assert_ne!(
        (-0.0f32 + 0.0f32).to_bits(),
        (-0.0f32).to_bits(),
        "adding +0.0 to -0.0 must flip the sign bit — otherwise this edge is moot"
    );
    assert_eq!((-0.0f32 + 0.0f32).to_bits(), 0.0f32.to_bits());
    assert_eq!((-0.0f32 + -0.0f32).to_bits(), (-0.0f32).to_bits(), "-0 is reachable from -0 adds");
    assert_eq!((0.0f32 + -0.0f32).to_bits(), 0.0f32.to_bits(), "+0-seeded chains stay +0");
    assert_eq!((1.5f32 + -1.5f32).to_bits(), 0.0f32.to_bits(), "cancellation yields +0 under RNE");

    // end to end, at mr = 2: rows 0+1 form a fully dead group (elided),
    // row 4 is a fully dead *ragged* last group (elided at residue), and
    // row 2 is dead inside a live group (its zeros run the dense drain) —
    // all with both zero signs; B carries -0.0 and negatives so the dense
    // oracle's dead-row products are themselves signed zeros
    let (m, k, n) = (5usize, 12usize, 7usize);
    let mut rng = Pcg32::seeded(41);
    let mut a: Vec<f32> = (0..m * k).map(|_| rng.range(-2.0, 2.0)).collect();
    let mut b: Vec<f32> = (0..k * n).map(|_| rng.range(-2.0, 2.0)).collect();
    let dead_rows = [0usize, 1, 2, 4];
    for &r in &dead_rows {
        for (i, v) in a[r * k..(r + 1) * k].iter_mut().enumerate() {
            *v = if i % 2 == 0 { -0.0 } else { 0.0 };
        }
    }
    b[3] = -0.0;
    b[n + 1] = -0.0;
    let afm = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(afm.as_ref());
    let cfg = TileConfig { mc: 4, kc: 8, nc: 4, mr: 2, nr: 2 };
    for (mul, label) in [
        (MulKernel::Lut(AmSim::new(&lut)), "lut"),
        (MulKernel::Direct(afm.as_ref()), "direct"),
        (MulKernel::Native, "native-dense"),
    ] {
        let mut want = vec![0.0f32; m * n];
        gemm_scalar_reference(&mul, &a, &b, &mut want, m, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm_tiled_with(&mul, cfg, &a, &b, &mut got, m, k, n, 1);
        assert_bits_eq(&got, &want, label);
        for &r in &dead_rows {
            for j in 0..n {
                assert_eq!(
                    got[r * n + j].to_bits(),
                    0.0f32.to_bits(),
                    "{label}: dead row {r} col {j} must be exactly +0.0, got {:e}",
                    got[r * n + j]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Dense fallback is real, and skipping is real
// ---------------------------------------------------------------------------

/// The native strategy provably takes the dense drain: with a dead `A`
/// row against a `B` strip carrying an infinity, hardware `0 × inf`
/// produces NaN — which only survives if the pair was *not* elided — and
/// the skip counter stays frozen while the pair counter advances. The
/// same operands under a gated multiplier take the skip (counter moves)
/// and still bit-match their own scalar oracle, whose zero-dominant
/// `mul(0, inf)` is a zero: the two strategies *should* disagree with
/// each other here, each matching its own semantics.
#[test]
fn native_fallback_keeps_nan_semantics_and_never_skips() {
    let _g = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (m, k, n) = (6usize, 8usize, 5usize);
    let mut rng = Pcg32::seeded(55);
    let mut a: Vec<f32> = (0..m * k).map(|_| rng.range(-2.0, 2.0)).collect();
    let mut b: Vec<f32> = (0..k * n).map(|_| rng.range(-2.0, 2.0)).collect();
    a[..2 * k].fill(0.0); // rows 0+1: a whole dead mr=2 group
    b[2] = f32::INFINITY; // contraction step 0, column 2
    let cfg = TileConfig { mc: 4, kc: 8, nc: 4, mr: 2, nr: 2 };

    let native = MulKernel::Native;
    let mut want = vec![0.0f32; m * n];
    gemm_scalar_reference(&native, &a, &b, &mut want, m, k, n);
    assert!(want[2].is_nan(), "oracle sanity: hardware 0 * inf must be NaN");
    let (pairs0, skips0) = (panel_pair_events(), panel_skip_events());
    let mut got = vec![0.0f32; m * n];
    gemm_tiled_with(&native, cfg, &a, &b, &mut got, m, k, n, 1);
    assert!(panel_pair_events() > pairs0, "native drain considered no pairs");
    assert_eq!(
        panel_skip_events(),
        skips0,
        "native drain skipped a pair — hardware * has no zero identity"
    );
    assert_bits_eq(&got, &want, "native NaN semantics");
    assert!(got[2].is_nan(), "the NaN was elided — the dense fallback is broken");

    // gated twin: same operands, zero-dominant semantics, pairs elided
    let afm = registry::by_name("afm16").unwrap();
    let direct = MulKernel::Direct(afm.as_ref());
    let mut want_d = vec![0.0f32; m * n];
    gemm_scalar_reference(&direct, &a, &b, &mut want_d, m, k, n);
    assert_eq!(want_d[2].to_bits(), 0.0f32.to_bits(), "zero-dominant 0 * inf is +0");
    let skips1 = panel_skip_events();
    let mut got_d = vec![0.0f32; m * n];
    gemm_tiled_with(&direct, cfg, &a, &b, &mut got_d, m, k, n, 1);
    assert!(panel_skip_events() > skips1, "gated drain elided nothing on a dead row");
    assert_bits_eq(&got_d, &want_d, "gated semantics under skipping");
}

/// A multiplier that *claims* the zero identity but violates it
/// (`mul(0, x) == 1.0`) — if the drain really elides dead pairs, the
/// tiled result must diverge from the model's own scalar reference on
/// the dead row (skipped: `+0.0`; dense oracle: `k * 1.0`). This is the
/// proof that the skip branch executes at all, and the demonstration of
/// exactly what the `tests/golden_mults.rs` flag audit protects against.
struct LyingZeroMul;

impl ApproxMul for LyingZeroMul {
    fn name(&self) -> &str {
        "liar8"
    }
    fn mantissa_bits(&self) -> u32 {
        8
    }
    fn mul(&self, a: f32, b: f32) -> f32 {
        if a == 0.0 || b == 0.0 {
            1.0 // the lie: a zero operand does NOT dominate
        } else {
            a * b
        }
    }
    fn mantissa_product(&self, _ma: u32, _mb: u32) -> (u32, u32) {
        (0, 0)
    }
    fn zero_identity(&self) -> bool {
        true // falsely declared — never do this outside a teeth test
    }
}

#[test]
fn a_lying_zero_identity_flag_visibly_changes_bits() {
    let _g = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let liar = LyingZeroMul;
    let (m, k, n) = (6usize, 8usize, 5usize);
    let mut rng = Pcg32::seeded(61);
    let mut a: Vec<f32> = (0..m * k).map(|_| rng.range(-2.0, 2.0)).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.range(-2.0, 2.0)).collect();
    a[..2 * k].fill(0.0); // rows 0+1: a whole dead mr=2 group
    let mul = MulKernel::Direct(&liar);
    assert!(mul.zero_skip_ok(), "the gate must believe the declared flag");
    let mut want = vec![0.0f32; m * n];
    gemm_scalar_reference(&mul, &a, &b, &mut want, m, k, n);
    assert_eq!(want[0], k as f32, "oracle sanity: the lie sums to k on the dead row");
    let skips0 = panel_skip_events();
    let mut got = vec![0.0f32; m * n];
    let cfg = TileConfig { mc: 4, kc: 8, nc: 4, mr: 2, nr: 2 };
    gemm_tiled_with(&mul, cfg, &a, &b, &mut got, m, k, n, 1);
    assert!(panel_skip_events() > skips0, "nothing was skipped — teeth test is vacuous");
    assert_eq!(got[0].to_bits(), 0.0f32.to_bits(), "a skipped pair leaves the +0.0 seed");
    assert_ne!(
        got[0].to_bits(),
        want[0].to_bits(),
        "tiled == scalar despite a lying flag: either nothing was skipped or \
         the oracle took the skip too"
    );
    // rows outside the dead group are untouched by the lie
    assert_bits_eq(&got[2 * n..], &want[2 * n..], "live rows under the lying flag");
}

// ---------------------------------------------------------------------------
// Counter arithmetic on an aligned geometry
// ---------------------------------------------------------------------------

/// On a fully aligned geometry the drain counters match closed form.
/// `m=16, k=32, n=16` under `mc=8, kc=16, nc=8, mr=2, nr=4`: 4 tiles,
/// 2 k-blocks each, 4 row-groups × 2 strips per block ⇒ 64 pairs.
/// Killing `B` columns 0–3 (strip 0 of the two left tiles) across all
/// of `k` ⇒ 2 tiles × 2 k-blocks × 4 groups × 1 dead strip = 16 skips.
#[test]
fn drain_counters_match_closed_form_on_aligned_geometry() {
    let _g = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (m, k, n) = (16usize, 32usize, 16usize);
    let cfg = TileConfig { mc: 8, kc: 16, nc: 8, mr: 2, nr: 4 };
    let mut rng = Pcg32::seeded(71);
    let a: Vec<f32> = (0..m * k).map(|_| rng.range(-2.0, 2.0)).collect();
    let mut b: Vec<f32> = (0..k * n).map(|_| rng.range(-2.0, 2.0)).collect();
    for kk in 0..k {
        b[kk * n..kk * n + 4].fill(0.0);
    }
    let afm = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(afm.as_ref());
    let mul = MulKernel::Lut(AmSim::new(&lut));
    let mut want = vec![0.0f32; m * n];
    gemm_scalar_reference(&mul, &a, &b, &mut want, m, k, n);
    let (pairs0, skips0) = (panel_pair_events(), panel_skip_events());
    let mut got = vec![0.0f32; m * n];
    gemm_tiled_with(&mul, cfg, &a, &b, &mut got, m, k, n, 1);
    assert_eq!(panel_pair_events() - pairs0, 64, "pair count");
    assert_eq!(panel_skip_events() - skips0, 16, "skip count");
    assert_bits_eq(&got, &want, "aligned-geometry correctness");
    // the dense column band is exactly +0.0 in the output
    for i in 0..m {
        for j in 0..4 {
            assert_eq!(got[i * n + j].to_bits(), 0.0f32.to_bits(), "dead col ({i},{j})");
        }
    }
}
