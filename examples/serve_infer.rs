//! Serving example: the multi-lane batching inference server running
//! end-to-end on the pure-Rust executor backend — bounded admission,
//! dynamic batching per lane, approximate multipliers via the AMSim LUT
//! — reporting throughput, latency percentiles, batch fill and rejects.
//!
//! ```sh
//! cargo run --release --example serve_infer
//! ```
//!
//! No artifacts needed: each lane owns a bit-identical `Lenet300`
//! replica. To serve a compiled artifact instead, build an
//! `EngineBackend` and use `serve_on_caller` (see `approxtrain serve
//! --backend engine`).

use std::time::{Duration, Instant};

use approxtrain::coordinator::backend::{CpuBackend, InferBackend, MulSpec};
use approxtrain::coordinator::server::{serve_pool, ServeConfig};
use approxtrain::data::synth::{mnist_like, SynthSpec};

fn main() -> anyhow::Result<()> {
    let lanes = 4;
    let batch = 16;
    let n_requests = 256;
    let n_clients = 8;

    let base = CpuBackend::for_model("lenet300", MulSpec::parse("lut:afm16")?, batch, 42)?;
    let mut backends = base.replicas(lanes);
    let cfg = ServeConfig { max_wait: Duration::from_millis(4), queue_depth: 4 * batch };
    let ds = mnist_like(&SynthSpec { n: n_requests, ..SynthSpec::mnist_like_default() });
    println!(
        "serving {} | {lanes} lanes x batch {batch} | queue depth {} | {n_clients} clients, \
         {n_requests} requests",
        base.describe(),
        cfg.queue_depth
    );

    let t0 = Instant::now();
    let (stats, rejected) = serve_pool(&mut backends, cfg, |client| {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let rejected = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 0..n_clients {
                let client = client.clone();
                let ds = &ds;
                let rejected = &rejected;
                s.spawn(move || {
                    for i in (t..n_requests).step_by(n_clients) {
                        match client.infer(ds.image(i).to_vec()) {
                            Ok(reply) => assert_eq!(reply.logits.len(), 10),
                            Err(_) => {
                                // bounded admission queue said no — a real
                                // client would back off and retry
                                rejected.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        rejected.load(Ordering::Relaxed)
    })?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests in {} batches over {:.2}s ({rejected} rejected)",
        stats.requests, stats.batches, wall
    );
    println!("throughput: {:.0} req/s", stats.requests as f64 / wall);
    println!(
        "latency: p50 {:.1} ms | p90 {:.1} ms | p99 {:.1} ms (mean {:.1} ms, max {:.1} ms)",
        stats.latency_percentile_s(50.0) * 1e3,
        stats.latency_percentile_s(90.0) * 1e3,
        stats.latency_percentile_s(99.0) * 1e3,
        stats.mean_latency_s() * 1e3,
        stats.max_latency_s() * 1e3
    );
    println!("mean batch fill: {:.1}/{batch}", stats.mean_fill());
    Ok(())
}
