//! Training metrics: per-epoch records and CSV/console emission (the data
//! behind the Fig 10 training curves).

use std::fmt::Write as _;

/// One epoch of a training run.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub test_acc: f32,
    pub seconds: f64,
}

/// A full training curve for one (model, dataset, multiplier) cell.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub label: String,
    pub epochs: Vec<EpochRecord>,
}

impl RunLog {
    pub fn new(label: &str) -> RunLog {
        RunLog { label: label.to_string(), epochs: Vec::new() }
    }

    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    pub fn best_test_acc(&self) -> f32 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f32::max)
    }

    /// CSV with a `label` column so multiple runs concatenate into one file.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("label,epoch,train_loss,train_acc,test_acc,seconds\n");
        for e in &self.epochs {
            writeln!(
                out,
                "{},{},{:.6},{:.4},{:.4},{:.3}",
                self.label, e.epoch, e.train_loss, e.train_acc, e.test_acc, e.seconds
            )
            .unwrap();
        }
        out
    }
}

/// Index of the **first maximum** of a row, NaN-tolerant.
///
/// * Ties keep the *first* maximal index — TF/NumPy `argmax` semantics
///   (the previous `max_by(partial_cmp)` scans kept the *last*).
/// * NaN entries are never selected and never panic — the previous
///   `partial_cmp().unwrap()` crashed the whole evaluation when a
///   diverged approximate-multiplier run produced a NaN logit; scoring
///   policy is "a NaN logit can't win", so a partially-NaN row is scored
///   against its finite entries and an all-NaN row deterministically
///   returns 0 (counted wrong unless the label happens to be 0 — a
///   diverged run scores ~chance instead of aborting).
///
/// The shared helper for every argmax over logits/probabilities in the
/// crate; `row` must be non-empty.
pub fn argmax(row: &[f32]) -> usize {
    assert!(!row.is_empty(), "argmax of an empty row");
    let mut best = f32::NEG_INFINITY;
    let mut best_idx = 0usize;
    let mut found = false;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        if !found || v > best {
            best = v;
            best_idx = i;
            found = true;
        }
    }
    best_idx
}

/// Count of correctly-classified rows (argmax == label) from logits
/// (row-major `[batch, classes]`). The count form lets callers weight
/// accuracy per *sample* across unevenly-filled batches — a per-batch
/// average of rates would overweight a padded final batch. NaN-safe via
/// [`argmax`]: rows with NaN logits score wrong (usually), never panic.
pub fn correct_from_logits(logits: &[f32], labels: &[u32], classes: usize) -> usize {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        if argmax(row) == label as usize {
            correct += 1;
        }
    }
    correct
}

/// Compute classification accuracy from logits (row-major `[batch, classes]`).
pub fn accuracy_from_logits(logits: &[f32], labels: &[u32], classes: usize) -> f32 {
    correct_from_logits(logits, labels, classes) as f32 / labels.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        let logits = [1.0, 0.0, 0.0, 9.0];
        assert_eq!(accuracy_from_logits(&logits, &[0, 1], 2), 1.0);
        assert_eq!(accuracy_from_logits(&logits, &[1, 0], 2), 0.0);
    }

    #[test]
    fn argmax_first_max_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1, "ties keep the first max (TF semantics)");
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0, 2.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
    }

    #[test]
    fn argmax_tolerates_nan() {
        // NaN never wins, never panics
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        // all-NaN row: deterministic index 0
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
    }

    #[test]
    fn nan_rows_score_wrong_instead_of_crashing() {
        // row 0 diverged to NaN (argmax -> 0, label 1: wrong); row 1 fine
        let logits = [f32::NAN, f32::NAN, 0.0, 9.0];
        assert_eq!(correct_from_logits(&logits, &[1, 1], 2), 1);
        // a NaN row whose argmax(0) happens to equal the label counts —
        // the policy is deterministic scoring, not guaranteed-wrong
        assert_eq!(correct_from_logits(&logits, &[0, 1], 2), 2);
    }

    #[test]
    fn runlog_csv_and_best() {
        let mut log = RunLog::new("lenet5/afm16");
        log.epochs.push(EpochRecord { epoch: 0, train_loss: 2.0, train_acc: 0.3, test_acc: 0.4, seconds: 1.0 });
        log.epochs.push(EpochRecord { epoch: 1, train_loss: 1.0, train_acc: 0.7, test_acc: 0.6, seconds: 1.0 });
        assert_eq!(log.final_test_acc(), 0.6);
        assert_eq!(log.best_test_acc(), 0.6);
        let csv = log.to_csv();
        assert!(csv.contains("lenet5/afm16,1,1.000000,0.7000,0.6000"));
    }
}
