//! Planted R5 violations: an `if`-guarded Condvar wait (no spurious-
//! wakeup re-check) and a nested lock pair absent from the declared
//! lock-order table.

use std::sync::{Condvar, Mutex};

pub struct Q {
    cv: Condvar,
    state: Mutex<bool>,
    other: Mutex<u32>,
}

impl Q {
    pub fn bad_wait(&self) {
        let mut st = self.state.lock().unwrap();
        if !*st {
            st = self.cv.wait(st).unwrap();
        }
        *st = false;
    }

    pub fn bad_nesting(&self) -> u32 {
        let st = self.state.lock().unwrap();
        let v = *self.other.lock().unwrap();
        drop(st);
        v
    }
}
