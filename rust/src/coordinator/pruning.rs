//! Magnitude pruning with a polynomial-decay sparsity schedule — the
//! substrate for Fig 11 ("Approximate Multiplier on top of Pruning").
//!
//! The paper follows the standard TensorFlow model-optimization recipe:
//! pre-train, then prune to increasing sparsity levels with brief retraining
//! after each level. We implement the same schedule: sparsity(t) =
//! final + (initial - final) * (1 - t/T)^3.
//!
//! Two mask shapes are provided. [`magnitude_mask`] is classic unstructured
//! element-wise pruning. [`magnitude_block_mask`] prunes whole contiguous
//! blocks by aggregate magnitude — the shape that actually feeds the
//! zero-skipping sparse GEMM drain: the tiled kernel elides work at
//! micro-panel granularity (`mr`-row groups × `nr`-column strips, see
//! `kernels::gemm::PackA::pack_a_occ`), and unstructured sparsity almost
//! never zeroes a whole panel (at 90% element sparsity the chance a 4×128
//! row group is all-zero is `0.9^512 ≈ 10⁻²⁴`), while block pruning at the
//! matrix-row granularity produces exactly the dead panels the drain skips.

use crate::runtime::executor::Value;
use anyhow::{bail, Result};

/// Polynomial-decay sparsity schedule (TF model-optimization semantics).
#[derive(Clone, Copy, Debug)]
pub struct PolynomialDecay {
    pub initial_sparsity: f32,
    pub final_sparsity: f32,
    pub steps: usize,
}

impl PolynomialDecay {
    pub fn sparsity_at(&self, step: usize) -> f32 {
        if self.steps == 0 || step >= self.steps {
            return self.final_sparsity;
        }
        let frac = 1.0 - step as f32 / self.steps as f32;
        self.final_sparsity + (self.initial_sparsity - self.final_sparsity) * frac.powi(3)
    }
}

/// A pruning mask over one tensor.
#[derive(Clone, Debug)]
pub struct Mask {
    pub keep: Vec<bool>,
}

impl Mask {
    pub fn sparsity(&self) -> f32 {
        let pruned = self.keep.iter().filter(|&&k| !k).count();
        pruned as f32 / self.keep.len().max(1) as f32
    }
}

/// Build a magnitude mask pruning the smallest-|w| fraction of `weights`.
///
/// Deterministic under ties and NaN-safe: candidates are ordered by
/// `|w|` under [`f32::total_cmp`] (NaN sorts above every finite value,
/// so NaN weights are treated as large and kept), and the sort is
/// stable, so equal magnitudes prune in ascending index order.
pub fn magnitude_mask(weights: &[f32], sparsity: f32) -> Mask {
    let n = weights.len();
    let k = ((n as f32) * sparsity.clamp(0.0, 1.0)).round() as usize;
    if k == 0 {
        return Mask { keep: vec![true; n] };
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| weights[a].abs().total_cmp(&weights[b].abs()));
    let mut keep = vec![true; n];
    for &i in &idx[..k.min(n)] {
        keep[i] = false;
    }
    Mask { keep }
}

/// Build a *block-structured* magnitude mask: `weights` is scored in
/// contiguous blocks of `block` elements (the final block may be short)
/// by summed `|w|`, and the lowest-scoring fraction of blocks is pruned
/// whole. Block count pruned = `round(nblocks * sparsity)`, so element
/// sparsity tracks `sparsity` up to the block-count rounding.
///
/// This is the mask shape that feeds the zero-skipping GEMM drain: with
/// `block` a multiple of the matrix row length (or of `nr` along a
/// column band) the pruned blocks become whole dead micro-panels the
/// tiled kernel can elide (see `kernels::gemm::PackA::pack_a_occ`),
/// whereas unstructured masks almost never do. Same determinism and
/// NaN policy as [`magnitude_mask`]: stable sort under `total_cmp`,
/// ties prune in ascending block order, NaN scores count as large.
pub fn magnitude_block_mask(weights: &[f32], sparsity: f32, block: usize) -> Mask {
    let n = weights.len();
    assert!(block > 0, "block size must be nonzero");
    let nblocks = n.div_ceil(block);
    let kb = ((nblocks as f32) * sparsity.clamp(0.0, 1.0)).round() as usize;
    if kb == 0 {
        return Mask { keep: vec![true; n] };
    }
    let score: Vec<f32> = (0..nblocks)
        .map(|g| weights[g * block..((g + 1) * block).min(n)].iter().map(|v| v.abs()).sum())
        .collect();
    let mut idx: Vec<usize> = (0..nblocks).collect();
    idx.sort_by(|&a, &b| score[a].total_cmp(&score[b]));
    let mut keep = vec![true; n];
    for &g in &idx[..kb.min(nblocks)] {
        for k in &mut keep[g * block..((g + 1) * block).min(n)] {
            *k = false;
        }
    }
    Mask { keep }
}

/// Apply a mask in place.
pub fn apply_mask(weights: &mut [f32], mask: &Mask) {
    assert_eq!(weights.len(), mask.keep.len());
    for (w, &k) in weights.iter_mut().zip(&mask.keep) {
        if !k {
            *w = 0.0;
        }
    }
}

/// Prune a set of parameter values (only tensors with >= `min_elems`
/// elements — biases and BN scales are conventionally left dense) to the
/// given sparsity. Returns the masks so retraining can re-apply them after
/// each optimizer step.
pub fn prune_params(params: &mut [Value], sparsity: f32, min_elems: usize) -> Vec<Option<Mask>> {
    params
        .iter_mut()
        .map(|v| match v {
            Value::F32(data) if data.len() >= min_elems => {
                let mask = magnitude_mask(data, sparsity);
                apply_mask(data, &mask);
                Some(mask)
            }
            _ => None,
        })
        .collect()
}

/// Re-apply masks after a training step (pruned weights stay zero).
///
/// Every `(param, mask)` pair is validated *before* anything is
/// mutated, so a shape mismatch (e.g. a parameter that was resized or
/// retyped since [`prune_params`] built the masks) returns an error and
/// leaves all parameters exactly as they were — no partially-masked
/// state to corrupt a training run.
pub fn reapply_masks(params: &mut [Value], masks: &[Option<Mask>]) -> Result<()> {
    if params.len() != masks.len() {
        bail!("reapply_masks: {} params but {} masks", params.len(), masks.len());
    }
    for (i, (v, m)) in params.iter().zip(masks).enumerate() {
        if let Some(mask) = m {
            match v {
                Value::F32(data) if data.len() == mask.keep.len() => {}
                Value::F32(data) => bail!(
                    "reapply_masks: param {i} has {} elems but its mask covers {}",
                    data.len(),
                    mask.keep.len()
                ),
                _ => bail!("reapply_masks: param {i} is masked but no longer an F32 tensor"),
            }
        }
    }
    for (v, m) in params.iter_mut().zip(masks) {
        if let (Value::F32(data), Some(mask)) = (v, m) {
            apply_mask(data, mask);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_decays_to_final() {
        let s = PolynomialDecay { initial_sparsity: 0.7, final_sparsity: 0.9, steps: 100 };
        assert!((s.sparsity_at(0) - 0.7).abs() < 1e-6);
        assert!((s.sparsity_at(100) - 0.9).abs() < 1e-6);
        assert!((s.sparsity_at(1000) - 0.9).abs() < 1e-6);
        // monotone non-decreasing
        let mut prev = 0.0;
        for t in 0..=100 {
            let v = s.sparsity_at(t);
            assert!(v >= prev - 1e-6, "step {t}");
            prev = v;
        }
    }

    #[test]
    fn magnitude_mask_prunes_smallest() {
        let w = vec![0.1, -5.0, 0.01, 3.0, -0.2];
        let mask = magnitude_mask(&w, 0.4);
        assert_eq!(mask.keep, vec![false, true, false, true, true]);
        assert!((mask.sparsity() - 0.4).abs() < 1e-6);
        let mut w2 = w.clone();
        apply_mask(&mut w2, &mask);
        assert_eq!(w2, vec![0.0, -5.0, 0.0, 3.0, -0.2]);
    }

    #[test]
    fn prune_params_skips_small_tensors() {
        let mut params = vec![
            Value::F32(vec![1.0, 0.001, 2.0, 0.002, 3.0, 0.003, 4.0, 0.004]),
            Value::F32(vec![0.5, 0.5]), // bias-like, untouched
        ];
        let masks = prune_params(&mut params, 0.5, 4);
        assert!(masks[0].is_some());
        assert!(masks[1].is_none());
        let pruned = params[0].as_f32().unwrap().iter().filter(|&&v| v == 0.0).count();
        assert_eq!(pruned, 4);
        assert_eq!(params[1].as_f32().unwrap(), &[0.5, 0.5]);
    }

    #[test]
    fn masks_persist_through_reapply() {
        let mut params = vec![Value::F32(vec![1.0, 0.01, 2.0, 0.02])];
        let masks = prune_params(&mut params, 0.5, 2);
        // simulate a training step reviving pruned weights
        if let Value::F32(d) = &mut params[0] {
            for v in d.iter_mut() {
                *v += 1.0;
            }
        }
        reapply_masks(&mut params, &masks).unwrap();
        let d = params[0].as_f32().unwrap();
        assert_eq!(d.iter().filter(|&&v| v == 0.0).count(), 2);
    }

    #[test]
    fn magnitude_ties_prune_lowest_indices_first() {
        // Four equal magnitudes (mixed signs): pruning 50% must drop the
        // two *lowest-index* candidates, deterministically.
        let w = vec![1.0, -1.0, 1.0, -1.0];
        let mask = magnitude_mask(&w, 0.5);
        assert_eq!(mask.keep, vec![false, false, true, true]);
        // NaN counts as large under total_cmp and is kept.
        let w = vec![f32::NAN, 0.5, 2.0, 0.25];
        let mask = magnitude_mask(&w, 0.5);
        assert_eq!(mask.keep, vec![true, false, true, false]);
    }

    #[test]
    fn all_zero_and_all_equal_vectors_prune_deterministically() {
        let zeros = vec![0.0; 6];
        let mask = magnitude_mask(&zeros, 0.5);
        assert_eq!(mask.keep, vec![false, false, false, true, true, true]);
        let equal = vec![3.25; 6];
        let mask = magnitude_mask(&equal, 0.5);
        assert_eq!(mask.keep, vec![false, false, false, true, true, true]);
        // sparsity 0 / 1 extremes
        assert_eq!(magnitude_mask(&equal, 0.0).keep, vec![true; 6]);
        assert_eq!(magnitude_mask(&equal, 1.0).keep, vec![false; 6]);
        // empty input is fine
        assert_eq!(magnitude_mask(&[], 0.5).keep, Vec::<bool>::new());
    }

    #[test]
    fn min_elems_boundary_is_inclusive() {
        // len == min_elems prunes; len == min_elems - 1 is left dense.
        let mut params = vec![
            Value::F32(vec![1.0, 0.001, 2.0, 0.002]), // exactly min_elems
            Value::F32(vec![1.0, 0.001, 2.0]),        // one short
        ];
        let masks = prune_params(&mut params, 0.5, 4);
        assert!(masks[0].is_some());
        assert!(masks[1].is_none());
        assert_eq!(params[1].as_f32().unwrap(), &[1.0, 0.001, 2.0]);
    }

    #[test]
    fn block_mask_prunes_whole_low_magnitude_blocks() {
        // Three blocks of 4 (last short): scores 4.0, 0.4, ~0.03.
        let w = vec![1.0, -1.0, 1.0, 1.0, 0.1, 0.1, -0.1, 0.1, 0.01, -0.02];
        let mask = magnitude_block_mask(&w, 0.67, 4);
        // round(3 * 0.67) = 2 blocks pruned: the two lowest-scoring.
        assert_eq!(
            mask.keep,
            vec![true, true, true, true, false, false, false, false, false, false]
        );
        // Tie between equal-score blocks prunes the lower block index.
        let w = vec![0.5, 0.5, 0.5, 0.5, 9.0, 0.0];
        let mask = magnitude_block_mask(&w, 0.34, 2);
        assert_eq!(mask.keep, vec![false, false, true, true, true, true]);
        // sparsity 0 keeps everything.
        assert_eq!(magnitude_block_mask(&w, 0.0, 2).keep, vec![true; 6]);
    }

    #[test]
    fn shape_changed_reapply_errors_without_corrupting() {
        let mut params = vec![
            Value::F32(vec![1.0, 0.01, 2.0, 0.02]),
            Value::F32(vec![5.0, 0.05, 6.0, 0.06]),
        ];
        let masks = prune_params(&mut params, 0.5, 2);
        // Revive everything, then resize the *second* tensor.
        for v in &mut params {
            if let Value::F32(d) = v {
                for x in d.iter_mut() {
                    *x = 7.0;
                }
            }
        }
        if let Value::F32(d) = &mut params[1] {
            d.push(7.0);
        }
        let err = reapply_masks(&mut params, &masks).unwrap_err();
        assert!(err.to_string().contains("param 1"), "{err}");
        // Validation ran before mutation: param 0 was NOT re-masked.
        assert_eq!(params[0].as_f32().unwrap(), &[7.0; 4]);
        // Mask-count mismatch also errors.
        let err = reapply_masks(&mut params[..1], &masks).unwrap_err();
        assert!(err.to_string().contains("1 params but 2 masks"), "{err}");
    }
}
