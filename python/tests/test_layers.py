"""L2 layers: im2col structure, custom-VJP gradients vs stock XLA, and the
fused dilation/pad paths (paper §VI-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import lutgen, mults
from compile.layers import MulCfg, amconv2d, amdense, im2col

LUT = jnp.asarray(lutgen.generate(mults.by_name("afm16")))
NATIVE = MulCfg("native", 7)


def rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def conv_ref(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@pytest.mark.parametrize("stride,pad", [(1, 0), (1, 1), (2, 1), (2, 0), (3, 1)])
def test_forward_matches_stock_conv(stride, pad):
    rng = np.random.default_rng(1)
    x = rand(rng, (2, 9, 9, 3))
    w = rand(rng, (3, 3, 3, 5))
    got = amconv2d(NATIVE, x, w, stride, pad, None)
    want = conv_ref(x, w, stride, pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,pad", [(1, 1), (2, 1), (2, 0)])
def test_conv_gradients_match_stock(stride, pad):
    """The restructured backward (fused dilation weight-grad + pad/dilate
    PLG + transpose-reverse) must equal XLA's autodiff of the stock conv."""
    rng = np.random.default_rng(2)
    x = rand(rng, (2, 8, 8, 2))
    w = rand(rng, (3, 3, 2, 4))

    def loss_custom(x, w):
        return jnp.sum(jnp.sin(amconv2d(NATIVE, x, w, stride, pad, None)))

    def loss_ref(x, w):
        return jnp.sum(jnp.sin(conv_ref(x, w, stride, pad)))

    gx, gw = jax.grad(loss_custom, argnums=(0, 1))(x, w)
    rgx, rgw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rgx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rgw), rtol=1e-4, atol=1e-4)


def test_dense_gradients_match_stock():
    rng = np.random.default_rng(3)
    x = rand(rng, (4, 6))
    w = rand(rng, (6, 3))
    b = rand(rng, (3,))

    def loss_custom(x, w, b):
        return jnp.sum(jnp.cos(amdense(NATIVE, x, w, b, None)))

    def loss_ref(x, w, b):
        return jnp.sum(jnp.cos(x @ w + b))

    g = jax.grad(loss_custom, argnums=(0, 1, 2))(x, w, b)
    rg = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g, rg):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-5,
                                   atol=1e-5)


def test_im2col_ordering_matches_rust():
    """(ky, kx, c) minor ordering — the contract shared with
    rust/src/kernels/im2col.rs (1x1 kernel: identity)."""
    x = jnp.arange(2 * 3 * 3 * 2, dtype=jnp.float32).reshape(2, 3, 3, 2)
    cols, (oh, ow) = im2col(x, 1, 1, 1, 0)
    assert (oh, ow) == (3, 3)
    np.testing.assert_array_equal(np.asarray(cols).ravel(),
                                  np.asarray(x).ravel())


def test_im2col_padding_zeros():
    x = jnp.ones((1, 4, 4, 1), jnp.float32)
    cols, _ = im2col(x, 3, 3, 1, 1)
    first_patch = np.asarray(cols)[0]
    assert (first_patch == 0).sum() == 5  # top-left corner padding
    assert (first_patch == 1).sum() == 4


def test_approximate_conv_close_to_exact():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(0, 1, (1, 8, 8, 2)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.3, (3, 3, 2, 4)).astype(np.float32))
    cfg = MulCfg("lut", 7)
    approx = amconv2d(cfg, x, w, 1, 1, LUT)
    exact = conv_ref(x, w, 1, 1)
    # AFM16 per-multiply error ~1%, 18-term dot products
    err = np.max(np.abs(np.asarray(approx) - np.asarray(exact)))
    assert err < 0.25, err


def test_approximate_gradients_flow():
    """Gradients through the approximate path are themselves approximate
    but must be descent directions on a simple quadratic."""
    rng = np.random.default_rng(5)
    cfg = MulCfg("lut", 7)
    x = jnp.asarray(rng.uniform(0.1, 1, (4, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.5, (6, 3)).astype(np.float32))
    b = jnp.zeros((3,), jnp.float32)
    target = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))

    def loss(w):
        y = amdense(cfg, x, w, b, LUT)
        return jnp.mean((y - target) ** 2)

    g = jax.grad(loss)(w)
    l0 = float(loss(w))
    l1 = float(loss(w - 0.05 * g))
    assert l1 < l0, f"approximate gradient is not a descent direction: {l0} -> {l1}"
