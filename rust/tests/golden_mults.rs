//! Golden-vector bit-exactness suite for every tabulatable multiplier.
//!
//! For every registered multiplier with m <= 8, a structured operand grid
//! (all exponent pairs at mantissa corners, the full mantissa x mantissa
//! grid at boundary exponents, signed zeros, subnormals, the exp=254 +
//! mantissa-carry overflow edge) is swept asserting that the three
//! simulation paths agree **bit for bit**:
//!
//! 1. `AmSim::mul_bits` — Algorithm 2 over the generated LUT;
//! 2. `mul_via_mantissa` — the direct functional model (ATxC);
//! 3. the batched panel path (`MulBackend::mul_panel` for both the LUT
//!    and Direct kernels) — the code the GEMM/conv/dense hot loops run.
//!
//! The single sanctioned difference: AMSim's flush-to-zero returns
//! *unsigned* zero (Alg. 2 line 14) where the direct model keeps the
//! product sign, so comparisons are modulo the sign of zero.
//!
//! AMSim's domain is finite operands (biased exponent fields 0..=254):
//! Algorithm 2 has no Inf/NaN lanes, and an exp=255 operand would be
//! treated as an ordinary huge exponent. Inf/NaN behaviour is therefore
//! asserted against the *direct* model only — see
//! `direct_models_handle_ieee_specials`: models declaring the
//! `zero_identity` capability gate a zero operand to a signed zero even
//! against Inf/NaN, the rest delegate IEEE specials to hardware
//! semantics. The declared flag itself is audited against brute force in
//! `declared_zero_identity_flags_match_brute_force` — it is the
//! machine-checked license for the zero-skipping GEMM drain
//! (`kernels::gemm`), so a wrong flag is a silent bit-exactness bug.

use approxtrain::amsim::AmSim;
use approxtrain::kernels::{MulBackend, MulKernel, SimdLevel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::fpbits::{MANT_BITS, MANT_MASK};
use approxtrain::mult::{registry, ApproxMul};
use approxtrain::util::simd;

/// Widest mantissa this suite sweeps exhaustively (m <= 8 keeps the full
/// mantissa grid at 2^16 pairs per exponent pair).
const MAX_GOLDEN_M: u32 = 8;

fn golden_models() -> Vec<Box<dyn ApproxMul>> {
    registry::names()
        .iter()
        .filter_map(|name| registry::by_name(name))
        .filter(|m| m.mantissa_bits() <= MAX_GOLDEN_M)
        .collect()
}

fn bits(sign: u32, exp: u32, mant: u32) -> u32 {
    debug_assert!(sign <= 1 && exp <= 255 && mant <= MANT_MASK);
    (sign << 31) | (exp << MANT_BITS) | mant
}

/// Representable m-bit mantissa fields at the corners of the range.
fn mantissa_corners(m: u32) -> Vec<u32> {
    let top = (1u32 << m) - 1;
    let mut vals = vec![0, 1, top / 2, top.saturating_sub(1), top];
    vals.sort_unstable();
    vals.dedup();
    vals.iter().map(|v| v << (MANT_BITS - m)).collect()
}

/// Equal bit patterns, treating +0.0 and -0.0 as equal (the sanctioned
/// AMSim flush-to-zero sign difference).
fn eq_mod_zero_sign(x: u32, y: u32) -> bool {
    x == y || (x & 0x7FFF_FFFF == 0 && y & 0x7FFF_FFFF == 0)
}

/// The three-way golden check over a list of operand bit-pairs: scalar
/// LUT vs scalar direct, then both batched panel kernels vs their scalar
/// counterparts (in 4096-pair panels, the hot-loop shape).
fn check_golden(name: &str, model: &dyn ApproxMul, lut: &MantissaLut, pairs: &[(u32, u32)]) {
    let sim = AmSim::new(lut);
    for &(ab, bb) in pairs {
        let via_lut = sim.mul_bits(ab, bb);
        let direct = model.mul(f32::from_bits(ab), f32::from_bits(bb)).to_bits();
        assert!(
            eq_mod_zero_sign(via_lut, direct),
            "{name}: {:e} ({ab:#010x}) * {:e} ({bb:#010x}): lut {via_lut:#010x} != direct {direct:#010x}",
            f32::from_bits(ab),
            f32::from_bits(bb),
        );
    }
    let lut_kernel = MulKernel::Lut(AmSim::new(lut));
    let direct_kernel = MulKernel::Direct(model);
    for chunk in pairs.chunks(4096) {
        let av: Vec<f32> = chunk.iter().map(|&(a, _)| f32::from_bits(a)).collect();
        let bv: Vec<f32> = chunk.iter().map(|&(_, b)| f32::from_bits(b)).collect();
        let mut out = vec![0.0f32; chunk.len()];
        lut_kernel.mul_panel(&av, &bv, &mut out);
        for (i, &(ab, bb)) in chunk.iter().enumerate() {
            assert_eq!(
                out[i].to_bits(),
                sim.mul_bits(ab, bb),
                "{name}: batched LUT panel diverged from mul_bits at {ab:#010x} * {bb:#010x}"
            );
        }
        direct_kernel.mul_panel(&av, &bv, &mut out);
        for (i, (&(ab, bb), (&a, &b))) in
            chunk.iter().zip(av.iter().zip(bv.iter())).enumerate()
        {
            assert_eq!(
                out[i].to_bits(),
                model.mul(a, b).to_bits(),
                "{name}: batched Direct panel diverged from scalar mul at {ab:#010x} * {bb:#010x}"
            );
        }
    }
}

/// All exponent pairs (1..=254 on each side) at mantissa corners — the
/// flush-to-zero (`ea + eb - 127 <= 0`) and overflow (`>= 255`, with and
/// without carry) boundaries are all crossed, for every model.
#[test]
fn all_exponent_pairs_at_mantissa_corners() {
    for model in golden_models() {
        let m = model.mantissa_bits();
        let lut = MantissaLut::generate(model.as_ref());
        // two corners keep the grid at 254^2 * 4 * 2 pairs per model;
        // zero mantissa exercises no-carry, full mantissa forces the
        // carry wherever the design produces one
        let corners = [0u32, MANT_MASK & (MANT_MASK << (MANT_BITS - m))];
        let mut pairs = Vec::with_capacity(254 * 254 * corners.len() * corners.len() * 2);
        for ea in 1..=254u32 {
            for eb in 1..=254u32 {
                for &ma in &corners {
                    for &mb in &corners {
                        pairs.push((bits(0, ea, ma), bits(0, eb, mb)));
                        pairs.push((bits(1, ea, ma), bits(0, eb, mb)));
                    }
                }
            }
        }
        // densify at the boundary exponents: the full 5x5 mantissa-corner
        // cross (both signs) where flush/overflow behaviour pivots
        let dense_corners = mantissa_corners(m);
        for (ea, eb) in
            [(1u32, 1u32), (1, 126), (2, 125), (126, 127), (127, 127), (127, 128), (253, 254), (254, 254)]
        {
            for &ma in &dense_corners {
                for &mb in &dense_corners {
                    for (sa, sb) in [(0u32, 0u32), (1, 0), (1, 1)] {
                        pairs.push((bits(sa, ea, ma), bits(sb, eb, mb)));
                    }
                }
            }
        }
        check_golden(model.name(), model.as_ref(), &lut, &pairs);
    }
}

/// The full m-bit mantissa x mantissa grid at boundary exponent pairs:
/// mid-range (127,127), the flush boundary (1,127 — product exponent 1),
/// and the overflow boundary (254,127 — exponent 254, so any mantissa
/// carry must saturate to infinity, the Algorithm-2 edge case).
#[test]
fn full_mantissa_grid_at_boundary_exponents() {
    for model in golden_models() {
        let m = model.mantissa_bits();
        let lut = MantissaLut::generate(model.as_ref());
        let shift = MANT_BITS - m;
        let mut pairs = Vec::with_capacity((1usize << (2 * m)) * 3);
        for (ea, eb) in [(127u32, 127u32), (1, 127), (254, 127)] {
            for ka in 0..(1u32 << m) {
                for kb in 0..(1u32 << m) {
                    pairs.push((bits(0, ea, ka << shift), bits(0, eb, kb << shift)));
                }
            }
        }
        check_golden(model.name(), model.as_ref(), &lut, &pairs);
    }
}

/// Signed zeros, subnormals (which AMSim and the models both flush) and
/// the exp=254 + carry overflow edge, against normal partners.
#[test]
fn special_operands_and_overflow_edge() {
    for model in golden_models() {
        let m = model.mantissa_bits();
        let lut = MantissaLut::generate(model.as_ref());
        let top_mant = MANT_MASK & (MANT_MASK << (MANT_BITS - m));
        let specials = [
            bits(0, 0, 0),             // +0.0
            bits(1, 0, 0),             // -0.0
            bits(0, 0, 1),             // smallest positive subnormal
            bits(1, 0, top_mant),      // large negative subnormal
            bits(0, 1, 0),             // smallest positive normal
            bits(0, 254, top_mant),    // largest finite (m-bit)
            bits(1, 254, top_mant),    // most negative finite (m-bit)
            bits(0, 127, top_mant),    // just under 2.0
            bits(1, 100, 1 << (MANT_BITS - m)),
        ];
        let mut pairs = Vec::new();
        for &a in &specials {
            for &b in &specials {
                pairs.push((a, b));
            }
        }
        check_golden(model.name(), model.as_ref(), &lut, &pairs);

        // the documented Algorithm-2 deviation: exponent sum 254 plus a
        // mantissa carry must saturate to +-inf (with the product sign),
        // never assemble exp=255 with a nonzero (NaN) mantissa — on every
        // path. Whether this operand pair carries is the model's own
        // business, so ask its mantissa_product for the expected outcome.
        let sim = AmSim::new(&lut);
        let a = bits(0, 190, top_mant);
        let b = bits(1, 191, top_mant); // exponent sum 190 + 191 - 127 = 254
        let (carry, _) = model.mantissa_product(top_mant, top_mant);
        for got in [
            sim.mul_bits(a, b),
            model.mul(f32::from_bits(a), f32::from_bits(b)).to_bits(),
        ] {
            let v = f32::from_bits(got);
            assert!(!v.is_nan(), "{}: overflow edge produced NaN {got:#010x}", model.name());
            if carry == 1 {
                assert!(
                    v.is_infinite() && v < 0.0,
                    "{}: exp 254 + carry must saturate to -inf, got {v} ({got:#010x})",
                    model.name()
                );
            } else {
                assert!(
                    v.is_finite(),
                    "{}: exp 254 without carry must stay finite, got {v}",
                    model.name()
                );
            }
        }
    }
}

/// Inf/NaN operands are outside AMSim's domain (module docs). With a
/// *nonzero* partner every direct model delegates them to IEEE hardware
/// semantics; with a zero partner the behaviour splits on the declared
/// `zero_identity` capability — zero-dominant designs gate the product
/// to a signed zero before the special lanes ever run, IEEE baselines
/// keep the hardware invalid-operation NaN.
#[test]
fn direct_models_handle_ieee_specials() {
    for model in golden_models() {
        let name = model.name();
        // nonzero x inf/NaN is hardware semantics on every model
        assert_eq!(model.mul(f32::INFINITY, 2.0), f32::INFINITY, "{name}");
        assert_eq!(model.mul(f32::NEG_INFINITY, 2.0), f32::NEG_INFINITY, "{name}");
        assert_eq!(model.mul(f32::INFINITY, -3.0), f32::NEG_INFINITY, "{name}");
        assert!(model.mul(f32::NAN, 1.5).is_nan(), "{name}: nan*x");
        assert!(model.mul(2.5, f32::NAN).is_nan(), "{name}: x*nan");
        if model.zero_identity() {
            // zero-dominant: the property that licenses zero-skipping
            assert_eq!(
                model.mul(f32::INFINITY, 0.0).to_bits(),
                0.0f32.to_bits(),
                "{name}: inf*0"
            );
            assert_eq!(
                model.mul(f32::INFINITY, -0.0).to_bits(),
                (-0.0f32).to_bits(),
                "{name}: inf*-0"
            );
            assert_eq!(model.mul(f32::NAN, 0.0).to_bits(), 0.0f32.to_bits(), "{name}: nan*0");
        } else {
            assert!(model.mul(f32::INFINITY, 0.0).is_nan(), "{name}: inf*0");
            assert!(model.mul(0.0, f32::NAN).is_nan(), "{name}: 0*nan");
        }
    }
}

/// Machine-checked audit of the `zero_identity` capability flag — the
/// license for the zero-skipping GEMM drain (`kernels::gemm`). For
/// **every** registered multiplier (not just the tabulatable ones), the
/// declared flag must match brute force: `mul(±0, x)` and `mul(x, ±0)`
/// over exponent/mantissa corners and the IEEE specials (signed zeros,
/// subnormals, ±inf, NaN payloads of both signs) either *always* yield
/// the signed zero of the IEEE product sign (flag true) or violate that
/// somewhere (flag false — a model that satisfies the identity but does
/// not declare it runs the dense drain for nothing, which this test also
/// refuses to let pass silently).
#[test]
fn declared_zero_identity_flags_match_brute_force() {
    let mut xs: Vec<u32> = Vec::new();
    for exp in [0u32, 1, 2, 126, 127, 128, 253, 254, 255] {
        for mant in [0u32, 1, MANT_MASK / 2, MANT_MASK] {
            for sign in [0u32, 1] {
                // exp=255 rows: mant=0 is +-inf, mant!=0 are NaN payloads
                xs.push(bits(sign, exp, mant));
            }
        }
    }
    for name in registry::names() {
        let model = registry::by_name(name).unwrap();
        let declared = model.zero_identity();
        assert_eq!(
            declared,
            registry::zero_identity(name),
            "{name}: registry helper disagrees with the model"
        );
        let mut violation: Option<(u32, u32, u32, u32)> = None;
        for &xb in &xs {
            let x = f32::from_bits(xb);
            for zb in [0u32, 1u32 << 31] {
                let z = f32::from_bits(zb);
                // the contract: a zero of the IEEE product (XOR) sign
                let want = (xb ^ zb) & (1u32 << 31);
                for (a, b) in [(z, x), (x, z)] {
                    let got = model.mul(a, b).to_bits();
                    if got != want && violation.is_none() {
                        violation = Some((a.to_bits(), b.to_bits(), got, want));
                    }
                }
            }
        }
        match (declared, violation) {
            (true, Some((a, b, got, want))) => panic!(
                "{name} declares zero_identity but mul({a:#010x}, {b:#010x}) = \
                 {got:#010x}, want {want:#010x} — skipping this model's dead \
                 panels would change bits"
            ),
            (false, None) => panic!(
                "{name} satisfies the zero identity on the whole corner sweep \
                 but does not declare it — the sparse drain falls back to dense \
                 for nothing; declare the flag (or add the violating operand \
                 to this sweep)"
            ),
            _ => {}
        }
    }
}

/// The vectorized decomposition/assembly arm (`amsim::simd`) must push
/// every golden operand through the *same* Algorithm-2 sequence as the
/// scalar path — per lane. For every tabulatable model and every
/// machine-executable [`SimdLevel`], the boundary-exponent
/// mantissa-corner grid plus the specials list (signed zeros,
/// subnormals, overflow edges, and raw exp=255 patterns, which AMSim
/// treats as ordinary huge exponents) is run through the batched panel
/// at three rotations, so each pair lands at lane positions 0, mid and
/// tail of the 8-wide vectors (and in the scalar-tail remainder),
/// asserted bitwise against the scalar-forced `mul_bits`.
#[test]
fn vectorized_decomposition_matches_scalar_per_lane_position() {
    const LANES: usize = 8;
    for model in golden_models() {
        let m = model.mantissa_bits();
        let lut = MantissaLut::generate(model.as_ref());
        let scalar = AmSim::with_simd(&lut, SimdLevel::Scalar);
        let top_mant = MANT_MASK & (MANT_MASK << (MANT_BITS - m));
        let dense = mantissa_corners(m);
        let mut pairs = Vec::new();
        for (ea, eb) in
            [(1u32, 1u32), (1, 126), (126, 127), (127, 127), (127, 128), (254, 127), (253, 254), (254, 254)]
        {
            for &ma in &dense {
                for &mb in &dense {
                    for (sa, sb) in [(0u32, 0u32), (1, 0), (1, 1)] {
                        pairs.push((bits(sa, ea, ma), bits(sb, eb, mb)));
                    }
                }
            }
        }
        let specials = [
            bits(0, 0, 0),          // +0.0 (flush lane)
            bits(1, 0, 0),          // -0.0
            bits(0, 0, 1),          // subnormal (flush lane)
            bits(0, 1, 0),          // smallest normal
            bits(0, 254, top_mant), // largest finite (m-bit)
            bits(1, 254, top_mant),
            bits(0, 255, 0),        // exp=255: huge-exponent lane, not IEEE inf
            bits(1, 255, top_mant),
            bits(0, 127, top_mant),
        ];
        for &a in &specials {
            for &b in &specials {
                pairs.push((a, b));
            }
        }
        let want: Vec<u32> = pairs.iter().map(|&(a, b)| scalar.mul_bits(a, b)).collect();
        let n = pairs.len();
        for level in simd::available_levels() {
            let kernel = MulKernel::Lut(AmSim::with_simd(&lut, level));
            for rot in [0usize, LANES / 2, LANES - 1] {
                let av: Vec<f32> =
                    (0..n).map(|i| f32::from_bits(pairs[(i + rot) % n].0)).collect();
                let bv: Vec<f32> =
                    (0..n).map(|i| f32::from_bits(pairs[(i + rot) % n].1)).collect();
                let mut out = vec![0.0f32; n];
                kernel.mul_panel(&av, &bv, &mut out);
                for i in 0..n {
                    let (ab, bb) = pairs[(i + rot) % n];
                    assert_eq!(
                        out[i].to_bits(),
                        want[(i + rot) % n],
                        "{}@{level} rot {rot}: lane {} of {ab:#010x} * {bb:#010x}",
                        model.name(),
                        i % LANES,
                    );
                }
            }
        }
    }
}

/// Every tabulatable registry multiplier yields a LUT with the full
/// `2^(2m)` entry payload (`payload_bytes() == 4 * 2^(2m)`) that passes
/// structural validation — the invariant `AmSim::new` relies on to elide
/// its per-gather bounds check.
#[test]
fn registry_luts_have_full_payload_and_validate() {
    for name in registry::names() {
        if !registry::lut_able(name) {
            continue;
        }
        let model = registry::by_name(name).unwrap();
        let m = model.mantissa_bits();
        let lut = MantissaLut::generate(model.as_ref());
        assert_eq!(lut.len(), 1usize << (2 * m), "{name}: entry count");
        assert_eq!(lut.payload_bytes(), 4usize << (2 * m), "{name}: payload bytes");
        lut.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}
