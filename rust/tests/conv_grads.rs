//! Conv-layer gradient correctness and implicit-GEMM bit-identity.
//!
//! Three nets:
//! 1. finite-difference checks of `amconv2d::weight_grad` and
//!    `amconv2d::input_grad` under the *fp32 multiplier* (the exact
//!    `MulKernel::Direct(fp32)` functional model), tolerance-based —
//!    running on the implicit-GEMM path the layers now use;
//! 2. bit-identity of all three conv GEMMs (forward, weight-grad,
//!    preceding-layer-grad) against both the materialized-im2col route
//!    and `gemm_scalar_reference` run over the materialized im2col
//!    matrices — for every simulation strategy, across the
//!    `(stride, pad)` grid of the acceptance sweep, on non-square inputs;
//! 3. the same bit-identity at degenerate and oversized `TileConfig`s and
//!    several thread counts through `gemm_tiled_src` directly, plus a
//!    no-allocation smoke check that a second conv pass reuses the
//!    recycled thread-local pack/scratch buffers.

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm_scalar_reference, gemm_tiled_src, SliceB, TileConfig};
use approxtrain::kernels::im2col::{
    im2col_forward, im2col_plg, im2col_weight_grad, Im2colForwardSrc, Im2colPlgSrc,
    Im2colWeightGradSrc,
};
use approxtrain::kernels::transpose_reverse::transpose_reverse;
use approxtrain::kernels::{buffer_growth_events, Conv2dGeom, MulKernel};
use approxtrain::layers::amconv2d;
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::tensor::Tensor;
use approxtrain::util::rng::Pcg32;

fn rand_tensor(shape: &[usize], rng: &mut Pcg32) -> Tensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range(-1.0, 1.0)).collect())
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what} idx {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

/// The acceptance contract for one geometry and one strategy: all three
/// conv GEMMs must agree bit for bit between the implicit route (what the
/// layers run), the materialized-im2col route (the kept oracle), and the
/// per-element scalar reference over the materialized cols matrices.
fn check_conv_bitwise(mul: &MulKernel, g: &Conv2dGeom, x: &Tensor, w: &Tensor, label: &str) {
    let (stride, pad) = (g.stride, g.pad);

    // forward
    let y = amconv2d::forward(mul, x, w, stride, pad);
    let y_mat = amconv2d::forward_materialized(mul, x, w, stride, pad);
    assert_bits(&y.data, &y_mat.data, &format!("{label}: fwd implicit vs materialized"));
    let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
    im2col_forward(g, &x.data, &mut cols);
    let mut y_ref = vec![0.0f32; g.col_rows() * g.out_c];
    gemm_scalar_reference(mul, &cols, &w.data, &mut y_ref, g.col_rows(), g.col_cols(), g.out_c);
    assert_bits(&y.data, &y_ref, &format!("{label}: fwd vs scalar oracle"));

    let dy = rand_tensor(&y.shape, &mut Pcg32::seeded(7300 + (stride * 10 + pad) as u64));

    // weight grad
    let dw = amconv2d::weight_grad(mul, x, &dy, &w.shape, stride, pad);
    let dw_mat = amconv2d::weight_grad_materialized(mul, x, &dy, &w.shape, stride, pad);
    assert_bits(&dw.data, &dw_mat.data, &format!("{label}: dw implicit vs materialized"));
    let q = g.batch * g.out_h() * g.out_w();
    let mut wg_cols = vec![0.0f32; g.col_cols() * q];
    im2col_weight_grad(g, &x.data, &mut wg_cols);
    let mut dw_ref = vec![0.0f32; g.col_cols() * g.out_c];
    gemm_scalar_reference(mul, &wg_cols, &dy.data, &mut dw_ref, g.col_cols(), q, g.out_c);
    assert_bits(&dw.data, &dw_ref, &format!("{label}: dw vs scalar oracle"));

    // preceding-layer grad
    let x_shape = [g.batch, g.in_h, g.in_w, g.in_c];
    let dx = amconv2d::input_grad(mul, &dy, w, &x_shape, stride, pad);
    let dx_mat = amconv2d::input_grad_materialized(mul, &dy, w, &x_shape, stride, pad);
    assert_bits(&dx.data, &dx_mat.data, &format!("{label}: dx implicit vs materialized"));
    let rows = g.batch * g.in_h * g.in_w;
    let rlen = g.k_h * g.k_w * g.out_c;
    let mut plg_cols = vec![0.0f32; rows * rlen];
    im2col_plg(g, &dy.data, &mut plg_cols);
    let wrt = transpose_reverse(&w.data, g.k_h, g.k_w, g.in_c, g.out_c);
    let mut dx_ref = vec![0.0f32; rows * g.in_c];
    gemm_scalar_reference(mul, &plg_cols, &wrt, &mut dx_ref, rows, rlen, g.in_c);
    assert_bits(&dx.data, &dx_ref, &format!("{label}: dx vs scalar oracle"));
}

/// Finite-difference check of both backward kernels under the fp32
/// multiplier functional model (exact, but exercised through the Direct
/// dispatch path the approximate designs use).
#[test]
fn gradients_match_finite_differences_under_fp32_direct() {
    let fp32 = registry::by_name("fp32").unwrap();
    let mul = MulKernel::Direct(fp32.as_ref());
    let mut rng = Pcg32::seeded(71);
    for (stride, pad) in [(1usize, 1usize), (2, 1)] {
        let x = rand_tensor(&[1, 6, 6, 2], &mut rng);
        let w = rand_tensor(&[3, 3, 2, 3], &mut rng);
        let y = amconv2d::forward(&mul, &x, &w, stride, pad);
        let dy = rand_tensor(&y.shape, &mut rng);
        let dw = amconv2d::weight_grad(&mul, &x, &dy, &w.shape, stride, pad);
        let dx = amconv2d::input_grad(&mul, &dy, &w, &x.shape, stride, pad);

        let loss = |x: &Tensor, w: &Tensor| -> f32 {
            let y = amconv2d::forward(&mul, x, w, stride, pad);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        for i in (0..w.len()).step_by(5) {
            let mut wp = w.clone();
            wp.data[i] += eps;
            let mut wm = w.clone();
            wm.data[i] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
            assert!(
                (num - dw.data[i]).abs() < 2e-2,
                "stride {stride} pad {pad}: dw[{i}] {num} vs {}",
                dw.data[i]
            );
        }
        for i in (0..x.len()).step_by(7) {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 2e-2,
                "stride {stride} pad {pad}: dx[{i}] {num} vs {}",
                dx.data[i]
            );
        }
    }
}

/// The three conv GEMMs, replayed through the per-element scalar oracle
/// over the layer's own im2col matrices, must match the layer outputs
/// bit for bit — at stride 2, pad 1, on a non-square input, for every
/// strategy (the acceptance contract of the tiled kernel as seen from
/// the conv layer).
#[test]
fn conv_gemms_bitwise_match_scalar_reference_at_odd_shapes() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let strategies = [
        MulKernel::Native,
        MulKernel::Direct(model.as_ref()),
        MulKernel::Lut(AmSim::new(&lut)),
    ];
    let (stride, pad) = (2usize, 1usize);
    let g = Conv2dGeom {
        batch: 2,
        in_h: 7,
        in_w: 9,
        in_c: 3,
        k_h: 3,
        k_w: 3,
        out_c: 5,
        stride,
        pad,
    };
    let mut rng = Pcg32::seeded(72);
    let x = rand_tensor(&[g.batch, g.in_h, g.in_w, g.in_c], &mut rng);
    let w = rand_tensor(&[g.k_h, g.k_w, g.in_c, g.out_c], &mut rng);
    for mul in &strategies {
        let label = mul.describe();

        // forward: y = im2col(x) * w
        let y = amconv2d::forward(mul, &x, &w, stride, pad);
        let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
        im2col_forward(&g, &x.data, &mut cols);
        let mut y_ref = vec![0.0f32; g.col_rows() * g.out_c];
        gemm_scalar_reference(mul, &cols, &w.data, &mut y_ref, g.col_rows(), g.col_cols(), g.out_c);
        assert_eq!(y.data.len(), y_ref.len(), "{label}: forward shape");
        for i in 0..y_ref.len() {
            assert_eq!(y.data[i].to_bits(), y_ref[i].to_bits(), "{label}: forward idx {i}");
        }

        let dy = rand_tensor(&y.shape, &mut Pcg32::seeded(73));

        // weight grad: dw = im2col_wg(x) * dy
        let dw = amconv2d::weight_grad(mul, &x, &dy, &w.shape, stride, pad);
        let q = g.batch * g.out_h() * g.out_w();
        let mut wg_cols = vec![0.0f32; g.col_cols() * q];
        im2col_weight_grad(&g, &x.data, &mut wg_cols);
        let mut dw_ref = vec![0.0f32; g.col_cols() * g.out_c];
        gemm_scalar_reference(mul, &wg_cols, &dy.data, &mut dw_ref, g.col_cols(), q, g.out_c);
        assert_eq!(dw.data.len(), dw_ref.len(), "{label}: dw shape");
        for i in 0..dw_ref.len() {
            assert_eq!(dw.data[i].to_bits(), dw_ref[i].to_bits(), "{label}: dw idx {i}");
        }

        // preceding-layer grad: dx = im2col_plg(dy) * transpose_reverse(w)
        let dx = amconv2d::input_grad(mul, &dy, &w, &x.shape, stride, pad);
        let rows = g.batch * g.in_h * g.in_w;
        let rlen = g.k_h * g.k_w * g.out_c;
        let mut plg_cols = vec![0.0f32; rows * rlen];
        im2col_plg(&g, &dy.data, &mut plg_cols);
        let wrt = transpose_reverse(&w.data, g.k_h, g.k_w, g.in_c, g.out_c);
        let mut dx_ref = vec![0.0f32; rows * g.in_c];
        gemm_scalar_reference(mul, &plg_cols, &wrt, &mut dx_ref, rows, rlen, g.in_c);
        assert_eq!(dx.data.len(), dx_ref.len(), "{label}: dx shape");
        for i in 0..dx_ref.len() {
            assert_eq!(dx.data[i].to_bits(), dx_ref[i].to_bits(), "{label}: dx idx {i}");
        }
    }
}

/// The acceptance sweep: implicit-GEMM conv == materialized-im2col conv
/// == `gemm_scalar_reference` for native / direct / LUT across the full
/// `(stride, pad)` grid on a non-square input.
#[test]
fn implicit_equals_materialized_equals_scalar_across_stride_pad_grid() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    for (stride, pad) in [(1usize, 0usize), (1, 1), (2, 1), (2, 0), (3, 1)] {
        let g = Conv2dGeom {
            batch: 2,
            in_h: 7,
            in_w: 9,
            in_c: 3,
            k_h: 3,
            k_w: 3,
            out_c: 5,
            stride,
            pad,
        };
        let mut rng = Pcg32::seeded(750 + (stride * 10 + pad) as u64);
        let x = rand_tensor(&[g.batch, g.in_h, g.in_w, g.in_c], &mut rng);
        let w = rand_tensor(&[g.k_h, g.k_w, g.in_c, g.out_c], &mut rng);
        for mul in [
            MulKernel::Native,
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(AmSim::new(&lut)),
        ] {
            let label = format!("s{stride}p{pad} {}", mul.describe());
            check_conv_bitwise(&mul, &g, &x, &w, &label);
        }
    }
}

/// The implicit im2col panel sources through `gemm_tiled_src` must match
/// the scalar oracle at degenerate, block-straddling and oversized
/// `TileConfig`s and at several thread counts — the implicit analog of
/// the slice-path geometry sweep in `batched_vs_scalar.rs`.
#[test]
fn implicit_sources_bitwise_stable_across_tile_geometries_and_threads() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let g = Conv2dGeom {
        batch: 2,
        in_h: 7,
        in_w: 9,
        in_c: 3,
        k_h: 3,
        k_w: 3,
        out_c: 5,
        stride: 2,
        pad: 1,
    };
    let mut rng = Pcg32::seeded(76);
    let x = rand_tensor(&[g.batch, g.in_h, g.in_w, g.in_c], &mut rng);
    let w = rand_tensor(&[g.k_h, g.k_w, g.in_c, g.out_c], &mut rng);
    let dy = rand_tensor(&[g.batch, g.out_h(), g.out_w(), g.out_c], &mut rng);
    let wrt = transpose_reverse(&w.data, g.k_h, g.k_w, g.in_c, g.out_c);

    let q = g.batch * g.out_h() * g.out_w();
    let rows = g.batch * g.in_h * g.in_w;
    let rlen = g.k_h * g.k_w * g.out_c;
    let mut fwd_cols = vec![0.0f32; g.col_rows() * g.col_cols()];
    im2col_forward(&g, &x.data, &mut fwd_cols);
    let mut wg_cols = vec![0.0f32; g.col_cols() * q];
    im2col_weight_grad(&g, &x.data, &mut wg_cols);
    let mut plg_cols = vec![0.0f32; rows * rlen];
    im2col_plg(&g, &dy.data, &mut plg_cols);

    let configs = [
        TileConfig { mc: 1, kc: 1, nc: 1, mr: 1, nr: 1 },
        TileConfig { mc: 3, kc: 5, nc: 2, mr: 2, nr: 2 },
        TileConfig::DEFAULT,
        TileConfig { mc: 512, kc: 512, nc: 512, mr: 8, nr: 16 },
    ];
    let fwd_src = Im2colForwardSrc::new(&g, &x.data);
    let wg_src = Im2colWeightGradSrc::new(&g, &x.data);
    let plg_src = Im2colPlgSrc::new(&g, &dy.data);
    for mul in [
        MulKernel::Native,
        MulKernel::Direct(model.as_ref()),
        MulKernel::Lut(AmSim::new(&lut)),
    ] {
        // (source, materialized cols, B operand, m, k, n, label)
        #[allow(clippy::type_complexity)]
        let gemms: [(&dyn approxtrain::kernels::gemm::PackA, &[f32], &[f32], usize, usize, usize, &str);
            3] = [
            (
                &fwd_src,
                fwd_cols.as_slice(),
                w.data.as_slice(),
                g.col_rows(),
                g.col_cols(),
                g.out_c,
                "fwd",
            ),
            (&wg_src, wg_cols.as_slice(), dy.data.as_slice(), g.col_cols(), q, g.out_c, "wg"),
            (&plg_src, plg_cols.as_slice(), wrt.as_slice(), rows, rlen, g.in_c, "plg"),
        ];
        for (src, cols, b, m, k, n, what) in gemms {
            let mut want = vec![0.0f32; m * n];
            gemm_scalar_reference(&mul, cols, b, &mut want, m, k, n);
            for cfg in configs {
                for threads in [1usize, 2, 8] {
                    let mut got = vec![0.0f32; m * n];
                    gemm_tiled_src(&mul, cfg, src, &SliceB { data: b, n }, &mut got, m, k, n, threads);
                    assert_bits(
                        &got,
                        &want,
                        &format!("{what} {} {cfg:?} t={threads}", mul.describe()),
                    );
                }
            }
        }
    }
}

/// No-allocation smoke check: after a warm first pass, a second conv
/// forward + backward at the same geometry must not grow the recycled
/// thread-local pack/scratch buffers — i.e. the implicit path performs no
/// per-call cols-matrix allocation (the sizes are small enough that
/// `gemm_auto_src` stays single-lane on this thread, so the thread-local
/// growth counter observes every packing).
#[test]
fn second_conv_pass_reuses_recycled_buffers() {
    let mut rng = Pcg32::seeded(77);
    let x = rand_tensor(&[1, 8, 8, 2], &mut rng);
    let w = rand_tensor(&[3, 3, 2, 3], &mut rng);
    let mul = MulKernel::Native;
    let run = |dy: &Tensor| {
        let y = amconv2d::forward(&mul, &x, &w, 1, 1);
        let dw = amconv2d::weight_grad(&mul, &x, dy, &w.shape, 1, 1);
        let dx = amconv2d::input_grad(&mul, dy, &w, &x.shape, 1, 1);
        (y, dw, dx)
    };
    let dy = rand_tensor(&[1, 8, 8, 3], &mut rng);
    let first = run(&dy); // warms pack + scratch buffers
    let before = buffer_growth_events();
    let second = run(&dy);
    assert_eq!(
        buffer_growth_events(),
        before,
        "steady-state conv pass must not grow the recycled buffers"
    );
    assert_bits(&second.0.data, &first.0.data, "steady-state fwd determinism");
    assert_bits(&second.1.data, &first.1.data, "steady-state dw determinism");
    assert_bits(&second.2.data, &first.2.data, "steady-state dx determinism");
}

/// Same bit-identity at a second odd geometry — stride 1 with an even
/// kernel (2x2) on a non-square input — so the tiled path is checked on
/// both strided and unit-stride im2col layouts.
#[test]
fn conv_forward_bitwise_matches_reference_even_kernel() {
    let model = registry::by_name("mit16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mul = MulKernel::Lut(AmSim::new(&lut));
    let g = Conv2dGeom {
        batch: 3,
        in_h: 5,
        in_w: 11,
        in_c: 2,
        k_h: 2,
        k_w: 2,
        out_c: 4,
        stride: 1,
        pad: 0,
    };
    let mut rng = Pcg32::seeded(74);
    let x = rand_tensor(&[g.batch, g.in_h, g.in_w, g.in_c], &mut rng);
    let w = rand_tensor(&[g.k_h, g.k_w, g.in_c, g.out_c], &mut rng);
    let y = amconv2d::forward(&mul, &x, &w, g.stride, g.pad);
    let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
    im2col_forward(&g, &x.data, &mut cols);
    let mut y_ref = vec![0.0f32; g.col_rows() * g.out_c];
    gemm_scalar_reference(&mul, &cols, &w.data, &mut y_ref, g.col_rows(), g.col_cols(), g.out_c);
    for i in 0..y_ref.len() {
        assert_eq!(y.data[i].to_bits(), y_ref[i].to_bits(), "idx {i}");
    }
}
