//! Negative fixture: every accepted shape, linted under the strictest
//! virtual path (deterministic + accumulation scope). Zero findings.

use std::cmp::Ordering;
use std::sync::{Condvar, Mutex};

pub fn compare(a: u32, b: u32) -> bool {
    // `cmp::Ordering` variants must not register as atomic sites
    a.cmp(&b) == Ordering::Less
}

pub fn index_product(acc: &mut [f32], a: &[f32], i: usize, k: usize, kk: usize) {
    // stars confined to index brackets are not product accumulation
    acc[0] += a[i * k + kk];
}

pub fn read_first(p: *const f32) -> f32 {
    // SAFETY: caller guarantees `p` points to at least one element.
    unsafe { *p }
}

pub struct W {
    cv: Condvar,
    state: Mutex<bool>,
}

impl W {
    pub fn wait_ready(&self) {
        let mut st = self.state.lock().unwrap();
        while !*st {
            st = self.cv.wait(st).unwrap();
        }
    }
}

pub fn gated(x: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        x[0] += 1.0;
    }
    x[0] += 0.0;
}

#[cfg(target_arch = "x86_64")]
pub fn arch_fn(x: f32) -> f32 {
    x + 1.0
}

#[cfg(not(target_arch = "x86_64"))]
pub fn arch_fn(x: f32) -> f32 {
    x + 1.0
}
