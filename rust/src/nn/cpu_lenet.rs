//! Pure-Rust LeNet executors — the ATxC ("CPU direct simulation") system of
//! Tables V/VI. Forward and full backward with every multiply routed
//! through a [`MulKernel`]; used by the CPU-path benchmarks and as an
//! end-to-end oracle against the compiled artifacts.

use crate::kernels::MulKernel;
use crate::layers::activations::{relu, relu_backward};
use crate::layers::softmax::cross_entropy_with_grad;
use crate::layers::{amconv2d, amdense};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// LeNet-300-100 parameters.
#[derive(Clone)]
pub struct Lenet300 {
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
    pub w3: Tensor,
    pub b3: Tensor,
}

impl Lenet300 {
    pub fn init(n_in: usize, classes: usize, seed: u64) -> Lenet300 {
        let he = |shape: &[usize], fan_in: usize, stream: u64| {
            let mut rng = Pcg32::new(seed, stream);
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| std * rng.normal()).collect())
        };
        Lenet300 {
            w1: he(&[n_in, 300], n_in, 1),
            b1: Tensor::zeros(&[300]),
            w2: he(&[300, 100], 300, 2),
            b2: Tensor::zeros(&[100]),
            w3: he(&[100, classes], 100, 3),
            b3: Tensor::zeros(&[classes]),
        }
    }

    /// Forward pass; `x` is `[batch, n_in]`.
    pub fn forward(&self, mul: &MulKernel, x: &Tensor) -> Tensor {
        let h1 = relu(&amdense::forward(mul, x, &self.w1, Some(&self.b1)));
        let h2 = relu(&amdense::forward(mul, &h1, &self.w2, Some(&self.b2)));
        amdense::forward(mul, &h2, &self.w3, Some(&self.b3))
    }

    /// One SGD training step; returns (loss, accuracy).
    pub fn train_step(
        &mut self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        lr: f32,
    ) -> (f32, f32) {
        // forward, keeping pre-activations for relu backward
        let z1 = amdense::forward(mul, x, &self.w1, Some(&self.b1));
        let h1 = relu(&z1);
        let z2 = amdense::forward(mul, &h1, &self.w2, Some(&self.b2));
        let h2 = relu(&z2);
        let logits = amdense::forward(mul, &h2, &self.w3, Some(&self.b3));
        let (loss, acc, dlogits) = cross_entropy_with_grad(&logits, labels);
        // backward
        let dw3 = amdense::weight_grad(mul, &h2, &dlogits);
        let db3 = amdense::bias_grad(&dlogits);
        let dh2 = relu_backward(&amdense::input_grad(mul, &dlogits, &self.w3), &z2);
        let dw2 = amdense::weight_grad(mul, &h1, &dh2);
        let db2 = amdense::bias_grad(&dh2);
        let dh1 = relu_backward(&amdense::input_grad(mul, &dh2, &self.w2), &z1);
        let dw1 = amdense::weight_grad(mul, x, &dh1);
        let db1 = amdense::bias_grad(&dh1);
        // plain SGD (the CPU path benchmarks per-batch cost, not curves)
        sgd(&mut self.w3, &dw3, lr);
        sgd(&mut self.b3, &db3, lr);
        sgd(&mut self.w2, &dw2, lr);
        sgd(&mut self.b2, &db2, lr);
        sgd(&mut self.w1, &dw1, lr);
        sgd(&mut self.b1, &db1, lr);
        (loss, acc)
    }
}

/// LeNet-5 parameters (28x28x1 input).
#[derive(Clone)]
pub struct Lenet5 {
    pub c1: Tensor, // [5,5,1,6]
    pub c2: Tensor, // [5,5,6,16]
    pub w1: Tensor, // [400,120]
    pub b1: Tensor,
    pub w2: Tensor, // [120,84]
    pub b2: Tensor,
    pub w3: Tensor, // [84,10]
    pub b3: Tensor,
}

impl Lenet5 {
    pub fn init(seed: u64) -> Lenet5 {
        let he = |shape: &[usize], fan_in: usize, stream: u64| {
            let mut rng = Pcg32::new(seed, stream);
            let std = (2.0 / fan_in as f32).sqrt();
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| std * rng.normal()).collect())
        };
        Lenet5 {
            c1: he(&[5, 5, 1, 6], 25, 1),
            c2: he(&[5, 5, 6, 16], 150, 2),
            w1: he(&[400, 120], 400, 3),
            b1: Tensor::zeros(&[120]),
            w2: he(&[120, 84], 120, 4),
            b2: Tensor::zeros(&[84]),
            w3: he(&[84, 10], 84, 5),
            b3: Tensor::zeros(&[10]),
        }
    }

    /// Forward; `x` is `[batch, 28, 28, 1]`.
    pub fn forward(&self, mul: &MulKernel, x: &Tensor) -> Tensor {
        use crate::kernels::pool::maxpool2x2;
        let a1 = relu(&amconv2d::forward(mul, x, &self.c1, 1, 2));
        let (p1, _) = maxpool2x2(&a1.data, x.shape[0], 28, 28, 6);
        let p1 = Tensor::from_vec(&[x.shape[0], 14, 14, 6], p1);
        let a2 = relu(&amconv2d::forward(mul, &p1, &self.c2, 1, 0));
        let (p2, _) = maxpool2x2(&a2.data, x.shape[0], 10, 10, 16);
        let p2 = Tensor::from_vec(&[x.shape[0], 400], p2);
        let h1 = relu(&amdense::forward(mul, &p2, &self.w1, Some(&self.b1)));
        let h2 = relu(&amdense::forward(mul, &h1, &self.w2, Some(&self.b2)));
        amdense::forward(mul, &h2, &self.w3, Some(&self.b3))
    }

    /// One SGD step (full backward through convs and pools).
    pub fn train_step(
        &mut self,
        mul: &MulKernel,
        x: &Tensor,
        labels: &[u32],
        lr: f32,
    ) -> (f32, f32) {
        use crate::kernels::pool::{maxpool2x2, maxpool2x2_backward};
        let batch = x.shape[0];
        // forward (cache everything)
        let z1 = amconv2d::forward(mul, x, &self.c1, 1, 2);
        let a1 = relu(&z1);
        let (p1d, arg1) = maxpool2x2(&a1.data, batch, 28, 28, 6);
        let p1 = Tensor::from_vec(&[batch, 14, 14, 6], p1d);
        let z2 = amconv2d::forward(mul, &p1, &self.c2, 1, 0);
        let a2 = relu(&z2);
        let (p2d, arg2) = maxpool2x2(&a2.data, batch, 10, 10, 16);
        let flat = Tensor::from_vec(&[batch, 400], p2d);
        let zf1 = amdense::forward(mul, &flat, &self.w1, Some(&self.b1));
        let h1 = relu(&zf1);
        let zf2 = amdense::forward(mul, &h1, &self.w2, Some(&self.b2));
        let h2 = relu(&zf2);
        let logits = amdense::forward(mul, &h2, &self.w3, Some(&self.b3));
        let (loss, acc, dlogits) = cross_entropy_with_grad(&logits, labels);
        // dense backward
        let dw3 = amdense::weight_grad(mul, &h2, &dlogits);
        let db3 = amdense::bias_grad(&dlogits);
        let dh2 = relu_backward(&amdense::input_grad(mul, &dlogits, &self.w3), &zf2);
        let dw2 = amdense::weight_grad(mul, &h1, &dh2);
        let db2 = amdense::bias_grad(&dh2);
        let dh1 = relu_backward(&amdense::input_grad(mul, &dh2, &self.w2), &zf1);
        let dw1 = amdense::weight_grad(mul, &flat, &dh1);
        let db1 = amdense::bias_grad(&dh1);
        let dflat = amdense::input_grad(mul, &dh1, &self.w1);
        // conv2 backward through pool2
        let da2 = maxpool2x2_backward(&dflat.data, &arg2, batch * 10 * 10 * 16);
        let dz2 = relu_backward(&Tensor::from_vec(&[batch, 10, 10, 16], da2), &z2);
        let dc2 = amconv2d::weight_grad(mul, &p1, &dz2, &self.c2.shape, 1, 0);
        let dp1 = amconv2d::input_grad(mul, &dz2, &self.c2, &p1.shape, 1, 0);
        // conv1 backward through pool1
        let da1 = maxpool2x2_backward(&dp1.data, &arg1, batch * 28 * 28 * 6);
        let dz1 = relu_backward(&Tensor::from_vec(&[batch, 28, 28, 6], da1), &z1);
        let dc1 = amconv2d::weight_grad(mul, x, &dz1, &self.c1.shape, 1, 2);
        // updates
        sgd(&mut self.c1, &dc1, lr);
        sgd(&mut self.c2, &dc2, lr);
        sgd(&mut self.w1, &dw1, lr);
        sgd(&mut self.b1, &db1, lr);
        sgd(&mut self.w2, &dw2, lr);
        sgd(&mut self.b2, &db2, lr);
        sgd(&mut self.w3, &dw3, lr);
        sgd(&mut self.b3, &db3, lr);
        (loss, acc)
    }
}

fn sgd(p: &mut Tensor, g: &Tensor, lr: f32) {
    for (pv, gv) in p.data.iter_mut().zip(&g.data) {
        *pv -= lr * gv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{mnist_like, SynthSpec};

    #[test]
    fn lenet300_learns_one_batch() {
        let ds = mnist_like(&SynthSpec { n: 32, ..SynthSpec::mnist_like_default() });
        let x = Tensor::from_vec(&[32, 784], ds.images.clone());
        let mut net = Lenet300::init(784, 10, 7);
        let mul = MulKernel::Native;
        let (l0, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
        let mut last = l0;
        for _ in 0..8 {
            let (l, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
            last = l;
        }
        assert!(last < l0 * 0.7, "loss {l0} -> {last}");
    }

    #[test]
    fn lenet5_learns_one_batch_with_approx_mult() {
        use crate::amsim::AmSim;
        use crate::lut::MantissaLut;
        use crate::mult::registry;
        let ds = mnist_like(&SynthSpec { n: 8, ..SynthSpec::mnist_like_default() });
        let x = Tensor::from_vec(&[8, 28, 28, 1], ds.images.clone());
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mul = MulKernel::Lut(AmSim::new(&lut));
        let mut net = Lenet5::init(7);
        let (l0, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
        let mut last = l0;
        for _ in 0..6 {
            let (l, _) = net.train_step(&mul, &x, &ds.labels, 0.05);
            last = l;
        }
        assert!(last < l0, "approx loss did not decrease: {l0} -> {last}");
    }
}
