//! Offline stub of the `xla` PJRT bindings used by `approxtrain::runtime`.
//!
//! The real dependency links `xla_extension` (a multi-GB native XLA build)
//! which is not present in this environment, and the build has no registry
//! access to fetch it. This stub keeps the whole crate compiling and the
//! pure-Rust (ATxC) paths fully functional:
//!
//! * [`Literal`] is a real host-side tensor container — construction,
//!   reshape, tuple handling and element extraction all work;
//! * device-side types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`]) carry the same signatures but fail at **client
//!   creation** with a clear message, which the coordinator surfaces as
//!   "artifact path unavailable" — exactly the behaviour it already has
//!   when `artifacts/` is not built.
//!
//! Swapping in the real bindings is a one-line `Cargo.toml` change; no
//! source edits are required.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion
/// into `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable in this offline build (stub xla crate); \
         the pure-Rust CPU kernels remain fully functional — see README \
         'Runtime backends'"
            .to_string(),
    )
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<&[Self]>;
}

/// Type-erased literal storage.
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

macro_rules! native {
    ($ty:ty, $variant:ident) => {
        impl NativeType for $ty {
            fn wrap(data: Vec<Self>) -> Data {
                Data::$variant(data)
            }
            fn unwrap(data: &Data) -> Option<&[Self]> {
                match data {
                    Data::$variant(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

/// Host-side tensor value (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.element_count() as i64 {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Split a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }

    /// Extract elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path).map_err(|e| Error(format!("{path}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// Computation wrapper (opaque in the stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client. In the stub, creation always fails — there is no XLA
/// runtime to back it — so every downstream path degrades exactly like a
/// missing `artifacts/` directory.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Compiled executable handle (never constructed in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle (never constructed in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_unavailable_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to run");
        assert!(err.to_string().contains("unavailable"), "{err}");
    }
}
