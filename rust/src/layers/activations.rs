//! Activation functions (exact — no multiplications are approximated in
//! them; the paper only approximates Conv2D/Dense multiplies).

use crate::tensor::Tensor;

pub fn relu(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    y.map_inplace(|v| v.max(0.0));
    y
}

/// ReLU backward: pass gradient where the *input* was positive.
pub fn relu_backward(dy: &Tensor, x: &Tensor) -> Tensor {
    assert_eq!(dy.shape, x.shape);
    let mut dx = dy.clone();
    for (d, &xv) in dx.data.iter_mut().zip(&x.data) {
        if xv <= 0.0 {
            *d = 0.0;
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_and_backward() {
        let x = Tensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -3.0]);
        let y = relu(&x);
        assert_eq!(y.data, vec![0.0, 0.0, 2.0, 0.0]);
        let dy = Tensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0]);
        let dx = relu_backward(&dy, &x);
        assert_eq!(dx.data, vec![0.0, 0.0, 1.0, 0.0]);
    }
}
