//! Pruning + approximate multipliers (paper §VIII-C / Fig 11): pre-train
//! the MNIST CNN, magnitude-prune to increasing sparsity with brief
//! retraining, under both the exact FP32 and the approximate AFM16
//! multiplier — demonstrating hardware/algorithm co-design through the
//! framework.
//!
//! ```sh
//! make artifacts && cargo run --release --example prune_train
//! ```

use std::path::Path;

use approxtrain::coordinator::pruning::{prune_params, reapply_masks};
use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
use approxtrain::data::synth::{mnist_like, SynthSpec};
use approxtrain::data::Batcher;
use approxtrain::runtime::executor::Engine;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let mut engine = Engine::new(dir)?;
    let ds = mnist_like(&SynthSpec { n: 512, ..SynthSpec::mnist_like_default() });
    let (train, test) = ds.split(128);
    let sparsities = [0.70, 0.80, 0.83, 0.90];

    for (disp, mode, mult) in [("FP32", "custom", "fp32"), ("AFM16", "lut", "afm16")] {
        let cfg = TrainConfig {
            model: "lenet5".into(),
            mode: mode.into(),
            mult: mult.into(),
            epochs: 4,
            lr: 0.05,
            seed: 42,
            eval_every: usize::MAX,
        };
        let mut tr = Trainer::new(&mut engine, cfg.clone(), dir)?;
        tr.fit(&train, &test)?;
        let baseline = tr.evaluate(&test)? * 100.0;
        let pretrained = tr.checkpoint()?;
        println!("\n=== {disp}: dense baseline {baseline:.2}% ===");
        for &s in &sparsities {
            let mut tr = Trainer::new(&mut engine, cfg.clone(), dir)?;
            tr.load_checkpoint(&pretrained)?;
            let masks = prune_params(tr.params_mut(), s, 128);
            // retrain 2 epochs with masks enforced after every step
            for epoch in 0..2u64 {
                for (images, labels) in Batcher::new(&train, tr.batch_size(), 42, 100 + epoch) {
                    tr.step(&images, &labels)?;
                    reapply_masks(tr.params_mut(), &masks);
                }
            }
            let acc = tr.evaluate(&test)? * 100.0;
            let delta = acc - baseline;
            println!("  sparsity {:>3.0}% -> test acc {acc:.2}% ({delta:+.2} pp vs dense)",
                     s * 100.0);
        }
    }
    Ok(())
}
