// Planted R7 fixture emitter: one schema matched by the doc, one not.
pub const SCHEMA_OK: &str = "approxtrain/bench_gemm/v5";
pub const SCHEMA_UNDOCUMENTED: &str = "approxtrain/bench_gemm/v9";
