//! Property tests for the batched multiply backend and the GEMM paths
//! built on it. The crate-wide accumulation contract — one running FP32
//! accumulator per output element, products added in ascending
//! contraction order — makes **every** path (panel, tiled at any
//! geometry, pool-threaded) *bit-identical* to the per-element scalar
//! `MulKernel::mul` reference for **all three strategies**, native
//! included: the op sequence is the same and rustc neither reassociates
//! nor FMA-contracts f32 arithmetic. Batching amortizes *dispatch* and
//! blocking improves *locality*; neither must ever change *arithmetic*.

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{
    gemm, gemm_panel, gemm_panel_threaded, gemm_scalar_reference, gemm_tiled_src,
    gemm_tiled_with, SliceA, SliceB, TileConfig,
};
use approxtrain::kernels::matvec::{
    dense_forward, dense_input_grad, dense_weight_grad, DENSE_GEMM_MIN_MACS,
};
use approxtrain::kernels::{MulBackend, MulKernel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::util::rng::Pcg32;
use approxtrain::util::simd;

fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range(-2.0, 2.0)).collect()
}

/// Run `f` under all three strategies.
fn for_each_strategy(f: impl Fn(&MulKernel, &str)) {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    f(&MulKernel::Native, "native");
    f(&MulKernel::Direct(model.as_ref()), "direct");
    f(&MulKernel::Lut(AmSim::new(&lut)), "lut");
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what} idx {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn gemm_paths_equal_scalar_dispatch_at_every_tile_size() {
    // shapes straddling the default block boundaries so accumulators are
    // continued across blocks, plus tile geometries from degenerate to
    // larger-than-matrix
    let shapes = [(1, 1, 1), (5, 17, 9), (33, 64, 20), (21, 65, 19), (16, 130, 24)];
    let configs = [
        TileConfig { mc: 1, kc: 1, nc: 1, mr: 1, nr: 1 },
        TileConfig { mc: 2, kc: 7, nc: 3, mr: 2, nr: 2 },
        TileConfig { mc: 16, kc: 32, nc: 16, mr: 4, nr: 8 },
        TileConfig::DEFAULT,
        TileConfig { mc: 512, kc: 512, nc: 512, mr: 16, nr: 16 },
    ];
    for (m, k, n) in shapes {
        for_each_strategy(|mul, name| {
            let mut rng = Pcg32::seeded(900 + (m * k * n) as u64);
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c_ref = vec![0.0f32; m * n];
            gemm_scalar_reference(mul, &a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            gemm(mul, &a, &b, &mut c, m, k, n);
            assert_bits(&c, &c_ref, &format!("gemm[{name}] ({m},{k},{n})"));
            gemm_panel(mul, &a, &b, &mut c, m, k, n);
            assert_bits(&c, &c_ref, &format!("gemm_panel[{name}] ({m},{k},{n})"));
            for cfg in configs {
                gemm_tiled_with(mul, cfg, &a, &b, &mut c, m, k, n, 1);
                assert_bits(
                    &c,
                    &c_ref,
                    &format!("gemm_tiled[{name}] {cfg:?} ({m},{k},{n})"),
                );
            }
        });
    }
}

/// The strategy sweep widened across *forced* SIMD levels: for every
/// machine-executable level (Scalar, Avx2, Avx2Fma when detected) ×
/// threads {1, 8}, the tiled and flat-panel threaded paths over
/// block-straddling shapes must equal the scalar dispatch oracle bit
/// for bit — the vector arms inherit the accumulation contract
/// unchanged, for both the native baseline and the LUT simulation.
#[test]
fn gemm_paths_equal_scalar_dispatch_at_every_forced_simd_level() {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let shapes = [(5usize, 17usize, 9usize), (21, 65, 19), (16, 130, 24)];
    for level in simd::available_levels() {
        let kernels = [
            (MulKernel::NativeAt(level), format!("native@{level}")),
            (MulKernel::Lut(AmSim::with_simd(&lut, level)), format!("lut@{level}")),
        ];
        for (mul, name) in &kernels {
            for (m, k, n) in shapes {
                let mut rng = Pcg32::seeded(910 + (m * k * n) as u64);
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
                for threads in [1usize, 8] {
                    let mut got = vec![0.0f32; m * n];
                    gemm_tiled_with(mul, TileConfig::DEFAULT, &a, &b, &mut got, m, k, n, threads);
                    assert_bits(
                        &got,
                        &want,
                        &format!("gemm_tiled[{name}] ({m},{k},{n}) t={threads}"),
                    );
                    gemm_panel_threaded(mul, &a, &b, &mut got, m, k, n, threads);
                    assert_bits(
                        &got,
                        &want,
                        &format!("gemm_panel_threaded[{name}] ({m},{k},{n}) t={threads}"),
                    );
                }
            }
        }
    }
}

/// The generalized panel-source entry point with slice sources IS the
/// slice path: `gemm_tiled_src(SliceA, SliceB)` must equal the scalar
/// oracle (and hence `gemm_tiled_with`) bit for bit at any tile geometry
/// and thread count. The implicit im2col sources are swept against the
/// same oracle in `tests/conv_grads.rs`.
#[test]
fn gemm_tiled_src_with_slice_sources_equals_slice_path() {
    let (m, k, n) = (21, 65, 19);
    for_each_strategy(|mul, name| {
        let mut rng = Pcg32::seeded(906);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut want = vec![0.0f32; m * n];
        gemm_scalar_reference(mul, &a, &b, &mut want, m, k, n);
        for cfg in [TileConfig { mc: 7, kc: 16, nc: 5, mr: 3, nr: 4 }, TileConfig::DEFAULT] {
            for threads in [1, 3, 8] {
                let mut got = vec![0.0f32; m * n];
                gemm_tiled_src(
                    mul,
                    cfg,
                    &SliceA { data: &a, k },
                    &SliceB { data: &b, n },
                    &mut got,
                    m,
                    k,
                    n,
                    threads,
                );
                assert_bits(&got, &want, &format!("gemm_tiled_src[{name}] {cfg:?} t={threads}"));
            }
        }
    });
}

#[test]
fn gemm_pool_threaded_equals_single_threaded() {
    let (m, k, n) = (43, 70, 31);
    // small tiles so the pool has a deep queue to steal from
    let cfg = TileConfig { mc: 8, kc: 16, nc: 8, mr: 4, nr: 3 };
    for_each_strategy(|mul, name| {
        let mut rng = Pcg32::seeded(901);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c1 = vec![0.0f32; m * n];
        gemm_tiled_with(mul, cfg, &a, &b, &mut c1, m, k, n, 1);
        for threads in [2, 4, 7, 43] {
            let mut ct = vec![0.0f32; m * n];
            gemm_tiled_with(mul, cfg, &a, &b, &mut ct, m, k, n, threads);
            // thread count must never change a single bit, for ANY strategy
            assert_bits(&ct, &c1, &format!("gemm_tiled[{name}] t={threads}"));
            gemm_panel_threaded(mul, &a, &b, &mut ct, m, k, n, threads);
            assert_bits(&ct, &c1, &format!("gemm_panel_threaded[{name}] t={threads}"));
        }
    });
}

#[test]
fn mul_panel_equals_elementwise_mul() {
    for n in [0usize, 1, 3, 4, 7, 64, 201] {
        for_each_strategy(|mul, name| {
            let mut rng = Pcg32::seeded(902 + n as u64);
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let mut out = vec![0.0f32; n];
            mul.mul_panel(&a, &b, &mut out);
            let want: Vec<f32> = (0..n).map(|i| mul.mul(a[i], b[i])).collect();
            assert_bits(&out, &want, &format!("mul_panel[{name}] n={n}"));
        });
    }
}

#[test]
fn dot_panel_equals_sequential_scalar() {
    for n in [0usize, 1, 2, 3, 4, 5, 8, 63, 64, 65, 200] {
        for_each_strategy(|mul, name| {
            let mut rng = Pcg32::seeded(903 + n as u64);
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let got = mul.dot_panel(&a, &b);
            let mut want = 0.0f32;
            for i in 0..n {
                want += mul.mul(a[i], b[i]);
            }
            assert_bits(&[got], &[want], &format!("dot_panel[{name}] n={n}"));
            // the seeded variant must continue an accumulation exactly
            let split = n / 3;
            let head = mul.dot_panel_acc(0.0, &a[..split], &b[..split]);
            let cont = mul.dot_panel_acc(head, &a[split..], &b[split..]);
            assert_bits(&[cont], &[want], &format!("dot_panel_acc[{name}] n={n}"));
        });
    }
}

/// Scalar references for the three dense kernels, in the kernels' operand
/// order (`mul(activation, weight)` / `mul(x, dy)` / `mul(dy, w)`) and
/// ascending-contraction accumulation. Both dense regimes — per-row
/// matvec and the tiled-GEMM fallback — must reproduce these bit for bit.
fn dense_refs(
    mul: &MulKernel,
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    batch: usize,
    n_in: usize,
    n_out: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; batch * n_out];
    for b in 0..batch {
        for o in 0..n_out {
            let mut acc = 0.0f32;
            for i in 0..n_in {
                acc += mul.mul(x[b * n_in + i], w[i * n_out + o]);
            }
            y[b * n_out + o] = acc;
        }
    }
    let mut dw = vec![0.0f32; n_in * n_out];
    for b in 0..batch {
        for i in 0..n_in {
            for o in 0..n_out {
                dw[i * n_out + o] += mul.mul(x[b * n_in + i], dy[b * n_out + o]);
            }
        }
    }
    let mut dx = vec![0.0f32; batch * n_in];
    for b in 0..batch {
        for i in 0..n_in {
            let mut acc = 0.0f32;
            for o in 0..n_out {
                acc += mul.mul(dy[b * n_out + o], w[i * n_out + o]);
            }
            dx[b * n_in + i] = acc;
        }
    }
    (y, dw, dx)
}

#[test]
fn dense_kernels_equal_scalar_reference_in_both_regimes() {
    // below DENSE_GEMM_MIN_MACS: matvec/fma_row regime; above: the tiled
    // GEMM fallback. Both shapes run the identical reference.
    let shapes = [(5usize, 37usize, 23usize), (40, 41, 41)];
    assert!(shapes[0].0 * shapes[0].1 * shapes[0].2 < DENSE_GEMM_MIN_MACS);
    assert!(shapes[1].0 * shapes[1].1 * shapes[1].2 >= DENSE_GEMM_MIN_MACS);
    for (batch, n_in, n_out) in shapes {
        for_each_strategy(|mul, name| {
            let mut rng = Pcg32::seeded(904 + (batch * n_in) as u64);
            let x = rand_vec(&mut rng, batch * n_in);
            let w = rand_vec(&mut rng, n_in * n_out);
            let dy = rand_vec(&mut rng, batch * n_out);
            let (y_ref, dw_ref, dx_ref) = dense_refs(mul, &x, &w, &dy, batch, n_in, n_out);

            let mut y = vec![0.0f32; batch * n_out];
            dense_forward(mul, &x, &w, &mut y, batch, n_in, n_out);
            assert_bits(&y, &y_ref, &format!("dense_forward[{name}] b={batch}"));

            let mut dw = vec![0.0f32; n_in * n_out];
            dense_weight_grad(mul, &x, &dy, &mut dw, batch, n_in, n_out);
            assert_bits(&dw, &dw_ref, &format!("dense_weight_grad[{name}] b={batch}"));

            let mut dx = vec![0.0f32; batch * n_in];
            dense_input_grad(mul, &dy, &w, &mut dx, batch, n_in, n_out);
            assert_bits(&dx, &dx_ref, &format!("dense_input_grad[{name}] b={batch}"));
        });
    }
}

/// End-to-end: a whole conv layer (forward + both gradients) through the
/// tiled kernels under LUT vs Direct stays bit-identical — the paper's
/// §VI footnote 2 validation, now running on the packed tiled code path.
#[test]
fn conv_layer_lut_equals_direct_through_batched_path() {
    use approxtrain::layers::amconv2d;
    use approxtrain::mult::fpbits::quantize_mantissa;
    use approxtrain::tensor::Tensor;
    let model = registry::by_name("mit16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mut rng = Pcg32::seeded(905);
    let mut q = |shape: &[usize]| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(
            shape,
            (0..n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect(),
        )
    };
    let x = q(&[2, 8, 8, 3]);
    let w = q(&[3, 3, 3, 4]);
    let direct = MulKernel::Direct(model.as_ref());
    let lut_k = MulKernel::Lut(AmSim::new(&lut));
    let y_d = amconv2d::forward(&direct, &x, &w, 2, 1);
    let y_l = amconv2d::forward(&lut_k, &x, &w, 2, 1);
    assert_bits(&y_l.data, &y_d.data, "conv forward");
    let dy = q(&y_d.shape);
    let dw_d = amconv2d::weight_grad(&direct, &x, &dy, &w.shape, 2, 1);
    let dw_l = amconv2d::weight_grad(&lut_k, &x, &dy, &w.shape, 2, 1);
    assert_bits(&dw_l.data, &dw_d.data, "conv weight grad");
    let dx_d = amconv2d::input_grad(&direct, &dy, &w, &x.shape, 2, 1);
    let dx_l = amconv2d::input_grad(&lut_k, &dy, &w, &x.shape, 2, 1);
    assert_bits(&dx_l.data, &dx_d.data, "conv input grad");
}
