//! Property tests for the batched multiply backend: the panel kernels
//! (GEMM / matvec / rank-1 update) must be *bit-identical* to the scalar
//! `MulKernel::mul` per-element reference with sequential FP32
//! accumulation — Direct and LUT exactly, Native modulo FP reassociation
//! (in practice also exact, but the contract only promises a tolerance) —
//! and the pool-threaded GEMM must equal the single-threaded one exactly
//! for every strategy. Batching amortizes *dispatch*; it must never change
//! *arithmetic*.

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm, gemm_scalar_reference, gemm_threaded};
use approxtrain::kernels::matvec::{dense_forward, dense_input_grad, dense_weight_grad};
use approxtrain::kernels::{MulBackend, MulKernel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::util::rng::Pcg32;

fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range(-2.0, 2.0)).collect()
}

/// Run `f` under all three strategies; `exact` says whether the comparison
/// must be bitwise (Direct/LUT) or tolerance-based (Native).
fn for_each_strategy(f: impl Fn(&MulKernel, bool, &str)) {
    let model = registry::by_name("afm16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    f(&MulKernel::Native, false, "native");
    f(&MulKernel::Direct(model.as_ref()), true, "direct");
    f(&MulKernel::Lut(AmSim::new(&lut)), true, "lut");
}

fn assert_same(got: &[f32], want: &[f32], exact: bool, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        if exact {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "{what} idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        } else {
            assert!(
                (got[i] - want[i]).abs() <= 1e-5 * want[i].abs().max(1.0),
                "{what} idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn gemm_batched_equals_scalar_dispatch() {
    // sizes straddling the BK=64 block boundary so the two-level
    // accumulation is exercised across blocks
    for (m, k, n) in [(1, 1, 1), (5, 17, 9), (33, 64, 20), (21, 65, 19), (16, 130, 24)] {
        for_each_strategy(|mul, exact, name| {
            let mut rng = Pcg32::seeded(900 + (m * k * n) as u64);
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            let mut c_ref = vec![0.0f32; m * n];
            gemm(mul, &a, &b, &mut c, m, k, n);
            gemm_scalar_reference(mul, &a, &b, &mut c_ref, m, k, n);
            assert_same(&c, &c_ref, exact, &format!("gemm[{name}] ({m},{k},{n})"));
        });
    }
}

#[test]
fn gemm_pool_threaded_equals_single_threaded() {
    let (m, k, n) = (43, 70, 31);
    for_each_strategy(|mul, _exact, name| {
        let mut rng = Pcg32::seeded(901);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c1 = vec![0.0f32; m * n];
        gemm_threaded(mul, &a, &b, &mut c1, m, k, n, 1);
        for threads in [2, 4, 7, 43] {
            let mut ct = vec![0.0f32; m * n];
            gemm_threaded(mul, &a, &b, &mut ct, m, k, n, threads);
            // thread count must never change a single bit, for ANY strategy
            assert_same(&ct, &c1, true, &format!("gemm_threaded[{name}] t={threads}"));
        }
    });
}

#[test]
fn mul_panel_equals_elementwise_mul() {
    for n in [0usize, 1, 3, 4, 7, 64, 201] {
        for_each_strategy(|mul, _exact, name| {
            let mut rng = Pcg32::seeded(902 + n as u64);
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let mut out = vec![0.0f32; n];
            mul.mul_panel(&a, &b, &mut out);
            let want: Vec<f32> = (0..n).map(|i| mul.mul(a[i], b[i])).collect();
            // products themselves are always bitwise-identical, native
            // included: there is no accumulation to reassociate
            assert_same(&out, &want, true, &format!("mul_panel[{name}] n={n}"));
        });
    }
}

#[test]
fn dot_panel_equals_sequential_scalar() {
    for n in [0usize, 1, 2, 3, 4, 5, 8, 63, 64, 65, 200] {
        for_each_strategy(|mul, exact, name| {
            let mut rng = Pcg32::seeded(903 + n as u64);
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let got = mul.dot_panel(&a, &b);
            let mut want = 0.0f32;
            for i in 0..n {
                want += mul.mul(a[i], b[i]);
            }
            assert_same(&[got], &[want], exact, &format!("dot_panel[{name}] n={n}"));
        });
    }
}

#[test]
fn dense_kernels_equal_scalar_reference() {
    let (batch, n_in, n_out) = (5, 37, 23);
    for_each_strategy(|mul, exact, name| {
        let mut rng = Pcg32::seeded(904);
        let x = rand_vec(&mut rng, batch * n_in);
        let w = rand_vec(&mut rng, n_in * n_out);
        let dy = rand_vec(&mut rng, batch * n_out);

        // forward: reference mirrors the kernel's transpose-then-dot shape
        let mut y = vec![0.0f32; batch * n_out];
        dense_forward(mul, &x, &w, &mut y, batch, n_in, n_out);
        let mut wt = vec![0.0f32; w.len()];
        for i in 0..n_in {
            for o in 0..n_out {
                wt[o * n_in + i] = w[i * n_out + o];
            }
        }
        let mut y_ref = vec![0.0f32; batch * n_out];
        for b in 0..batch {
            for o in 0..n_out {
                let mut acc = 0.0f32;
                for i in 0..n_in {
                    acc += mul.mul(wt[o * n_in + i], x[b * n_in + i]);
                }
                y_ref[b * n_out + o] = acc;
            }
        }
        assert_same(&y, &y_ref, exact, &format!("dense_forward[{name}]"));

        // weight gradient: batched fma_row vs scalar rank-1 updates
        let mut dw = vec![0.0f32; n_in * n_out];
        dense_weight_grad(mul, &x, &dy, &mut dw, batch, n_in, n_out);
        let mut dw_ref = vec![0.0f32; n_in * n_out];
        for b in 0..batch {
            for i in 0..n_in {
                for o in 0..n_out {
                    dw_ref[i * n_out + o] += mul.mul(x[b * n_in + i], dy[b * n_out + o]);
                }
            }
        }
        assert_same(&dw, &dw_ref, exact, &format!("dense_weight_grad[{name}]"));

        // input gradient
        let mut dx = vec![0.0f32; batch * n_in];
        dense_input_grad(mul, &dy, &w, &mut dx, batch, n_in, n_out);
        let mut dx_ref = vec![0.0f32; batch * n_in];
        for b in 0..batch {
            for i in 0..n_in {
                let mut acc = 0.0f32;
                for o in 0..n_out {
                    acc += mul.mul(w[i * n_out + o], dy[b * n_out + o]);
                }
                dx_ref[b * n_in + i] = acc;
            }
        }
        assert_same(&dx, &dx_ref, exact, &format!("dense_input_grad[{name}]"));
    });
}

/// End-to-end: a whole conv layer (forward + both gradients) through the
/// batched kernels under LUT vs Direct stays bit-identical — the paper's
/// §VI footnote 2 validation, now running on the panel code path.
#[test]
fn conv_layer_lut_equals_direct_through_batched_path() {
    use approxtrain::layers::amconv2d;
    use approxtrain::mult::fpbits::quantize_mantissa;
    use approxtrain::tensor::Tensor;
    let model = registry::by_name("mit16").unwrap();
    let lut = MantissaLut::generate(model.as_ref());
    let mut rng = Pcg32::seeded(905);
    let mut q = |shape: &[usize]| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(
            shape,
            (0..n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect(),
        )
    };
    let x = q(&[2, 8, 8, 3]);
    let w = q(&[3, 3, 3, 4]);
    let direct = MulKernel::Direct(model.as_ref());
    let lut_k = MulKernel::Lut(AmSim::new(&lut));
    let y_d = amconv2d::forward(&direct, &x, &w, 2, 1);
    let y_l = amconv2d::forward(&lut_k, &x, &w, 2, 1);
    assert_same(&y_l.data, &y_d.data, true, "conv forward");
    let dy = q(&y_d.shape);
    let dw_d = amconv2d::weight_grad(&direct, &x, &dy, &w.shape, 2, 1);
    let dw_l = amconv2d::weight_grad(&lut_k, &x, &dy, &w.shape, 2, 1);
    assert_same(&dw_l.data, &dw_d.data, true, "conv weight grad");
    let dx_d = amconv2d::input_grad(&direct, &dy, &w, &x.shape, 2, 1);
    let dx_l = amconv2d::input_grad(&lut_k, &dy, &w, &x.shape, 2, 1);
    assert_same(&dx_l.data, &dx_d.data, true, "conv input grad");
}
