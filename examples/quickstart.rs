//! Quickstart: the whole ApproxTrain pipeline in one file.
//!
//! 1. take an approximate multiplier functional model (AFM16),
//! 2. tabulate its mantissa products (paper Algorithm 1),
//! 3. simulate multiplications through AMSim (Algorithm 2),
//! 4. run an approximate GEMM on the CPU kernel path,
//! 5. and — if `artifacts/` is built — run the same GEMM through the
//!    compiled Pallas/XLA artifact and compare.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use approxtrain::amsim::AmSim;
use approxtrain::kernels::gemm::{gemm, gemm_auto};
use approxtrain::kernels::{MulBackend, MulKernel};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::fpbits::quantize_mantissa;
use approxtrain::mult::registry;
use approxtrain::runtime::executor::{Engine, Value};
use approxtrain::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // 1. the "C/C++ functional model" of the designer
    let afm16 = registry::by_name("afm16").expect("registered multiplier");
    println!("multiplier: {} (m = {} mantissa bits)", afm16.name(), afm16.mantissa_bits());

    // 2. Algorithm 1 — mantissa-product LUT
    let lut = MantissaLut::generate(afm16.as_ref());
    println!("LUT: {} entries, {} bytes (paper quotes 65.53 kB for m=7)",
             lut.len(), lut.payload_bytes());

    // 3. Algorithm 2 — AMSim
    let sim = AmSim::new(&lut);
    for (a, b) in [(1.5f32, 2.25f32), (-3.0, 0.4375), (7.0, 0.125)] {
        println!("  amsim({a} * {b}) = {} (exact {})", sim.mul(a, b), a * b);
    }

    // 4. approximate GEMM on the CPU kernel (ATxC path). gemm_auto runs
    //    the cache-blocked tiled kernel: packed operand panels feed the
    //    batched MulBackend ops — one strategy dispatch per panel, a tight
    //    contiguous LUT-gather inner loop — and large problems fan out as
    //    2D output tiles over the persistent worker pool.
    let n = 64;
    let mut rng = Pcg32::seeded(1);
    let a: Vec<f32> = (0..n * n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect();
    let mut c_exact = vec![0.0f32; n * n];
    let mut c_approx = vec![0.0f32; n * n];
    gemm_auto(&MulKernel::Native, &a, &b, &mut c_exact, n, n, n);
    gemm_auto(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_approx, n, n, n);
    let max_err = c_exact
        .iter()
        .zip(&c_approx)
        .map(|(e, ap)| (e - ap).abs())
        .fold(0.0f32, f32::max);
    println!("CPU GEMM {n}x{n}: max |exact - approx| = {max_err:.4}");
    // the batched panel ops are also directly usable (bit-identical to
    // scalar sim.mul per element):
    let d = MulKernel::Lut(AmSim::new(&lut)).dot_panel(&a[..n], &b[..n]);
    println!("dot_panel over one row: {d:.4}");

    // 5. same computation through the AOT-compiled artifact (ATxG path)
    match Engine::new(std::path::Path::new("artifacts")) {
        Ok(mut engine) if engine.manifest().find("gemm128", "gemm", "lut").is_some() => {
            let n = 128;
            let a: Vec<f32> =
                (0..n * n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect();
            let b: Vec<f32> =
                (0..n * n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect();
            let out = engine.run(
                "gemm128_lut",
                &[Value::F32(a.clone()), Value::F32(b.clone()), Value::U32(lut.entries.clone())],
            )?;
            let c_xla = out[0].as_f32()?;
            let mut c_cpu = vec![0.0f32; n * n];
            gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_cpu, n, n, n);
            let diff = c_xla
                .iter()
                .zip(&c_cpu)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            println!("XLA artifact vs CPU kernel (same LUT): max diff = {diff:.2e}");
        }
        _ => println!("(artifacts/ not built — run `make artifacts` for the XLA half)"),
    }
    Ok(())
}
