//! Hand-rolled CLI argument parsing (no `clap` in the offline dependency
//! set). Supports `--flag`, `--key value`, and positional arguments.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        if let Some(cmd) = iter.peek() {
            if !cmd.starts_with("--") {
                out.command = iter.next().unwrap();
            }
        }
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f32(&self, key: &str, default: f32) -> f32 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // note: a bare flag followed by a positional would be parsed as
        // `--key value`; flags therefore go last (documented limitation)
        let a = parse("train --model lenet5 --lr 0.05 pos1 --quick");
        assert_eq!(a.command, "train");
        assert_eq!(a.opt("model"), Some("lenet5"));
        assert_eq!(a.opt_f32("lr", 0.0), 0.05);
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = parse("bench --size=256");
        assert_eq!(a.opt_usize("size", 0), 256);
        assert_eq!(a.opt_usize("missing", 7), 7);
        assert_eq!(a.opt_or("mode", "lut"), "lut");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --verbose");
        assert!(a.has_flag("verbose"));
        assert!(a.opt("verbose").is_none());
    }
}
