//! Synthetic dataset generators (see module docs of [`super`]).
//!
//! Generation recipe per sample of class `c`:
//! 1. start from the class template `T_c` — a fixed smoothed random pattern
//!    drawn once per class from the dataset seed;
//! 2. apply a random circular shift of up to `max_shift` pixels;
//! 3. add i.i.d. Gaussian pixel noise of `noise_sigma`;
//! 4. clamp to [0, 1].
//!
//! The class templates are well separated (their pairwise distance is large
//! compared to the noise), so LeNet/ResNet-class models reach high accuracy
//! within a few epochs — which is what the paper's convergence comparisons
//! need; the interesting signal is the *difference between multipliers*,
//! not the absolute accuracy.

use super::Dataset;
use crate::util::rng::Pcg32;

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
    pub max_shift: usize,
    pub noise_sigma: f32,
    pub seed: u64,
}

impl SynthSpec {
    /// MNIST-like: 28x28 grayscale, 10 classes.
    pub fn mnist_like_default() -> SynthSpec {
        SynthSpec { n: 2048, h: 28, w: 28, c: 1, classes: 10, max_shift: 1, noise_sigma: 0.25, seed: 1234 }
    }
    /// CIFAR10-like: 32x32 RGB, 10 classes (scaled-down spatially for the
    /// tiny-ResNet experiments via the `h`/`w` fields).
    pub fn cifar_like_default() -> SynthSpec {
        SynthSpec { n: 2048, h: 16, w: 16, c: 3, classes: 10, max_shift: 1, noise_sigma: 0.3, seed: 4321 }
    }
    /// ImageNet-like: larger images, more classes (heavily scaled; see
    /// DESIGN.md §Substitutions #2/#3).
    pub fn imagenet_like_default() -> SynthSpec {
        SynthSpec { n: 2048, h: 32, w: 32, c: 3, classes: 20, max_shift: 2, noise_sigma: 0.3, seed: 9999 }
    }
}

/// Generate the class templates: smoothed uniform noise per class.
fn templates(spec: &SynthSpec, rng: &mut Pcg32) -> Vec<Vec<f32>> {
    let sz = spec.h * spec.w * spec.c;
    (0..spec.classes)
        .map(|_| {
            let raw: Vec<f32> = (0..sz).map(|_| rng.uniform()).collect();
            // 3x3 box blur per channel to give spatial structure CNNs can
            // exploit
            let mut smooth = vec![0.0f32; sz];
            for y in 0..spec.h {
                for x in 0..spec.w {
                    for ch in 0..spec.c {
                        let mut acc = 0.0;
                        let mut cnt = 0.0;
                        for dy in -1i32..=1 {
                            for dx in -1i32..=1 {
                                let yy = y as i32 + dy;
                                let xx = x as i32 + dx;
                                if yy >= 0 && xx >= 0 && yy < spec.h as i32 && xx < spec.w as i32
                                {
                                    acc += raw
                                        [(yy as usize * spec.w + xx as usize) * spec.c + ch];
                                    cnt += 1.0;
                                }
                            }
                        }
                        smooth[(y * spec.w + x) * spec.c + ch] = acc / cnt;
                    }
                }
            }
            // stretch contrast so classes are well separated
            smooth.iter().map(|&v| ((v - 0.5) * 3.0 + 0.5).clamp(0.0, 1.0)).collect()
        })
        .collect()
}

/// Generate a dataset from a spec.
pub fn generate(name: &str, spec: &SynthSpec) -> Dataset {
    let mut rng = Pcg32::new(spec.seed, 0xDA7A);
    let tmpl = templates(spec, &mut rng);
    let sz = spec.h * spec.w * spec.c;
    let mut images = Vec::with_capacity(spec.n * sz);
    let mut labels = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let class = (i % spec.classes) as u32; // balanced
        let t = &tmpl[class as usize];
        let shift = spec.max_shift as i32;
        let dy = rng.below((2 * shift + 1) as u32) as i32 - shift;
        let dx = rng.below((2 * shift + 1) as u32) as i32 - shift;
        for y in 0..spec.h as i32 {
            for x in 0..spec.w as i32 {
                for ch in 0..spec.c {
                    let sy = (y + dy).rem_euclid(spec.h as i32) as usize;
                    let sx = (x + dx).rem_euclid(spec.w as i32) as usize;
                    let v = t[(sy * spec.w + sx) * spec.c + ch]
                        + spec.noise_sigma * rng.normal();
                    images.push(v.clamp(0.0, 1.0));
                }
            }
        }
        labels.push(class);
    }
    Dataset {
        name: name.to_string(),
        images,
        labels,
        n: spec.n,
        h: spec.h,
        w: spec.w,
        c: spec.c,
        classes: spec.classes,
    }
}

pub fn mnist_like(spec: &SynthSpec) -> Dataset {
    generate("mnist-like", spec)
}
pub fn cifar_like(spec: &SynthSpec) -> Dataset {
    generate("cifar-like", spec)
}
pub fn imagenet_like(spec: &SynthSpec) -> Dataset {
    generate("imagenet-like", spec)
}

/// Named dataset lookup used by the CLI/config layer.
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    let mut spec = match name {
        "mnist" => SynthSpec::mnist_like_default(),
        "cifar10" => SynthSpec::cifar_like_default(),
        "imagenet" => SynthSpec::imagenet_like_default(),
        _ => return None,
    };
    spec.n = n;
    spec.seed = seed;
    Some(generate(name, &spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let spec = SynthSpec { n: 100, ..SynthSpec::mnist_like_default() };
        let a = mnist_like(&spec);
        let b = mnist_like(&spec);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let mut counts = vec![0; 10];
        for &l in &a.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn pixels_in_unit_range() {
        let ds = cifar_like(&SynthSpec { n: 50, ..SynthSpec::cifar_like_default() });
        assert!(ds.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert_eq!(ds.images.len(), 50 * 16 * 16 * 3);
    }

    /// Classes must be separable: nearest-template classification of clean
    /// generated samples should beat 90%.
    #[test]
    fn classes_are_separable() {
        let spec = SynthSpec { n: 200, noise_sigma: 0.15, ..SynthSpec::mnist_like_default() };
        let ds = mnist_like(&spec);
        // build per-class mean images from the first half, classify second
        let sz = ds.image_len();
        let mut means = vec![vec![0.0f64; sz]; ds.classes];
        let mut counts = vec![0usize; ds.classes];
        for i in 0..100 {
            let img = ds.image(i);
            let c = ds.labels[i] as usize;
            for (m, &v) in means[c].iter_mut().zip(img) {
                *m += v as f64;
            }
            counts[c] += 1;
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        let mut correct = 0;
        for i in 100..200 {
            let img = ds.image(i);
            let best = (0..ds.classes)
                .min_by(|&a, &b| {
                    let da: f64 =
                        means[a].iter().zip(img).map(|(m, &v)| (m - v as f64).powi(2)).sum();
                    let db: f64 =
                        means[b].iter().zip(img).map(|(m, &v)| (m - v as f64).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct >= 90, "nearest-mean accuracy {correct}/100");
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mnist", 10, 1).is_some());
        assert!(by_name("cifar10", 10, 1).is_some());
        assert!(by_name("imagenet", 10, 1).is_some());
        assert!(by_name("svhn", 10, 1).is_none());
    }
}
