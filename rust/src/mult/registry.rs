//! Name-based multiplier registry: the CLI, experiment configs and LUT file
//! headers all refer to multipliers by these names (paper Table II plus the
//! Fig 6 designs).

use super::models::{Afm, AndCompensated, ExactFp, Mitchell, Realm};
use super::ApproxMul;

/// All registered multiplier names, in presentation order.
pub fn names() -> &'static [&'static str] {
    &[
        "fp32", "bfloat16", "fp16", "afm32", "afm16", "mit16", "realm16", "trunc16", "comp16",
    ]
}

/// Instantiate a multiplier functional model by name.
pub fn by_name(name: &str) -> Option<Box<dyn ApproxMul>> {
    Some(match name {
        // exact baselines (Table II)
        "fp32" => Box::new(ExactFp::new("fp32", 23, true)),
        "bfloat16" => Box::new(ExactFp::new("bfloat16", 7, true)),
        // (1,8,10): FP16-precision mantissa with FP32 exponent range, the
        // paper's datatype convention (§VII keeps e=8 in all formats)
        "fp16" => Box::new(ExactFp::new("fp16", 10, true)),
        // approximate designs
        "afm32" => Box::new(Afm::new("afm32", 23, 6)),
        "afm16" => Box::new(Afm::new("afm16", 7, 4)),
        "mit16" => Box::new(Mitchell::new("mit16", 7)),
        "realm16" => Box::new(Realm::new("realm16", 7)),
        // DRUM-style truncation is an approximate design, not an IEEE
        // baseline: it gates on a zero operand (zero-dominant specials)
        "trunc16" => Box::new(ExactFp::new("trunc16", 7, false).with_zero_identity()),
        "comp16" => Box::new(AndCompensated::new("comp16", 7)),
        _ => return None,
    })
}

/// Whether a multiplier's mantissa product can be tabulated (paper §V-B:
/// AMSim supports m in 1..=12; wider mantissas use direct simulation).
pub fn lut_able(name: &str) -> bool {
    by_name(name).map(|m| m.mantissa_bits() <= 12).unwrap_or(false)
}

/// Whether a registered multiplier declares the zero identity
/// ([`ApproxMul::zero_identity`]): `mul(±0, x) == ±0` for every `x`,
/// NaN/inf included. This is the per-registry-entry gate for the sparse
/// GEMM's zero-skipping drain (`MulKernel::zero_skip_ok`); entries
/// answering `false` — the exact IEEE baselines, whose `0 × inf` must stay
/// NaN — always take the dense path. Unknown names are conservatively
/// `false`. The flag is audited against brute-force model behaviour in
/// `tests/golden_mults.rs`.
pub fn zero_identity(name: &str) -> bool {
    by_name(name).map(|m| m.zero_identity()).unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_name_instantiates() {
        for name in names() {
            let m = by_name(name).expect(name);
            assert_eq!(m.name(), *name);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn lut_ability_follows_mantissa_width() {
        assert!(lut_able("afm16"));
        assert!(lut_able("bfloat16"));
        assert!(!lut_able("afm32"));
        assert!(!lut_able("fp32"));
        assert!(!lut_able("nope"));
    }

    /// The zero-identity capability splits the registry exactly along the
    /// exact-baseline / approximate-design line (the brute-force audit of
    /// the flag itself lives in tests/golden_mults.rs).
    #[test]
    fn zero_identity_splits_baselines_from_approximate_designs() {
        for name in ["fp32", "bfloat16", "fp16"] {
            assert!(!zero_identity(name), "{name} must stay IEEE-exact");
        }
        for name in ["afm32", "afm16", "mit16", "realm16", "trunc16", "comp16"] {
            assert!(zero_identity(name), "{name} should be zero-dominant");
        }
        assert!(!zero_identity("nope"));
    }
}
