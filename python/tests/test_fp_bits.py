"""fp_bits: quantization semantics (mirrors rust fpbits tests)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.fp_bits import (MANT_BITS, compose, decompose, from_bits,
                             quantize_mantissa, to_bits)


def test_roundtrip_bits():
    xs = np.array([0.0, 1.0, -1.0, 1.5, -3.375, 1e-20, 1e20], dtype=np.float32)
    assert np.array_equal(from_bits(to_bits(xs)), xs)


def test_decompose_known():
    s, e, m = decompose(np.float32(-1.5))
    assert (s, e, m) == (1, 127, 1 << 22)


def test_quantize_examples():
    assert quantize_mantissa(np.float32(1 + 2**-7), 7) == np.float32(1 + 2**-7)
    assert quantize_mantissa(np.float32(1 + 2**-8), 7) == np.float32(1.0)
    # carry into exponent
    assert quantize_mantissa(np.float32(2.0 - 2**-9), 7) == np.float32(2.0)


def test_quantize_flushes_subnormals():
    tiny = np.float32(1e-44)  # subnormal
    assert quantize_mantissa(tiny, 7) == 0.0


finite_f32 = st.floats(width=32, allow_nan=False, allow_infinity=False,
                       allow_subnormal=False)


@settings(max_examples=300, deadline=None)
@given(finite_f32, st.integers(1, 23))
def test_quantize_idempotent(v, m):
    q = quantize_mantissa(np.float32(v), m)
    qq = quantize_mantissa(q, m)
    assert to_bits(q) == to_bits(qq) or (q == 0.0 and qq == 0.0)


@settings(max_examples=300, deadline=None)
@given(finite_f32, st.integers(1, 22))
def test_quantized_mantissa_has_no_low_bits(v, m):
    q = quantize_mantissa(np.float32(v), m)
    if not np.isfinite(q) or q == 0.0:
        return
    _, _, mant = decompose(q)
    assert int(mant) & ((1 << (MANT_BITS - m)) - 1) == 0


def test_compose_decompose_consistency():
    for v in [0.25, 7.0, -128.5]:
        s, e, m = decompose(np.float32(v))
        assert compose(s, e, m) == np.float32(v)
