//! The per-file rule implementations R1–R6 (R7 lives in [`crate::lint::xref`]).
//!
//! Every rule reads the scrubbed channels of a [`SourceFile`] — never the
//! raw text — so doc prose, log strings and commented-out code can not
//! produce findings. The rules are deliberately lexical: they encode the
//! repo's own conventions (documented in `docs/LINTS.md`), not general
//! Rust semantics, which is what makes them implementable without a
//! compiler and reviewable by hand.

use crate::lint::lexer::{
    block_keyword, enclosing_open, find_sub, find_word, ident_before, is_ident_byte,
    matching_close, normalize_line, statement_start,
};
use crate::lint::{policy, Finding, SourceFile};

/// `(start, end)` byte ranges of `#[cfg(test)]`-gated items (the attr
/// through the matching close brace of the item it gates).
pub fn test_regions(code: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for pos in find_sub(code, "#[cfg(test)]") {
        let b = code.as_bytes();
        let mut i = pos + "#[cfg(test)]".len();
        while i < b.len() && b[i] != b'{' {
            i += 1;
        }
        if i < b.len() {
            if let Some(close) = matching_close(code, i) {
                out.push((pos, close));
                continue;
            }
        }
        out.push((pos, code.len()));
    }
    out
}

fn in_test(pos: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(s, e)| pos >= s && pos <= e)
}

// ---------------------------------------------------------------- R1

/// R1: every `unsafe` keyword must be introduced by a comment containing
/// `SAFETY` (line style) or `# Safety` (doc style) — either in the
/// contiguous comment/attribute block directly above the statement that
/// contains it, or on a comment-only line between the statement start
/// and the `unsafe` token itself (multi-line statements).
pub fn r1_safety(sf: &SourceFile, out: &mut Vec<Finding>) {
    for pos in find_word(&sf.code, "unsafe") {
        let stmt = statement_start(&sf.code, pos);
        let stmt_line = sf.line_of(stmt);
        let tok_line = sf.line_of(pos);
        let mut text = String::new();
        // contiguous comment / attribute lines directly above the statement
        let mut l = stmt_line;
        while l > 1 {
            l -= 1;
            let code_t = sf.line_code(l).trim();
            let com_t = sf.line_comments(l).trim();
            if code_t.is_empty() && !com_t.is_empty() {
                text.push_str(com_t);
                text.push('\n');
            } else if code_t.starts_with('#') {
                continue; // attribute line keeps the block contiguous
            } else {
                break;
            }
        }
        // comment lines inside the statement, up to and including the
        // token's own line (trailing `// SAFETY:` comments count)
        for l in stmt_line..=tok_line {
            let com_t = sf.line_comments(l).trim();
            if !com_t.is_empty() {
                text.push_str(com_t);
                text.push('\n');
            }
        }
        if !(text.contains("SAFETY") || text.contains("# Safety")) {
            out.push(Finding {
                rule: "R1",
                path: sf.path.clone(),
                line: tok_line,
                msg: "`unsafe` without an immediately preceding SAFETY comment stating the \
                      invariant"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------- R2

const R2_BANNED_WORDS: &[(&str, &str)] = &[
    ("SystemTime", "wall-clock time is nondeterministic"),
    ("Instant", "monotonic clock reads are nondeterministic"),
    ("HashMap", "randomized iteration order breaks replay; use BTreeMap"),
    ("HashSet", "randomized iteration order breaks replay; use BTreeSet"),
];
const R2_BANNED_SUBS: &[(&str, &str)] = &[
    ("env::var", "environment reads hide run-to-run state"),
    ("var_os", "environment reads hide run-to-run state"),
];

/// R2: determinism — the modules named in
/// [`policy::deterministic_module`] must not touch clocks, hash-ordered
/// collections or the environment outside `#[cfg(test)]` items.
pub fn r2_determinism(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !policy::deterministic_module(&sf.path) {
        return;
    }
    let regions = test_regions(&sf.code);
    let mut push = |pos: usize, tok: &str, why: &str, out: &mut Vec<Finding>| {
        out.push(Finding {
            rule: "R2",
            path: sf.path.clone(),
            line: sf.line_of(pos),
            msg: format!("`{tok}` in a deterministic module: {why}"),
        });
    };
    for &(w, why) in R2_BANNED_WORDS {
        for pos in find_word(&sf.code, w) {
            if !in_test(pos, &regions) {
                push(pos, w, why, out);
            }
        }
    }
    for &(s, why) in R2_BANNED_SUBS {
        for pos in find_sub(&sf.code, s) {
            if !in_test(pos, &regions) {
                push(pos, s, why, out);
            }
        }
    }
}

// ---------------------------------------------------------------- R3

const ATOMIC_VARIANTS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// R3 site scan: every distinct line containing an
/// `std::sync::atomic::Ordering` variant use, as
/// `(line, whitespace-free normalized code line)`. `cmp::Ordering`
/// variants (`Less`/`Equal`/`Greater`) never match.
pub fn r3_sites(sf: &SourceFile) -> Vec<(usize, String)> {
    let b = sf.code.as_bytes();
    let mut lines = Vec::new();
    for pos in find_sub(&sf.code, "Ordering::") {
        if pos > 0 && is_ident_byte(b[pos - 1]) {
            continue;
        }
        let rest = &sf.code[pos + "Ordering::".len()..];
        let follows = ATOMIC_VARIANTS.iter().any(|v| {
            rest.starts_with(v) && !rest[v.len()..].bytes().next().is_some_and(is_ident_byte)
        });
        if !follows {
            continue;
        }
        let line = sf.line_of(pos);
        if lines.last() != Some(&line) {
            lines.push(line);
        }
    }
    lines
        .into_iter()
        .map(|l| (l, normalize_line(sf.line_code(l))))
        .collect()
}

// ---------------------------------------------------------------- R4

/// One accumulation-contract-relevant site.
pub struct AccumSite {
    pub line: usize,
    pub what: &'static str,
}

/// R4 site scan. Two tiers:
///
/// * crate-wide (any scanned file): `mul_add` / `fmadd` tokens — fused
///   multiply-add reassociates the contract's `round(a*b)` then
///   `acc + p` sequence, so every use must sit in an audited file;
/// * inside the accumulation-scope modules
///   ([`policy::accum_scope`]): `.sum(`/`.sum::<`, `.fold(`, and `+=`
///   whose right-hand side still contains a `*` after index
///   expressions (`[…]`) are stripped — the lexical shape of a fused
///   or reassociated product accumulation.
///
/// `#[cfg(test)]` items are exempt (oracles there re-derive sums on
/// purpose). The allowlist in `rust/lint/accum.allow` is file-granular.
pub fn r4_sites(sf: &SourceFile) -> Vec<AccumSite> {
    let regions = test_regions(&sf.code);
    let mut out = Vec::new();
    for pos in find_word(&sf.code, "mul_add") {
        if !in_test(pos, &regions) {
            out.push(AccumSite { line: sf.line_of(pos), what: "mul_add" });
        }
    }
    for pos in find_sub(&sf.code, "fmadd") {
        if !in_test(pos, &regions) {
            out.push(AccumSite { line: sf.line_of(pos), what: "fmadd" });
        }
    }
    if policy::accum_scope(&sf.path) {
        let b = sf.code.as_bytes();
        for pos in find_sub(&sf.code, ".sum") {
            let after = pos + ".sum".len();
            if after < b.len() && is_ident_byte(b[after]) {
                continue;
            }
            if !in_test(pos, &regions) {
                out.push(AccumSite { line: sf.line_of(pos), what: ".sum" });
            }
        }
        for pos in find_sub(&sf.code, ".fold(") {
            if !in_test(pos, &regions) {
                out.push(AccumSite { line: sf.line_of(pos), what: ".fold" });
            }
        }
        for pos in find_sub(&sf.code, "+=") {
            if in_test(pos, &regions) {
                continue;
            }
            let end = sf.code[pos..]
                .find(';')
                .map(|r| pos + r)
                .unwrap_or(sf.code.len());
            let rhs = &sf.code[pos + 2..end];
            // strip index expressions so a[i*k+kk] does not read as a product
            let mut depth = 0i32;
            let mut has_star = false;
            for ch in rhs.chars() {
                match ch {
                    '[' => depth += 1,
                    ']' => depth -= 1,
                    '*' if depth == 0 => has_star = true,
                    _ => {}
                }
            }
            if has_star {
                out.push(AccumSite { line: sf.line_of(pos), what: "+= with product" });
            }
        }
    }
    out
}

// ---------------------------------------------------------------- R5

/// R5a: a `Condvar` wait (receiver named `cv`, the crate-wide
/// convention) must sit directly inside a `while` or `loop` block so the
/// predicate is re-checked against spurious wakeups.
/// R5b: nested `.lock()` acquisitions must appear in
/// [`policy::LOCK_ORDER`].
pub fn r5_condvar_locks(sf: &SourceFile, out: &mut Vec<Finding>) {
    let code = &sf.code;
    for pat in [".wait(", ".wait_timeout("] {
        for pos in find_sub(code, pat) {
            if ident_before(code, pos) != "cv" {
                continue;
            }
            let ok = match enclosing_open(code, pos) {
                Some(open) => {
                    let kw = block_keyword(code, open);
                    kw == "while" || kw == "loop"
                }
                None => false,
            };
            if !ok {
                out.push(Finding {
                    rule: "R5",
                    path: sf.path.clone(),
                    line: sf.line_of(pos),
                    msg: "Condvar wait not directly inside a while/loop predicate re-check"
                        .to_string(),
                });
            }
        }
    }

    // lock sites with their guard scopes
    struct LockSite {
        pos: usize,
        recv: String,
        scope_end: usize,
    }
    let mut sites: Vec<LockSite> = Vec::new();
    for pos in find_sub(code, ".lock(") {
        let recv = ident_before(code, pos);
        let enc_end = enclosing_open(code, pos)
            .and_then(|o| matching_close(code, o))
            .unwrap_or(code.len());
        let scope_end = guard_scope_end(code, pos).unwrap_or_else(|| {
            // temporary guard: lives to the end of its statement
            code[pos..].find(';').map(|r| pos + r).unwrap_or(code.len()).min(enc_end)
        });
        sites.push(LockSite { pos, recv, scope_end: scope_end.min(enc_end) });
    }
    for a in &sites {
        for b in &sites {
            if b.pos <= a.pos || b.pos >= a.scope_end {
                continue;
            }
            let allowed = policy::LOCK_ORDER
                .iter()
                .any(|&(p, outer, inner)| p == sf.path && outer == a.recv && inner == b.recv);
            if !allowed {
                out.push(Finding {
                    rule: "R5",
                    path: sf.path.clone(),
                    line: sf.line_of(b.pos),
                    msg: format!(
                        "nested lock acquisition `{}` -> `{}` not in the declared lock-order \
                         table",
                        a.recv, b.recv
                    ),
                });
            }
        }
    }
}

/// If the `.lock(` call at `pos` is the value of a `let` binding (the
/// binding holds the guard), return where the guard's scope ends: the
/// `drop(<binding>)` call if there is one, else the close of the
/// enclosing block. Returns `None` for temporary guards.
fn guard_scope_end(code: &str, pos: usize) -> Option<usize> {
    let b = code.as_bytes();
    let stmt = statement_start(code, pos);
    let is_let = code[stmt..].starts_with("let")
        && !b.get(stmt + 3).copied().is_some_and(is_ident_byte);
    if !is_let {
        return None;
    }
    // step over `.lock(...)` and any chained `.unwrap*(...)`
    let mut i = matching_paren(code, pos + ".lock".len())? + 1;
    loop {
        let mut j = i;
        while j < b.len() && (b[j] as char).is_whitespace() {
            j += 1;
        }
        if code[j..].starts_with(".unwrap") {
            let mut k = j + 1;
            while k < b.len() && (is_ident_byte(b[k]) || b[k] == b'.') {
                k += 1;
            }
            i = matching_paren(code, k)? + 1;
        } else {
            i = j;
            break;
        }
    }
    if i >= b.len() || b[i] != b';' {
        return None; // chain continues: the guard is a temporary
    }
    // binding name: let [mut] <name>
    let mut w = stmt + "let".len();
    let next_word = |w: &mut usize| -> String {
        while *w < b.len() && !is_ident_byte(b[*w]) {
            *w += 1;
        }
        let s = *w;
        while *w < b.len() && is_ident_byte(b[*w]) {
            *w += 1;
        }
        code[s..*w].to_string()
    };
    let mut name = next_word(&mut w);
    if name == "mut" {
        name = next_word(&mut w);
    }
    let enc_end = enclosing_open(code, pos)
        .and_then(|o| matching_close(code, o))
        .unwrap_or(code.len())
        .max(i);
    for dp in find_word(&code[i..enc_end], "drop") {
        let after = &code[i + dp + "drop".len()..enc_end];
        let inner = after.trim_start();
        if let Some(rest) = inner.strip_prefix('(') {
            if rest.trim_start().strip_prefix(name.as_str()).map_or(false, |r| {
                r.trim_start().starts_with(')')
            }) {
                return Some(i + dp);
            }
        }
    }
    Some(enc_end)
}

fn matching_paren(code: &str, open: usize) -> Option<usize> {
    let b = code.as_bytes();
    if open >= b.len() || b[open] != b'(' {
        return None;
    }
    let mut depth = 0i32;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

// ---------------------------------------------------------------- R6

/// R6: every `#[cfg(target_arch = "x86_64")]` gate must leave a scalar
/// path behind: a gated block/`if` must have fallthrough code after it
/// in the enclosing block, a gated `fn` must have a
/// `#[cfg(not(target_arch …))]` sibling of the same name, and gated
/// `mod`/`use` items are the gate mechanism itself. Files listed in
/// [`policy::GATED_MODULE_FILES`] are compiled only under the gate and
/// are exempt wholesale.
pub fn r6_cfg_gates(sf: &SourceFile, out: &mut Vec<Finding>) {
    if policy::GATED_MODULE_FILES.contains(&sf.path.as_str()) {
        return;
    }
    let code = &sf.code;
    // scalar siblings declared under cfg(not(target_arch ...))
    let mut scalar_fns: Vec<String> = Vec::new();
    for pos in find_sub(code, "#[cfg(not(target_arch") {
        if let Some(GatedItem::Fn(name)) = classify_gated(code, pos) {
            scalar_fns.push(name);
        }
    }
    for pos in find_sub(code, "#[cfg(target_arch") {
        let line = sf.line_of(pos);
        let bad = |msg: String, out: &mut Vec<Finding>| {
            out.push(Finding { rule: "R6", path: sf.path.clone(), line, msg });
        };
        match classify_gated(code, pos) {
            Some(GatedItem::Block { close }) => {
                let enc_end = enclosing_open(code, pos)
                    .and_then(|o| matching_close(code, o))
                    .unwrap_or(code.len());
                let tail = &code[(close + 1).min(enc_end)..enc_end];
                if tail.trim().is_empty() {
                    bad(
                        "gated block with no scalar fallthrough code after it".to_string(),
                        out,
                    );
                }
            }
            Some(GatedItem::Fn(name)) => {
                if !scalar_fns.contains(&name) {
                    bad(
                        format!(
                            "gated fn `{name}` has no `#[cfg(not(target_arch …))]` scalar \
                             counterpart in this file"
                        ),
                        out,
                    );
                }
            }
            Some(GatedItem::ModOrUse) => {}
            None => bad(
                "gated item is not a recognized paired shape (block/if with fallthrough, \
                 fn with scalar sibling, mod, use)"
                    .to_string(),
                out,
            ),
        }
    }
}

enum GatedItem {
    Block { close: usize },
    Fn(String),
    ModOrUse,
}

/// Classify the item a `#[cfg(…)]` attribute at `attr_pos` gates.
fn classify_gated(code: &str, attr_pos: usize) -> Option<GatedItem> {
    let b = code.as_bytes();
    // end of this attribute (strings inside are already blanked, so the
    // first `]` closes it), then any further attributes
    let mut i = attr_pos;
    loop {
        while i < b.len() && b[i] != b']' {
            i += 1;
        }
        i += 1;
        while i < b.len() && (b[i] as char).is_whitespace() {
            i += 1;
        }
        if i < b.len() && b[i] == b'#' {
            continue;
        }
        break;
    }
    if i >= b.len() {
        return None;
    }
    if b[i] == b'{' {
        return Some(GatedItem::Block { close: matching_close(code, i)? });
    }
    // read identifier words until a shape-deciding keyword
    let mut saw_if = false;
    let mut guard = 0;
    while i < b.len() && guard < 16 {
        guard += 1;
        while i < b.len() && !is_ident_byte(b[i]) {
            if b[i] == b'{' {
                // `if cond {` — the gated item is the if's block (plus
                // any else-chain, which shares its fallthrough check)
                return if saw_if {
                    let mut close = matching_close(code, i)?;
                    loop {
                        let rest = code[close + 1..].trim_start();
                        if rest.starts_with("else") {
                            let off = code.len() - rest.len() + "else".len();
                            let mut j = off;
                            while j < b.len() && b[j] != b'{' {
                                j += 1;
                            }
                            close = matching_close(code, j)?;
                        } else {
                            break;
                        }
                    }
                    Some(GatedItem::Block { close })
                } else {
                    None
                };
            }
            i += 1;
        }
        let s = i;
        while i < b.len() && is_ident_byte(b[i]) {
            i += 1;
        }
        match &code[s..i] {
            "if" => saw_if = true,
            "mod" | "use" => return Some(GatedItem::ModOrUse),
            "fn" => {
                let mut j = i;
                while j < b.len() && !is_ident_byte(b[j]) {
                    j += 1;
                }
                let ns = j;
                while j < b.len() && is_ident_byte(b[j]) {
                    j += 1;
                }
                return Some(GatedItem::Fn(code[ns..j].to_string()));
            }
            _ => {}
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::SourceFile;

    fn sf(src: &str) -> SourceFile {
        SourceFile::new("rust/src/kernels/x.rs".to_string(), src)
    }

    #[test]
    fn r1_accepts_and_rejects() {
        let mut out = Vec::new();
        r1_safety(
            &sf("// SAFETY: ptr is valid for n elements\nunsafe { go() };\n"),
            &mut out,
        );
        assert!(out.is_empty());
        r1_safety(&sf("unsafe { go() };\n"), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn r3_excludes_cmp_ordering() {
        let s = sf("a.load(Ordering::Acquire);\nx.cmp(&y) == Ordering::Less;\n");
        let sites = r3_sites(&s);
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].0, 1);
        assert_eq!(sites[0].1, "a.load(Ordering::Acquire);");
    }

    #[test]
    fn r4_strips_index_brackets() {
        let sites = r4_sites(&sf("acc += a[i * k + kk];\nacc += x * r;\n"));
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].line, 2);
    }

    #[test]
    fn r5_wait_needs_loop() {
        let mut out = Vec::new();
        r5_condvar_locks(&sf("while !done { st = self.cv.wait(st).unwrap(); }\n"), &mut out);
        assert!(out.is_empty());
        r5_condvar_locks(&sf("if !done { st = self.cv.wait(st).unwrap(); }\n"), &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn r5_guard_scope_sees_nesting() {
        let src = "fn f() { let mut g = a.lock().unwrap(); let h = b.lock().unwrap(); }\n";
        let mut out = Vec::new();
        r5_condvar_locks(&sf(src), &mut out);
        assert_eq!(out.len(), 1, "nested a -> b must be reported");
        let dropped =
            "fn f() { let mut g = a.lock().unwrap(); drop(g); let h = b.lock().unwrap(); }\n";
        out.clear();
        r5_condvar_locks(&sf(dropped), &mut out);
        assert!(out.is_empty(), "drop(g) ends the guard scope");
    }
}
