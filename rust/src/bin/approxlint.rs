//! `approxlint` — run the in-repo static-analysis pass over a source
//! tree (default: the current directory) and exit nonzero on findings.
//!
//!     cargo run -q --release --bin approxlint -- [repo-root]
//!
//! Pure std, no build of the crate's kernels required; ci.sh runs it as
//! the first stage, before the compile. Rules and policy:
//! `docs/LINTS.md` and the `approxtrain::lint` module docs.

use std::path::Path;
use std::process::ExitCode;

use approxtrain::lint;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    let findings = match lint::run_all(Path::new(&root)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("approxlint: cannot scan `{root}`: {e}");
            return ExitCode::from(2);
        }
    };
    if findings.is_empty() {
        println!("approxlint: clean (rules R1-R7)");
        return ExitCode::SUCCESS;
    }
    let mut rule = "";
    for f in &findings {
        if f.rule != rule {
            rule = f.rule;
            println!("== {rule} ==");
        }
        println!("  {f}");
    }
    println!("approxlint: {} finding(s)", findings.len());
    ExitCode::FAILURE
}
