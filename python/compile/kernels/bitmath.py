"""Integer bit-math implementations of AMSim and the direct multiplier
models in jnp — shared by the Pallas kernels (L1) and the pure-jnp oracle
(``ref.py``). Everything stays inside int32/uint32 so the lowered HLO is
plain integer ALU ops (the widest intermediate product is the k x k AFM
partial product, < 2^12, and the 8x8-bit bfloat16 significand product,
< 2^16).

These mirror ``rust/src/mult/models.rs`` / ``rust/src/amsim`` bit-exactly;
pytest asserts this against the numpy mirrors in ``compile.mults``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# numpy scalars: embedded as literals at trace time (pallas kernels must
# not capture concrete *jax* arrays from the closure, and Python ints above
# int32 max overflow jax's weak typing)
import numpy as np

SIGN_MASK = np.uint32(0x8000_0000)
EXP_MASK = np.uint32(0x7F80_0000)
MANT_MASK = np.uint32(0x007F_FFFF)
MANT_BITS = 23
EXP_BIAS = 127

# REALM correction constants (identical to rust + numpy mirrors), already
# scaled to the 23-bit mantissa field.
REALM_LOG_CORR = (209403, 506903, 669557, 721940, 682465, 565287, 381522, 140059)
REALM_ANTILOG_CORR = (-152893, -408621, -592590, -698305, -718684, -646004, -471841, -187011)


def _bits(x):
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def _float(b):
    return jax.lax.bitcast_convert_type(b, jnp.float32)


def _fields(x):
    b = _bits(x)
    return b, (b & EXP_MASK) >> MANT_BITS, b & MANT_MASK


def _assemble(sign, exp_pre, carry, mant):
    """Common sign/exponent scaffolding (paper Alg. 2 lines 11-20):
    flush-to-zero on the pre-carry exponent, overflow after the carry."""
    zero = exp_pre <= 0
    exp_c = exp_pre + carry.astype(jnp.int32)
    inf = exp_c >= 255
    exp_field = jnp.clip(exp_c, 1, 254).astype(jnp.uint32)
    body = sign | (exp_field << MANT_BITS) | mant
    out = jnp.where(zero, jnp.uint32(0), jnp.where(inf, sign | EXP_MASK, body))
    return _float(out)


def _exp_pre(ea, eb):
    exp = ea.astype(jnp.int32) + eb.astype(jnp.int32) - EXP_BIAS
    # operand zero/subnormal forces a flush
    return jnp.where((ea == 0) | (eb == 0), jnp.int32(-1000), exp)


# ---------------------------------------------------------------------------
# AMSim: LUT-based simulation (paper Algorithm 2)
# ---------------------------------------------------------------------------

def amsim_mul(a, b, lut, m: int):
    """Elementwise LUT-based approximate multiply. ``lut`` is a uint32
    vector of 2^(2m) entries ``(carry << 23) | mantissa23``."""
    ab, ea, ma = _fields(a)
    bb, eb, mb = _fields(b)
    sh = jnp.uint32(MANT_BITS - m)
    idx = ((ma >> sh) << jnp.uint32(m)) | (mb >> sh)
    entry = jnp.take(lut, idx.astype(jnp.int32), axis=0)
    carry = (entry >> jnp.uint32(MANT_BITS)) & jnp.uint32(1)
    mant = entry & MANT_MASK
    sign = (ab ^ bb) & SIGN_MASK
    return _assemble(sign, _exp_pre(ea, eb), carry, mant)


# ---------------------------------------------------------------------------
# Direct bit-manipulation models (the Fig 6 "direct simulation" path)
# ---------------------------------------------------------------------------

def _trunc_m(mant, m: int):
    keep = jnp.uint32((0x007F_FFFF >> (MANT_BITS - m)) << (MANT_BITS - m))
    return mant & keep


def afm_mantissa(ma, mb, m: int, k: int):
    """AFM (minimally biased) mantissa product in int32-safe arithmetic:
    ``x + y + topk(x)*topk(y) + (x + y) >> (k+1)`` with the k x k partial
    product computed on the k-bit tops (shift 23-2k keeps it in range)."""
    ma = _trunc_m(ma, m)
    mb = _trunc_m(mb, m)
    top = jnp.uint32(MANT_BITS - k)
    ak = ma >> top  # k bits
    bk_ = mb >> top
    xy = (ak * bk_) << jnp.uint32(MANT_BITS - 2 * k)
    comp = (ma + mb) >> jnp.uint32(k + 1)
    t = ma + mb + xy + comp  # < 3 * 2^23, fits uint32
    one = jnp.uint32(1 << MANT_BITS)
    carry = (t >= one).astype(jnp.uint32)
    frac = jnp.where(carry == 1, jnp.minimum((t - one) >> jnp.uint32(1), MANT_MASK), t)
    return carry, _trunc_m(frac, m)


def mitchell_mantissa(ma, mb, m: int):
    s = _trunc_m(ma, m) + _trunc_m(mb, m)
    one = jnp.uint32(1 << MANT_BITS)
    carry = (s >= one).astype(jnp.uint32)
    frac = jnp.where(carry == 1, s - one, s)
    return carry, _trunc_m(frac, m)


def _seg_lookup(seg, table):
    """8-entry constant lookup as a select chain — pallas kernels may not
    capture array constants, but scalar constants are fine (and on real
    hardware this is exactly the 8-way constant mux REALM synthesizes)."""
    out = jnp.full(seg.shape, np.int32(table[0]))
    for i in range(1, 8):
        out = jnp.where(seg == i, np.int32(table[i]), out)
    return out


def realm_mantissa(ma, mb, m: int):
    ma = _trunc_m(ma, m).astype(jnp.int32)
    mb = _trunc_m(mb, m).astype(jnp.int32)
    seg = lambda v: v >> (MANT_BITS - 3)
    s = (ma + mb + _seg_lookup(seg(ma), REALM_LOG_CORR)
         + _seg_lookup(seg(mb), REALM_LOG_CORR))
    one = jnp.int32(1 << MANT_BITS)
    carry = (s >= one).astype(jnp.uint32)
    s = jnp.where(carry == 1, s - one, s)
    f = jnp.clip(s, 0, int(0x007F_FFFF))
    g = jnp.clip(f + _seg_lookup(seg(f), REALM_ANTILOG_CORR), 0, int(0x007F_FFFF))
    return carry, _trunc_m(g.astype(jnp.uint32), m)


def exact_mantissa(ma, mb, m: int):
    """Exact RNE product at m <= 11 bits (significand product fits int32)."""
    assert m <= 11, "int32-safe exact product needs m <= 11"
    sh = jnp.uint32(MANT_BITS - m)
    sa = (jnp.uint32(1 << m) | (_trunc_m(ma, m) >> sh))  # m+1 bits
    sb = (jnp.uint32(1 << m) | (_trunc_m(mb, m) >> sh))
    p = sa * sb  # [2^2m, 2^(2m+2))
    carry = (p >> jnp.uint32(2 * m + 1)).astype(jnp.uint32)
    # variable drop keeps the carry-normalization shift-out bit as part of
    # the rounding tail (no double rounding — matches the 46-bit mirrors)
    drop = jnp.uint32(m) + carry
    kept = (p >> drop) & jnp.uint32((1 << m) - 1)
    low = p & ((jnp.uint32(1) << drop) - 1)
    half = jnp.uint32(1) << (drop - 1)
    kept = kept + ((low > half) | ((low == half) & ((kept & 1) == 1))).astype(jnp.uint32)
    ovf = (kept >> jnp.uint32(m)) != 0
    kept = jnp.where(ovf, jnp.uint32(0), kept)
    carry = carry + ovf.astype(jnp.uint32)
    return carry, (kept << sh) & MANT_MASK


_DIRECT = {
    "afm32": lambda ma, mb: afm_mantissa(ma, mb, 23, 6),
    "afm16": lambda ma, mb: afm_mantissa(ma, mb, 7, 4),
    "mit16": lambda ma, mb: mitchell_mantissa(ma, mb, 7),
    "realm16": lambda ma, mb: realm_mantissa(ma, mb, 7),
    "bfloat16": lambda ma, mb: exact_mantissa(ma, mb, 7),
    "fp16": lambda ma, mb: exact_mantissa(ma, mb, 10),
}

DIRECT_NAMES = tuple(_DIRECT)


def direct_mul(a, b, mult_name: str):
    """Elementwise direct (bit-manipulation) approximate multiply for the
    in-graph simulable designs."""
    ab, ea, ma = _fields(a)
    bb, eb, mb = _fields(b)
    carry, mant = _DIRECT[mult_name](ma, mb)
    sign = (ab ^ bb) & SIGN_MASK
    return _assemble(sign, _exp_pre(ea, eb), carry, mant)
