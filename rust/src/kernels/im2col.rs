//! The three IM2COL kernels (paper §VI-D).
//!
//! * [`im2col_forward`] — standard patch extraction for the forward pass.
//! * [`im2col_weight_grad`] — patch extraction for the weight gradient with
//!   the paper's key optimization: the dilation of `Errors^{l+1}` implied by
//!   stride > 1 is **fused** by *skipping* input elements instead of
//!   materializing a dilated array (§VI-B.1).
//! * [`im2col_plg`] — patch extraction over the *logical*
//!   `PaddedDilatedErrors^{l+1}` for the preceding-layer gradient: each
//!   element checks whether its position is a dilated (zero) position and
//!   reads the undilated error array otherwise (§VI-B.2).
//! * [`dilate_explicit`] — the naive separate-dilation baseline the paper
//!   argues against; kept for the ablation benchmark.

use super::Conv2dGeom;

/// Forward im2col: `cols[b*oh*ow, kh*kw*c]`, NHWC input, zero padding.
pub fn im2col_forward(g: &Conv2dGeom, input: &[f32], cols: &mut [f32]) {
    assert_eq!(input.len(), g.batch * g.in_h * g.in_w * g.in_c);
    assert_eq!(cols.len(), g.col_rows() * g.col_cols());
    let (oh, ow) = (g.out_h(), g.out_w());
    let mut idx = 0;
    for b in 0..g.batch {
        let in_base = b * g.in_h * g.in_w * g.in_c;
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..g.k_h {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for kx in 0..g.k_w {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if iy < 0 || iy >= g.in_h as isize || ix < 0 || ix >= g.in_w as isize {
                            for _ in 0..g.in_c {
                                cols[idx] = 0.0;
                                idx += 1;
                            }
                        } else {
                            let src =
                                in_base + (iy as usize * g.in_w + ix as usize) * g.in_c;
                            cols[idx..idx + g.in_c]
                                .copy_from_slice(&input[src..src + g.in_c]);
                            idx += g.in_c;
                        }
                    }
                }
            }
        }
    }
}

/// Weight-gradient im2col with fused dilation (paper §VI-B.1).
///
/// Produces `cols[kh*kw*c, b*oh*ow]` such that
/// `dW[kh*kw*c, oc] = cols x dY[b*oh*ow, oc]`.
/// The stride-induced dilation of the error map is realized by *reading the
/// activation at strided positions* — no dilated array is ever built.
pub fn im2col_weight_grad(g: &Conv2dGeom, activation: &[f32], cols: &mut [f32]) {
    assert_eq!(activation.len(), g.batch * g.in_h * g.in_w * g.in_c);
    let (oh, ow) = (g.out_h(), g.out_w());
    let q_len = g.batch * oh * ow;
    assert_eq!(cols.len(), g.col_cols() * q_len);
    for ky in 0..g.k_h {
        for kx in 0..g.k_w {
            for c in 0..g.in_c {
                let r = (ky * g.k_w + kx) * g.in_c + c;
                let row = &mut cols[r * q_len..(r + 1) * q_len];
                let mut q = 0;
                for b in 0..g.batch {
                    let in_base = b * g.in_h * g.in_w * g.in_c;
                    for oy in 0..oh {
                        // fused dilation: stride positions are *skipped
                        // reads* of the activation, exactly the paper's
                        // IM2COL_Weight_Kernel element skipping
                        let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                        for ox in 0..ow {
                            let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                            row[q] = if iy < 0
                                || iy >= g.in_h as isize
                                || ix < 0
                                || ix >= g.in_w as isize
                            {
                                0.0
                            } else {
                                activation
                                    [in_base + (iy as usize * g.in_w + ix as usize) * g.in_c + c]
                            };
                            q += 1;
                        }
                    }
                }
            }
        }
    }
}

/// Preceding-layer-gradient im2col (paper §VI-B.2 / IM2COL_PLG_Kernel).
///
/// Logically: pad and dilate `errors[b, oh, ow, oc]` to
/// `PD[b, (oh-1)*s+1 + 2*(kh-1-pad), ...]`, then im2col with stride 1 and a
/// `kh x kw` window, yielding `cols[b*in_h*in_w, kh*kw*oc]` so that
/// `dX = cols x TransposedReversedW[kh*kw*oc, c]`.
///
/// Physically: each output element computes its position inside the logical
/// padded-dilated array and either copies a zero (dilated/padded position)
/// or reads the original `errors` — the fused pad+dilate of the paper.
pub fn im2col_plg(g: &Conv2dGeom, errors: &[f32], cols: &mut [f32]) {
    let (oh, ow) = (g.out_h(), g.out_w());
    assert_eq!(errors.len(), g.batch * oh * ow * g.out_c);
    let rows = g.batch * g.in_h * g.in_w;
    let rlen = g.k_h * g.k_w * g.out_c;
    assert_eq!(cols.len(), rows * rlen);
    // full-correlation padding of the dilated map
    let pad_h = g.k_h as isize - 1 - g.pad as isize;
    let pad_w = g.k_w as isize - 1 - g.pad as isize;
    let mut idx = 0;
    for b in 0..g.batch {
        let e_base = b * oh * ow * g.out_c;
        for y in 0..g.in_h as isize {
            for x in 0..g.in_w as isize {
                for ky in 0..g.k_h as isize {
                    // position inside the logical dilated (stride-spaced) map
                    let dy = y + ky - pad_h;
                    for kx in 0..g.k_w as isize {
                        let dx = x + kx - pad_w;
                        // a real error element sits at dilated position
                        // (oy*s, ox*s); everything else is a fused zero
                        let s = g.stride as isize;
                        let valid = dy >= 0
                            && dx >= 0
                            && dy % s == 0
                            && dx % s == 0
                            && dy / s < oh as isize
                            && dx / s < ow as isize;
                        if valid {
                            let src = e_base
                                + ((dy / s) as usize * ow + (dx / s) as usize) * g.out_c;
                            cols[idx..idx + g.out_c]
                                .copy_from_slice(&errors[src..src + g.out_c]);
                        } else {
                            cols[idx..idx + g.out_c].fill(0.0);
                        }
                        idx += g.out_c;
                    }
                }
            }
        }
    }
}

/// Naive explicit dilation (the baseline the paper's fused approach
/// replaces): insert `stride-1` zeros between error elements. Returns the
/// dilated map of shape `[batch, (oh-1)*s+1, (ow-1)*s+1, oc]`.
pub fn dilate_explicit(g: &Conv2dGeom, errors: &[f32]) -> (Vec<f32>, usize, usize) {
    let (oh, ow) = (g.out_h(), g.out_w());
    assert_eq!(errors.len(), g.batch * oh * ow * g.out_c);
    let dh = (oh - 1) * g.stride + 1;
    let dw = (ow - 1) * g.stride + 1;
    let mut out = vec![0.0f32; g.batch * dh * dw * g.out_c];
    for b in 0..g.batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let src = ((b * oh + oy) * ow + ox) * g.out_c;
                let dst = ((b * dh + oy * g.stride) * dw + ox * g.stride) * g.out_c;
                out[dst..dst + g.out_c].copy_from_slice(&errors[src..src + g.out_c]);
            }
        }
    }
    (out, dh, dw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn geom(stride: usize, pad: usize) -> Conv2dGeom {
        Conv2dGeom {
            batch: 2,
            in_h: 6,
            in_w: 6,
            in_c: 3,
            k_h: 3,
            k_w: 3,
            out_c: 4,
            stride,
            pad,
        }
    }

    #[test]
    fn forward_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: cols == input
        let g = Conv2dGeom { k_h: 1, k_w: 1, ..geom(1, 0) };
        let n = g.batch * g.in_h * g.in_w * g.in_c;
        let input: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
        im2col_forward(&g, &input, &mut cols);
        assert_eq!(cols, input);
    }

    #[test]
    fn forward_padding_zeros_at_border() {
        let g = geom(1, 1);
        let n = g.batch * g.in_h * g.in_w * g.in_c;
        let input = vec![1.0f32; n];
        let mut cols = vec![-1.0f32; g.col_rows() * g.col_cols()];
        im2col_forward(&g, &input, &mut cols);
        // first output position (0,0): top-left 3x3 patch has 5 padded
        // positions (first row + first col) of 3 channels each
        let first_patch = &cols[0..g.col_cols()];
        let zeros = first_patch.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 5 * 3);
        assert_eq!(first_patch.iter().filter(|&&v| v == 1.0).count(), 4 * 3);
    }

    /// Fused-dilation weight-grad columns must equal the explicit route:
    /// dilate errors, then compute the stride-1 weight-grad columns.
    #[test]
    fn weight_grad_fusion_equals_explicit_dilation() {
        for stride in [1, 2, 3] {
            let g = geom(stride, 1);
            let mut rng = Pcg32::seeded(31);
            let act: Vec<f32> =
                (0..g.batch * g.in_h * g.in_w * g.in_c).map(|_| rng.range(-1.0, 1.0)).collect();
            let (oh, ow) = (g.out_h(), g.out_w());
            let q = g.batch * oh * ow;
            let mut cols = vec![0.0f32; g.col_cols() * q];
            im2col_weight_grad(&g, &act, &mut cols);
            // reference: dW[r, oc] via direct convolution definition
            let errors: Vec<f32> = (0..q * g.out_c).map(|_| rng.range(-1.0, 1.0)).collect();
            // dW from cols x errors
            let mut dw_fused = vec![0.0f32; g.col_cols() * g.out_c];
            for r in 0..g.col_cols() {
                for oc in 0..g.out_c {
                    let mut acc = 0.0;
                    for qq in 0..q {
                        acc += cols[r * q + qq] * errors[qq * g.out_c + oc];
                    }
                    dw_fused[r * g.out_c + oc] = acc;
                }
            }
            // dW from the convolution definition
            let mut dw_ref = vec![0.0f32; g.col_cols() * g.out_c];
            for ky in 0..g.k_h {
                for kx in 0..g.k_w {
                    for c in 0..g.in_c {
                        for oc in 0..g.out_c {
                            let mut acc = 0.0;
                            for b in 0..g.batch {
                                for oy in 0..oh {
                                    for ox in 0..ow {
                                        let iy =
                                            (oy * g.stride + ky) as isize - g.pad as isize;
                                        let ix =
                                            (ox * g.stride + kx) as isize - g.pad as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= g.in_h as isize
                                            || ix >= g.in_w as isize
                                        {
                                            continue;
                                        }
                                        let a = act[((b * g.in_h + iy as usize) * g.in_w
                                            + ix as usize)
                                            * g.in_c
                                            + c];
                                        let e = errors
                                            [((b * oh + oy) * ow + ox) * g.out_c + oc];
                                        acc += a * e;
                                    }
                                }
                            }
                            dw_ref[((ky * g.k_w + kx) * g.in_c + c) * g.out_c + oc] = acc;
                        }
                    }
                }
            }
            for i in 0..dw_ref.len() {
                assert!(
                    (dw_fused[i] - dw_ref[i]).abs() < 1e-4,
                    "stride {stride} idx {i}: {} vs {}",
                    dw_fused[i],
                    dw_ref[i]
                );
            }
        }
    }

    #[test]
    fn explicit_dilation_shape_and_content() {
        let g = geom(2, 0);
        let (oh, ow) = (g.out_h(), g.out_w());
        let errors: Vec<f32> = (0..g.batch * oh * ow * g.out_c).map(|i| i as f32 + 1.0).collect();
        let (d, dh, dw) = dilate_explicit(&g, &errors);
        assert_eq!((dh, dw), ((oh - 1) * 2 + 1, (ow - 1) * 2 + 1));
        // non-zero exactly at even positions
        for b in 0..g.batch {
            for y in 0..dh {
                for x in 0..dw {
                    let v = d[((b * dh + y) * dw + x) * g.out_c];
                    if y % 2 == 0 && x % 2 == 0 {
                        assert_ne!(v, 0.0);
                    } else {
                        assert_eq!(v, 0.0);
                    }
                }
            }
        }
    }

    /// PLG columns must reproduce the logical pad+dilate+im2col composition.
    #[test]
    fn plg_fusion_equals_explicit_composition() {
        for (stride, pad) in [(1, 0), (1, 1), (2, 1), (2, 0)] {
            let g = geom(stride, pad);
            let (oh, ow) = (g.out_h(), g.out_w());
            let mut rng = Pcg32::seeded(32);
            let errors: Vec<f32> =
                (0..g.batch * oh * ow * g.out_c).map(|_| rng.range(-1.0, 1.0)).collect();
            let rows = g.batch * g.in_h * g.in_w;
            let rlen = g.k_h * g.k_w * g.out_c;
            let mut cols = vec![0.0f32; rows * rlen];
            im2col_plg(&g, &errors, &mut cols);

            // explicit: dilate, add the asymmetric output padding
            // ((in + 2p - k) % s extra zero rows/cols at bottom-right, the
            // standard conv-transpose correction), then pad, then stride-1
            // im2col
            let (d, dh, dw) = dilate_explicit(&g, &errors);
            let opad_h = (g.in_h + 2 * g.pad - g.k_h) % g.stride;
            let opad_w = (g.in_w + 2 * g.pad - g.k_w) % g.stride;
            let (eh, ew) = (dh + opad_h, dw + opad_w);
            let mut d_ext = vec![0.0f32; g.batch * eh * ew * g.out_c];
            for b in 0..g.batch {
                for y in 0..dh {
                    for x in 0..dw {
                        for ch in 0..g.out_c {
                            d_ext[((b * eh + y) * ew + x) * g.out_c + ch] =
                                d[((b * dh + y) * dw + x) * g.out_c + ch];
                        }
                    }
                }
            }
            let gd = Conv2dGeom {
                batch: g.batch,
                in_h: eh,
                in_w: ew,
                in_c: g.out_c,
                k_h: g.k_h,
                k_w: g.k_w,
                out_c: 1,
                stride: 1,
                pad: (g.k_h as isize - 1 - g.pad as isize) as usize,
            };
            assert_eq!((gd.out_h(), gd.out_w()), (g.in_h, g.in_w), "stride {stride} pad {pad}");
            let mut cols_ref = vec![0.0f32; gd.col_rows() * gd.col_cols()];
            im2col_forward(&gd, &d_ext, &mut cols_ref);
            assert_eq!(cols.len(), cols_ref.len());
            for i in 0..cols.len() {
                assert_eq!(cols[i], cols_ref[i], "stride {stride} pad {pad} idx {i}");
            }
        }
    }
}
