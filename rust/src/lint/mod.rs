//! `approxlint` — the crate's in-repo static-analysis pass.
//!
//! Every guarantee this reproduction makes (bit-exact replay of the
//! ascending-k single-accumulator contract, deterministic fault scripts,
//! audited atomics, paired SIMD fallbacks) is a *source-level* property:
//! a new call site can silently break it without failing any runtime
//! test, because runtime tests only exercise the sites that already
//! exist. This module encodes those contracts as seven lexical rules
//! over the crate's own sources and runs them as the first ci.sh stage —
//! before the build, in milliseconds, with no dependencies beyond std:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1 | every `unsafe` is introduced by a `SAFETY`/`# Safety` comment |
//! | R2 | deterministic modules never touch clocks, hash maps, or env |
//! | R3 | every atomic `Ordering::` site is in the reviewed allowlist |
//! | R4 | FP accumulation shapes stay in the audited accumulator files |
//! | R5 | `Condvar` waits re-check in loops; lock nesting is declared |
//! | R6 | every `x86_64` cfg gate leaves a scalar path behind |
//! | R7 | tests ↔ Cargo.toml ↔ ci.sh ↔ bench-schema docs agree |
//!
//! The scan set is `rust/src/**` plus the top level of `rust/tests/` and
//! `examples/` — the planted-violation fixtures in
//! `rust/tests/lint_fixtures/` are data for the lint's own test suite,
//! not part of the tree under lint. Rules read scrubbed channels
//! ([`lexer::scrub`]) so comments and strings can't produce findings.
//! Policy — which modules are deterministic, which files are audited
//! accumulators, the lock-order table — is declared in [`policy`], and
//! the two reviewed allowlists live in `rust/lint/*.allow`. Rationale,
//! extension guide and the normalization spec: `docs/LINTS.md`.

pub mod allow;
pub mod lexer;
pub mod rules;
pub mod xref;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint finding. Rendered as `RULE path:line message`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}:{} {}", self.rule, self.path, self.line, self.msg)
    }
}

/// A source file prepared for linting: repo-relative path plus the two
/// scrubbed channels and shared line offsets (code and comments have
/// identical line structure by construction).
pub struct SourceFile {
    pub path: String,
    pub code: String,
    pub comments: String,
    offsets: Vec<usize>,
}

impl SourceFile {
    pub fn new(path: String, raw: &str) -> SourceFile {
        let sc = lexer::scrub(raw);
        let offsets = lexer::line_offsets(&sc.code);
        SourceFile { path, code: sc.code, comments: sc.comments, offsets }
    }

    /// 1-based line number of byte `pos` in either channel.
    pub fn line_of(&self, pos: usize) -> usize {
        lexer::line_of(&self.offsets, pos)
    }

    /// Code-channel text of 1-based line `l` (without the newline).
    pub fn line_code(&self, l: usize) -> &str {
        self.slice_line(&self.code, l)
    }

    /// Comments-channel text of 1-based line `l`.
    pub fn line_comments(&self, l: usize) -> &str {
        self.slice_line(&self.comments, l)
    }

    fn slice_line<'a>(&self, chan: &'a str, l: usize) -> &'a str {
        if l == 0 || l > self.offsets.len() {
            return "";
        }
        let start = self.offsets[l - 1];
        let end = self.offsets.get(l).map(|e| e - 1).unwrap_or(chan.len());
        &chan[start..end.max(start)]
    }
}

/// The declared policy tables: which parts of the tree each rule binds.
/// Deliberately coarse (path prefixes, receiver-name conventions) so a
/// reviewer can audit the tables themselves in one sitting.
pub mod policy {
    /// R2: modules whose behavior must be a pure function of their
    /// inputs — the simulation core and the replay-critical coordinator
    /// pieces (fault scripts are keyed on batch indices, never wall
    /// time; the wire format and the reduction tree must replay).
    pub const DETERMINISTIC_PREFIXES: &[&str] =
        &["rust/src/kernels/", "rust/src/amsim/", "rust/src/mult/"];
    pub const DETERMINISTIC_FILES: &[&str] = &[
        "rust/src/coordinator/data_parallel.rs",
        "rust/src/coordinator/faults.rs",
        "rust/src/coordinator/wire.rs",
    ];

    pub fn deterministic_module(path: &str) -> bool {
        DETERMINISTIC_PREFIXES.iter().any(|p| path.starts_with(p))
            || DETERMINISTIC_FILES.contains(&path)
    }

    /// R4: where accumulation shapes are checked at all — the modules
    /// implementing the crate's FP32 accumulator chains. Everywhere
    /// else, `+=` is overwhelmingly integer bookkeeping; here, every
    /// product-accumulation must sit in an `accum.allow`-audited file.
    pub const ACCUM_SCOPE_PREFIXES: &[&str] = &["rust/src/kernels/", "rust/src/amsim/"];
    pub const ACCUM_SCOPE_FILES: &[&str] = &["rust/src/coordinator/data_parallel.rs"];

    pub fn accum_scope(path: &str) -> bool {
        ACCUM_SCOPE_PREFIXES.iter().any(|p| path.starts_with(p))
            || ACCUM_SCOPE_FILES.contains(&path)
    }

    /// R5: the only sanctioned nested lock acquisition,
    /// `(file, outer receiver, inner receiver)`. `ThreadPool::wait`
    /// holds the `done` guard while reading `panic`; the worker side
    /// releases `panic` before touching `done`, so the order is acyclic.
    pub const LOCK_ORDER: &[(&str, &str, &str)] =
        &[("rust/src/util/threads.rs", "done", "panic")];

    /// R6: files that are themselves `#[cfg(target_arch = "x86_64")]`
    /// modules (gated at their `mod` declaration) — every item inside
    /// is x86-only by construction and needs no per-item pairing.
    pub const GATED_MODULE_FILES: &[&str] =
        &["rust/src/kernels/simd.rs", "rust/src/amsim/simd.rs"];

    /// Checked-in allowlists (repo-relative).
    pub const ATOMICS_ALLOW: &str = "rust/lint/atomics.allow";
    pub const ACCUM_ALLOW: &str = "rust/lint/accum.allow";
}

fn walk(dir: &Path, recursive: bool, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            if recursive {
                walk(&p, true, out)?;
            }
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Load the scan set under `root`: `rust/src/**/*.rs` plus the top
/// level of `rust/tests/` and `examples/`.
pub fn collect_files(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    walk(&root.join("rust/src"), true, &mut paths)?;
    walk(&root.join("rust/tests"), false, &mut paths)?;
    let examples = root.join("examples");
    if examples.is_dir() {
        walk(&examples, false, &mut paths)?;
    }
    let mut files = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        let raw = fs::read_to_string(&p)?;
        files.push(SourceFile::new(rel, &raw));
    }
    Ok(files)
}

/// Run every rule over the tree at `root`; returns the sorted findings
/// (empty = clean). IO errors reading the tree itself are returned as
/// `Err`; missing/malformed allowlists are findings, not errors.
pub fn run_all(root: &Path) -> io::Result<Vec<Finding>> {
    let files = collect_files(root)?;
    let mut out = Vec::new();

    let mut atomic_sites: Vec<(String, usize, String)> = Vec::new(); // path, line, key
    let mut accum_sites: Vec<(String, usize, &'static str)> = Vec::new();
    for sf in &files {
        rules::r1_safety(sf, &mut out);
        rules::r2_determinism(sf, &mut out);
        rules::r5_condvar_locks(sf, &mut out);
        rules::r6_cfg_gates(sf, &mut out);
        for (line, key) in rules::r3_sites(sf) {
            atomic_sites.push((sf.path.clone(), line, key));
        }
        for site in rules::r4_sites(sf) {
            accum_sites.push((sf.path.clone(), site.line, site.what));
        }
    }

    // R3: every site allowlisted, every entry live
    match fs::read_to_string(root.join(policy::ATOMICS_ALLOW)) {
        Ok(text) => match allow::parse_atomics(&text) {
            Ok(entries) => {
                for (path, line, key) in &atomic_sites {
                    if !entries.iter().any(|e| e.path == *path && e.key == *key) {
                        out.push(Finding {
                            rule: "R3",
                            path: path.clone(),
                            line: *line,
                            msg: format!(
                                "atomic ordering site not in {} (key `{key}`): add an entry \
                                 with a one-line justification",
                                policy::ATOMICS_ALLOW
                            ),
                        });
                    }
                }
                for e in &entries {
                    if !atomic_sites.iter().any(|(p, _, k)| *p == e.path && *k == e.key) {
                        out.push(Finding {
                            rule: "R3",
                            path: policy::ATOMICS_ALLOW.to_string(),
                            line: e.line,
                            msg: format!("stale allowlist entry: no site in {} matches `{}`",
                                e.path, e.key),
                        });
                    }
                }
            }
            Err((line, msg)) => out.push(Finding {
                rule: "R3",
                path: policy::ATOMICS_ALLOW.to_string(),
                line,
                msg,
            }),
        },
        Err(_) => out.push(Finding {
            rule: "R3",
            path: policy::ATOMICS_ALLOW.to_string(),
            line: 1,
            msg: "atomics allowlist missing".to_string(),
        }),
    }

    // R4: every site in an audited file, every audited file live
    match fs::read_to_string(root.join(policy::ACCUM_ALLOW)) {
        Ok(text) => match allow::parse_accum(&text) {
            Ok(entries) => {
                for (path, line, what) in &accum_sites {
                    if !entries.iter().any(|e| e.path == *path) {
                        out.push(Finding {
                            rule: "R4",
                            path: path.clone(),
                            line: *line,
                            msg: format!(
                                "accumulation-contract site (`{what}`) outside the audited \
                                 accumulator files in {}",
                                policy::ACCUM_ALLOW
                            ),
                        });
                    }
                }
                for e in &entries {
                    if !accum_sites.iter().any(|(p, _, _)| *p == e.path) {
                        out.push(Finding {
                            rule: "R4",
                            path: policy::ACCUM_ALLOW.to_string(),
                            line: e.line,
                            msg: format!("stale allowlist entry: {} has no accumulation sites",
                                e.path),
                        });
                    }
                }
            }
            Err((line, msg)) => out.push(Finding {
                rule: "R4",
                path: policy::ACCUM_ALLOW.to_string(),
                line,
                msg,
            }),
        },
        Err(_) => out.push(Finding {
            rule: "R4",
            path: policy::ACCUM_ALLOW.to_string(),
            line: 1,
            msg: "accumulation allowlist missing".to_string(),
        }),
    }

    xref::r7_xref(root, &mut out);

    out.sort_by(|a, b| {
        (a.rule, &a.path, a.line, &a.msg).cmp(&(b.rule, &b.path, b.line, &b.msg))
    });
    Ok(out)
}
