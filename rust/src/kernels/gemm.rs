//! GEMM kernel — the paper's workhorse (§VI-D "GEMM kernel").
//!
//! The CUDA version fetches 16x16 tiles of both operands into on-chip
//! shared memory; the CPU analog is cache blocking: pack a `BK x BN` panel
//! of `B` once per tile row and walk `A` rows through it, accumulating in
//! FP32. The multiply itself is pluggable ([`MulKernel`]) so the same
//! kernel body serves the native / direct-simulation / AMSim comparisons of
//! Fig 6.

use super::MulKernel;

/// Cache-block sizes. 64x64 f32 panels are 16 KiB — two fit in a typical
/// 32 KiB L1D the way two 16x16 tiles fit in a CUDA SM's shared memory.
pub const BM: usize = 64;
pub const BN: usize = 64;
pub const BK: usize = 64;

/// `c[M,N] = a[M,K] * b[K,N]` (row-major, C overwritten), multiplications
/// routed through `mul`, accumulation in FP32.
pub fn gemm(mul: &MulKernel, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_threaded(mul, a, b, c, m, k, n, 1);
}

/// Threaded variant: output row-blocks are distributed over `threads`
/// workers (the coarse-grained parallelism axis of the CUDA grid).
pub fn gemm_threaded(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let threads = threads.max(1).min(m);
    if threads == 1 {
        gemm_block_range(mul, a, b, c, 0, m, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, c_block) in c.chunks_mut(rows_per * n).enumerate() {
            let m0 = t * rows_per;
            let m1 = (m0 + c_block.len() / n).min(m);
            s.spawn(move || {
                // re-base the row indices onto the thread's sub-slice of C
                gemm_rows_into(mul, a, b, c_block, m0, m1, k, n);
            });
        }
    });
}

/// Blocked GEMM of global rows `[m0, m1)` written into a C sub-slice that
/// starts at row `m0`.
fn gemm_rows_into(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c_block: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    let mut b_panel = vec![0.0f32; BK * BN];
    for j0 in (0..n).step_by(BN) {
        let jn = (j0 + BN).min(n);
        for k0 in (0..k).step_by(BK) {
            let kn = (k0 + BK).min(k);
            let kw = kn - k0;
            for j in j0..jn {
                for kk in k0..kn {
                    b_panel[(j - j0) * kw + (kk - k0)] = b[kk * n + j];
                }
            }
            for i in m0..m1 {
                let a_row = &a[i * k + k0..i * k + kn];
                let c_row = &mut c_block[(i - m0) * n + j0..(i - m0) * n + jn];
                for (jj, c_val) in c_row.iter_mut().enumerate() {
                    let b_col = &b_panel[jj * kw..jj * kw + kw];
                    *c_val += mul.dot(a_row, b_col);
                }
            }
        }
    }
}

/// Internal: single-threaded blocked GEMM over a row range `[m0, m1)`.
/// The B panel `[k0..kn, j0..jn]` is packed contiguously (the CUDA
/// "shared-memory fetch") and transposed so the inner dot walks both
/// operands with stride 1.
fn gemm_block_range(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    gemm_rows_into(mul, a, b, &mut c[m0 * n..m1 * n], m0, m1, k, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amsim::AmSim;
    use crate::lut::MantissaLut;
    use crate::mult::fpbits::quantize_mantissa;
    use crate::mult::registry;
    use crate::util::rng::Pcg32;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn native_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 70, 130)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
            let mut c = vec![0.0f32; m * n];
            gemm(&MulKernel::Native, &a, &b, &mut c, m, k, n);
            let want = naive_gemm(&a, &b, m, k, n);
            for i in 0..m * n {
                assert!(
                    (c[i] - want[i]).abs() <= 1e-4 * want[i].abs().max(1.0),
                    "({m},{k},{n}) idx {i}: {} vs {}",
                    c[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn lut_and_direct_agree_bitwise() {
        // ATxG and ATxC must produce identical numbers — the paper's own
        // validation methodology (§VI footnote 2).
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(22);
        let (m, k, n) = (19, 31, 23);
        let a: Vec<f32> =
            (0..m * k).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let mut c_direct = vec![0.0f32; m * n];
        let mut c_lut = vec![0.0f32; m * n];
        gemm(&MulKernel::Direct(model.as_ref()), &a, &b, &mut c_direct, m, k, n);
        gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_lut, m, k, n);
        for i in 0..m * n {
            assert_eq!(c_direct[i].to_bits(), c_lut[i].to_bits(), "idx {i}");
        }
    }

    #[test]
    fn approx_gemm_is_close_to_exact() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(23);
        let (m, k, n) = (16, 64, 16);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut c_exact = vec![0.0f32; m * n];
        let mut c_approx = vec![0.0f32; m * n];
        gemm(&MulKernel::Native, &a, &b, &mut c_exact, m, k, n);
        gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_approx, m, k, n);
        let scale = (k as f32).sqrt();
        for i in 0..m * n {
            assert!(
                (c_exact[i] - c_approx[i]).abs() < 0.05 * scale,
                "idx {i}: {} vs {}",
                c_exact[i],
                c_approx[i]
            );
        }
    }

    #[test]
    fn empty_dims() {
        let mut c = vec![0.0f32; 0];
        gemm(&MulKernel::Native, &[], &[], &mut c, 0, 5, 0);
    }
}
