//! Planted R4 violations: all three accumulation shapes. The lint test
//! lints this under an accumulation-scope virtual path (all three fire)
//! and under an out-of-scope path (only the crate-wide `mul_add` fires).

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

pub fn fused(a: f32, b: f32, c: f32) -> f32 {
    a.mul_add(b, c)
}
