//! GEMM kernel — the paper's workhorse (§VI-D "GEMM kernel").
//!
//! The CUDA version fetches 16x16 tiles of both operands into on-chip
//! shared memory; the CPU analog is the hierarchical cache-blocked kernel
//! [`gemm_tiled`]: `A` is packed into `MC x KC` row-panels and `B` into
//! `KC x NC` column-panels (reusable per-thread buffers, see
//! [`super::with_pack_buffers`]), so the batched [`MulBackend`] panel ops
//! — and in particular the AMSim LUT-gather loop — stream over contiguous
//! memory instead of striding through `B`. The output is partitioned into
//! a 2D grid of `MC x NC` tiles scheduled over the persistent worker pool
//! ([`crate::util::threads`]) with work-stealing over a shared tile queue
//! (the pool's atomic chunk cursor); every tile owns a disjoint rectangle
//! of `C`, so results are deterministic for any lane count.
//!
//! ## The micro-kernel drain
//!
//! Within a tile, output is produced by the register-blocked `MR x NR`
//! *micro-kernel* ([`MulBackend::mul_microtile`], BLIS-style register
//! blocking): per contraction step the `MR` packed `A` operands and `NR`
//! packed `B` operands are decomposed once (pre-shifted LUT row bases,
//! hoisted exponents/signs) and feed `MR x NR` **independent** FP32
//! accumulator chains, hiding FP-add latency and cutting per-MAC
//! decomposition cost by ~`MR*NR/(MR+NR)` versus draining each element
//! with its own serial [`MulBackend::dot_panel_acc`] chain. To feed it
//! contiguously, `B` panels are packed in an **`NR`-strip interleaved
//! layout** (see [`PackB`]). Remainder edges (`m mod MR`, `n mod NR`)
//! run the same micro-kernel at the leftover `mr`/`nr`; a fully
//! degenerate `1 x 1` micro-tile instead drains through
//! [`MulBackend::dot_panel_acc`] — the pre-micro-kernel per-element
//! path, bit-identical by that op's accumulator-continuation contract
//! (and what the bench's `mr1nr1` ablation row measures).
//!
//! ## The accumulation contract
//!
//! Every GEMM path in this module computes each output element with a
//! **single running FP32 accumulator, adding products in ascending
//! contraction (`k`) order** — cache blocks continue the accumulator via
//! [`MulBackend::dot_panel_acc`] / [`MulBackend::mul_microtile`] instead
//! of reducing block-local partial sums, and the micro-kernel's `MR x NR`
//! accumulators are independent *between* elements but strictly
//! sequential *within* each element. FP addition is not associative, so
//! this is what makes the result *independent of blocking*:
//! [`gemm_tiled`] is bit-identical to the per-element scalar oracle
//! [`gemm_scalar_reference`] for **all three strategies** (native
//! included — same op sequence, and rustc neither reassociates nor
//! FMA-contracts f32 arithmetic), at every tile size, micro-tile shape
//! and thread count. `tests/batched_vs_scalar.rs`,
//! `tests/microtile.rs` and the in-module property tests enforce this.
//!
//! The pre-tiling row-sliced path is kept as [`gemm_panel`] /
//! [`gemm_panel_threaded`]: same contract, no `A` packing, 1D row-block
//! threading — the bench's "panel vs tiled" comparison partner.
//!
//! ## Panel sources (implicit GEMM)
//!
//! Operand packing is abstracted behind the [`PackA`] / [`PackB`] traits:
//! [`gemm_tiled_src`] packs its `MC x KC` / `KC x NC` panels from
//! whatever source it is handed. [`SliceA`] / [`SliceB`] reproduce the
//! materialized-matrix packing (and [`gemm_tiled_with`] is exactly that),
//! while the im2col sources in [`super::im2col`] pack panels *directly
//! from the NHWC tensors* using the fused dilation/padding index
//! computations — the implicit-GEMM convolution: no `col_rows x
//! col_cols` matrix ever exists, memory is `O(tile)` via the recycled
//! [`super::with_pack_buffers`] buffers. Because a source only defines
//! *values* and the dot/accumulation code is shared, the implicit route
//! is bit-identical to the materialized route by construction (enforced
//! in `tests/conv_grads.rs` and `tests/batched_vs_scalar.rs`).
//!
//! ## The zero-skipping sparse drain
//!
//! Sparsity rides the same packing abstraction: [`PackA::pack_a_occ`] /
//! [`PackB::pack_b_occ`] emit a per-micro-panel [`Occupancy`] bitmap next
//! to the packed floats (one bit per `mr`-row group of the `A` panel, one
//! per `nr`-column strip of the `B` panel — exactly the granularity the
//! micro-kernel drains at), and `tile_into` elides every (a-row-group ×
//! b-strip) pair in which either side is dead, so only live pairs reach
//! [`MulBackend::mul_microtile`]. Magnitude-pruned models (see
//! `coordinator::pruning`) thus stop paying dense cost through the packed
//! tiles. Skipping is gated per multiplier by
//! [`MulKernel::zero_skip_ok`] — the audited `mul(0, x)` zero-identity
//! capability — and falls back to the dense drain (bit-for-bit the
//! pre-sparsity behaviour, occupancy never scanned) where the identity
//! fails, native hardware `*` included (`0 × inf == NaN`). The full
//! bitwise-no-op argument lives on [`PackA::pack_a_occ`];
//! `tests/sparse_gemm.rs` holds the occupancy-residue × sparsity
//! differential net, the sign-of-zero teeth test and the dense-fallback
//! proofs, and [`super::panel_skip_events`] counts elided pairs for
//! observability.

use super::{
    note_panel_drain, with_pack_buffers_occ, MulBackend, MulKernel, Occupancy, MR_MAX, NR_MAX,
};
use crate::util::threads::{self, SendMutPtr};

/// Source of `A`-operand row-panels for the tiled GEMM — the packing half
/// of the "implicit GEMM" generalization (paper §VI-B: dilation/padding
/// fused into IM2COL indexing instead of materialized arrays).
///
/// `pack_a` must fill `out` (row-major, `ih` rows of `kw` elements) with
/// the rectangle of the *logical* `M x K` matrix whose top-left element is
/// `(i0, k0)`. [`gemm_tiled_src`] only ever reads what it packed, so the
/// values a source produces fully define its logical matrix; a source that
/// computes elements on the fly (the im2col sources in
/// [`super::im2col`]) is indistinguishable — bit for bit — from a
/// [`SliceA`] over the materialized matrix.
///
/// `Sync` is a supertrait because panels are packed concurrently by the
/// worker pool's lanes (each into its own thread-local buffer).
pub trait PackA: Sync {
    fn pack_a(&self, i0: usize, ih: usize, k0: usize, kw: usize, out: &mut [f32]);

    /// Pack the panel **and** emit its per-micro-panel [`Occupancy`]
    /// bitmap: one bit per `mr`-row group (group `g` covers packed rows
    /// `[g*mr, min((g+1)*mr, ih))`; the last group may be short), set iff
    /// the group holds at least one element with `v != 0.0`. `±0.0` is
    /// dead; NaN and subnormals are live (conservative — the skip
    /// argument below only needs *dead* to mean "exactly a signed zero").
    ///
    /// The default packs via [`PackA::pack_a`] and then scans the packed
    /// floats ([`scan_packed_a`]), so every source — the materialized
    /// [`SliceA`], the implicit im2col sources — gets a correct bitmap
    /// for free; a source with cheaper structural knowledge (e.g. a
    /// block-sparse store) may override, but the bitmap it emits must
    /// equal the scan of its packed values.
    ///
    /// ## Why skipping a dead micro-panel pair is a bitwise no-op
    ///
    /// The drain elides a (row-group × strip) pair only when (a) the
    /// multiplier passed [`MulKernel::zero_skip_ok`], so `mul(±0, x)` and
    /// `mul(x, ±0)` are signed zeros for **every** `x` (NaN/inf
    /// included), and (b) one side of the pair is all-`±0.0` — so every
    /// elided product is a `±0.0`. Adding `±0.0` to an FP32 accumulator
    /// is the identity for every value except `acc == -0.0` (where
    /// `-0.0 + (+0.0) == +0.0` flips the sign bit). That residual case
    /// cannot arise: [`gemm_tiled_src`] initializes `C` with `+0.0`
    /// (`c.fill(0.0)`), and under round-to-nearest-even an addition only
    /// produces `-0.0` from `(-0.0) + (-0.0)`, so an accumulator chain
    /// seeded with `+0.0` can never reach `-0.0`. Hence eliding the adds
    /// leaves the accumulator — and every later add in the ascending-`k`
    /// chain — bitwise unchanged: the crate-wide contract survives.
    /// `tests/sparse_gemm.rs::minus_zero_accumulator_is_the_load_bearing_edge`
    /// is the teeth test showing the `+0.0`-fill premise is load-bearing.
    fn pack_a_occ(
        &self,
        i0: usize,
        ih: usize,
        k0: usize,
        kw: usize,
        mr: usize,
        out: &mut [f32],
        occ: &mut Occupancy,
    ) {
        self.pack_a(i0, ih, k0, kw, out);
        scan_packed_a(out, ih, kw, mr, occ);
    }
}

/// Source of `B`-operand column-panels for the tiled GEMM.
///
/// `pack_b` must fill `out` (length `jw * kw`) with the panel covering
/// columns `[j0, j0 + jw)` x contraction rows `[k0, k0 + kw)` of the
/// logical `K x N` matrix, in the **`nr`-strip interleaved layout** the
/// micro-kernel streams: columns are grouped into strips of `nr` (the
/// last strip may be narrower), strip `s` of width `w` starts at element
/// offset `s * nr * kw`, and within a strip the element for contraction
/// step `kk`, strip column `c` sits at `strip_base + kk * w + c`:
///
/// ```text
/// out[s*nr*kw + kk*w + c] = B[k0 + kk, j0 + s*nr + c]
/// ```
///
/// so each micro-kernel step reads its `w` `B` operands contiguously and
/// consecutive steps advance by `w` — a pure streaming walk. With
/// `nr == 1` this degenerates to the previous transposed column-major
/// layout (`out[j * kw + kk]`).
pub trait PackB: Sync {
    fn pack_b(&self, j0: usize, jw: usize, k0: usize, kw: usize, nr: usize, out: &mut [f32]);

    /// Pack the panel **and** emit its per-strip [`Occupancy`] bitmap:
    /// one bit per `nr`-column strip (strip `s` covers panel columns
    /// `[s*nr, min((s+1)*nr, jw))` — contiguous in the interleaved
    /// layout), set iff the strip holds at least one `v != 0.0` element.
    /// Same liveness convention, default implementation shape
    /// (pack-then-scan via [`scan_packed_b`]) and override contract as
    /// [`PackA::pack_a_occ`], where the full skip-safety argument lives.
    fn pack_b_occ(
        &self,
        j0: usize,
        jw: usize,
        k0: usize,
        kw: usize,
        nr: usize,
        out: &mut [f32],
        occ: &mut Occupancy,
    ) {
        self.pack_b(j0, jw, k0, kw, nr, out);
        scan_packed_b(out, jw, kw, nr, occ);
    }
}

/// Scan a packed `A` panel (row-major `ih x kw`, the [`PackA::pack_a`]
/// layout) into its per-`mr`-row-group occupancy bitmap. Row groups are
/// contiguous in the packed layout, so each test is one linear sweep that
/// short-circuits at the first live element.
pub fn scan_packed_a(out: &[f32], ih: usize, kw: usize, mr: usize, occ: &mut Occupancy) {
    let groups = ih.div_ceil(mr);
    occ.reset(groups);
    for g in 0..groups {
        let r1 = ((g + 1) * mr).min(ih);
        if out[g * mr * kw..r1 * kw].iter().any(|&v| v != 0.0) {
            occ.set(g);
        }
    }
}

/// Scan a packed `B` panel (`nr`-strip interleaved, the [`PackB::pack_b`]
/// layout) into its per-strip occupancy bitmap. Strips are contiguous
/// (`kw * w` elements each), so each test is one linear sweep.
pub fn scan_packed_b(out: &[f32], jw: usize, kw: usize, nr: usize, occ: &mut Occupancy) {
    let strips = jw.div_ceil(nr);
    occ.reset(strips);
    let mut base = 0;
    for s in 0..strips {
        let w = nr.min(jw - s * nr);
        if out[base..base + kw * w].iter().any(|&v| v != 0.0) {
            occ.set(s);
        }
        base += kw * w;
    }
}

/// [`PackA`] over a materialized row-major `M x K` slice (`k` = row
/// stride). The packing loop previously hard-wired into `tile_into`.
pub struct SliceA<'a> {
    pub data: &'a [f32],
    pub k: usize,
}

impl PackA for SliceA<'_> {
    fn pack_a(&self, i0: usize, ih: usize, k0: usize, kw: usize, out: &mut [f32]) {
        // hard even in release: a mis-sized panel buffer would hand the
        // micro-kernel's unsafe SIMD arm a short operand slice (once per
        // panel, so the cost is noise)
        assert_eq!(out.len(), ih * kw);
        for i in 0..ih {
            let src = (i0 + i) * self.k + k0;
            out[i * kw..(i + 1) * kw].copy_from_slice(&self.data[src..src + kw]);
        }
    }
}

/// [`PackB`] over a materialized row-major `K x N` slice (`n` = row
/// stride), packed into the `nr`-strip interleaved layout. Each strip
/// row is a contiguous `w`-wide copy out of a `B` row — unit-stride on
/// both sides, unlike the per-element strided writes of the old
/// column-major packing.
pub struct SliceB<'a> {
    pub data: &'a [f32],
    pub n: usize,
}

impl PackB for SliceB<'_> {
    fn pack_b(&self, j0: usize, jw: usize, k0: usize, kw: usize, nr: usize, out: &mut [f32]) {
        // hard even in release (see SliceA::pack_a)
        assert_eq!(out.len(), jw * kw);
        let mut base = 0;
        let mut j = 0;
        while j < jw {
            let w = nr.min(jw - j);
            for kk in 0..kw {
                let src = (k0 + kk) * self.n + j0 + j;
                out[base + kk * w..base + (kk + 1) * w]
                    .copy_from_slice(&self.data[src..src + w]);
            }
            base += w * kw;
            j += w;
        }
    }
}

/// Cache-block sizes of the row-sliced [`gemm_panel`] path. 64x64 f32
/// panels are 16 KiB — two fit in a typical 32 KiB L1D the way two 16x16
/// tiles fit in a CUDA SM's shared memory.
pub const BN: usize = 64;
pub const BK: usize = 64;

/// MAC-count threshold above which [`gemm_auto`] fans out over the pool.
/// Below it, panel packing + tile handoff costs more than it saves.
pub const AUTO_THREAD_MACS: usize = 1 << 18;

/// Tile geometry of the hierarchical cache-blocked [`gemm_tiled`] path:
/// `A` row-panels are `mc x kc`, `B` column-panels `kc x nc`, the output
/// is computed in `mc x nc` tiles, and each tile is drained by the
/// `mr x nr` register-blocked micro-kernel
/// ([`MulBackend::mul_microtile`]).
///
/// Thanks to the running-accumulator contract (module docs) the choice
/// only affects speed, never a single output bit — micro-tile shape
/// included.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileConfig {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
    /// Micro-kernel register-block height (`<=` [`super::MR_MAX`]).
    pub mr: usize,
    /// Micro-kernel register-block width (`<=` [`super::NR_MAX`]);
    /// also the `B`-panel strip width (see [`PackB`]).
    pub nr: usize,
}

impl TileConfig {
    /// Default geometry: a 64x128 `A` panel and a 128x64 `B` panel are
    /// 32 KiB each (both L2-resident; one 128-step 8-wide `B` strip is
    /// 4 KiB, comfortably L1-resident under the gather loop). The 4x8
    /// micro-tile runs 32 independent accumulator chains and amortizes
    /// each operand decomposition over 8/4 products respectively
    /// (`4*8/(4+8) ~ 2.7x` fewer decompositions per MAC).
    pub const DEFAULT: TileConfig = TileConfig { mc: 64, kc: 128, nc: 64, mr: 4, nr: 8 };

    /// Geometries probed by the bench autotune (`bench-gemm` records the
    /// fastest into `BENCH_gemm.json`), sweeping the micro-tile shape
    /// (`mr x nr`) alongside the cache-tile shape. The `mr = nr = 1`
    /// candidate is the pre-micro-kernel per-element drain — kept both as
    /// the ablation row and so the autotune can detect a machine where
    /// register blocking loses. Bit-exactness is unaffected by the
    /// choice; only cache/register behaviour differs per machine.
    pub const AUTOTUNE_CANDIDATES: [TileConfig; 8] = [
        TileConfig { mc: 32, kc: 64, nc: 32, mr: 4, nr: 4 },
        TileConfig { mc: 64, kc: 64, nc: 64, mr: 4, nr: 8 },
        TileConfig::DEFAULT,
        TileConfig { mc: 64, kc: 128, nc: 64, mr: 1, nr: 1 },
        TileConfig { mc: 64, kc: 128, nc: 64, mr: 2, nr: 16 },
        TileConfig { mc: 64, kc: 128, nc: 64, mr: 8, nr: 4 },
        TileConfig { mc: 64, kc: 256, nc: 64, mr: 8, nr: 8 },
        TileConfig { mc: 128, kc: 128, nc: 128, mr: 4, nr: 8 },
    ];

    fn assert_valid(&self) {
        assert!(
            self.mc > 0 && self.kc > 0 && self.nc > 0 && self.mr > 0 && self.nr > 0,
            "tile dims must be positive: {self:?}"
        );
        assert!(
            self.mr <= super::MR_MAX && self.nr <= super::NR_MAX,
            "micro-tile exceeds {}x{}: {self:?}",
            super::MR_MAX,
            super::NR_MAX
        );
    }
}

/// `c[M,N] = a[M,K] * b[K,N]` (row-major, C overwritten), multiplications
/// routed through `mul`, accumulation in FP32. Single-lane tiled kernel
/// with the default [`TileConfig`].
pub fn gemm(mul: &MulKernel, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_tiled(mul, a, b, c, m, k, n);
}

/// [`gemm`] that picks its own parallelism: the persistent pool's full
/// width for large problems, single-lane for small ones. The layers
/// (conv/dense) call this so every model forward/backward shares the same
/// warm pool and the same tiled hot path.
pub fn gemm_auto(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    gemm_auto_src(mul, &SliceA { data: a, k }, &SliceB { data: b, n }, c, m, k, n);
}

/// Single-lane cache-blocked GEMM with the default [`TileConfig`].
pub fn gemm_tiled(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm_tiled_with(mul, TileConfig::DEFAULT, a, b, c, m, k, n, 1);
}

/// Cache-blocked GEMM with the default [`TileConfig`], fanned out over
/// the persistent pool when `threads > 1`.
pub fn gemm_tiled_threaded(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    gemm_tiled_with(mul, TileConfig::DEFAULT, a, b, c, m, k, n, threads);
}

/// The hierarchical cache-blocked GEMM. `threads <= 1` runs inline. At
/// `threads >= ` the pool width, every `MC x NC` output tile is its own
/// queue entry drained by all lanes plus the submitting thread
/// (work-stealing: fast lanes naturally take more tiles); a smaller
/// `threads` caps concurrency by splitting the tile range into that many
/// chunks, so at most `threads` lanes execute. Scheduling never affects
/// output: bit-identical to [`gemm_scalar_reference`] for every
/// strategy, tile geometry and lane count — see the module-level
/// accumulation contract.
pub fn gemm_tiled_with(
    mul: &MulKernel,
    cfg: TileConfig,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    gemm_tiled_src(mul, cfg, &SliceA { data: a, k }, &SliceB { data: b, n }, c, m, k, n, threads);
}

/// [`gemm_tiled_with`] generalized over [`PackA`]/[`PackB`] panel sources
/// — the implicit-GEMM entry point. The tiling, scheduling and inner dot
/// loops are byte-for-byte the slice path's (that path *is* this one with
/// [`SliceA`]/[`SliceB`]), so any source producing the same logical
/// matrix values yields bit-identical output at every tile geometry and
/// thread count.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiled_src(
    mul: &MulKernel,
    cfg: TileConfig,
    a: &dyn PackA,
    b: &dyn PackB,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(c.len(), m * n, "C shape");
    cfg.assert_valid();
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let tile_cols = n.div_ceil(cfg.nc);
    let tiles = m.div_ceil(cfg.mc) * tile_cols;
    let base = SendMutPtr(c.as_mut_ptr());
    let threads = threads.max(1).min(tiles);
    if threads == 1 {
        for t in 0..tiles {
            tile_into(mul, cfg, a, b, base, m, k, n, t, tile_cols);
        }
        return;
    }
    // at full width, chunk = one tile (the finest-grained stealing queue);
    // below it, `threads` chunks bound how many lanes can pick up work
    let pool = threads::global();
    let chunks = if threads >= pool.width() { tiles } else { threads };
    pool.run_chunks(tiles, chunks, |_, t0, t1| {
        for t in t0..t1 {
            tile_into(mul, cfg, a, b, base, m, k, n, t, tile_cols);
        }
    });
}

/// [`gemm_tiled_src`] that picks its own parallelism: pool width above
/// [`AUTO_THREAD_MACS`] MACs, single lane below. The single home of the
/// auto-threading policy — [`gemm_auto`] delegates here with slice
/// sources, so the implicit conv path and the slice path can never
/// diverge in parallelism.
pub fn gemm_auto_src(
    mul: &MulKernel,
    a: &dyn PackA,
    b: &dyn PackB,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let lanes = threads::global().width();
    let big = m.saturating_mul(k).saturating_mul(n) >= AUTO_THREAD_MACS;
    let threads = if big { lanes } else { 1 };
    gemm_tiled_src(mul, TileConfig::DEFAULT, a, b, c, m, k, n, threads);
}

/// Compute one `MC x NC` output tile. For each `KC` block of the
/// contraction dimension, the `A` rows and `B` columns of the block are
/// packed into this thread's reusable buffers (the CUDA "shared-memory
/// fetch") by the panel sources — a memcpy for slice operands, on-the-fly
/// im2col indexing for implicit conv operands — then the tile is drained
/// in `mr x nr` micro-tiles: accumulators are loaded from `C`, continued
/// through the register-blocked [`MulBackend::mul_microtile`] over the
/// whole `KC` block, and stored back. Remainder micro-tiles at the
/// tile's right/bottom edges run the same micro-kernel at the leftover
/// `mr`/`nr` width; a `1 x 1` micro-tile drains through
/// [`MulBackend::dot_panel_acc`] (the pre-micro-kernel per-element
/// path) — either way the edges follow the exact same per-element
/// accumulation sequence.
///
/// Deliberate trade-off: each tile packs its own operand panels, so a
/// `B` panel is re-packed once per tile *row* (and an `A` panel once per
/// tile *column*) — ~`1/mc + 1/nc` of the MAC count in cheap copies.
/// The payoff is that tiles stay fully independent (no shared packed
/// panel, no synchronization), which is what lets the scheduler hand
/// them out as a free-form work-stealing queue.
#[allow(clippy::too_many_arguments)]
fn tile_into(
    mul: &MulKernel,
    cfg: TileConfig,
    a: &dyn PackA,
    b: &dyn PackB,
    c: SendMutPtr,
    m: usize,
    k: usize,
    n: usize,
    tile: usize,
    tile_cols: usize,
) {
    let i0 = (tile / tile_cols) * cfg.mc;
    let i1 = (i0 + cfg.mc).min(m);
    let j0 = (tile % tile_cols) * cfg.nc;
    let j1 = (j0 + cfg.nc).min(n);
    let (ih, jw) = (i1 - i0, j1 - j0);
    // Zero-skipping is decided once per GEMM: only multipliers with the
    // audited zero identity may elide dead micro-panel pairs (see
    // PackA::pack_a_occ for the bitwise no-op argument). Everything else
    // — native hardware `*` included — takes the dense drain below with
    // the occupancy bitmaps never scanned nor read.
    let skip = mul.zero_skip_ok();
    // micro-tile accumulator block, on the stack (at most 1 KiB)
    let mut acc = [0.0f32; MR_MAX * NR_MAX];
    // (considered, skipped) micro-panel pair counts, accumulated in
    // locals and flushed to the global observability counters once per
    // tile so the hot loop never touches an atomic
    let (pairs, skips) = with_pack_buffers_occ(cfg.mc * cfg.kc, cfg.kc * cfg.nc, |apack,
                                                                                  bpack,
                                                                                  a_occ,
                                                                                  b_occ| {
        let (mut pairs, mut skips) = (0u64, 0u64);
        for k0 in (0..k).step_by(cfg.kc) {
            let kn = (k0 + cfg.kc).min(k);
            let kw = kn - k0;
            if skip {
                a.pack_a_occ(i0, ih, k0, kw, cfg.mr, &mut apack[..ih * kw], a_occ);
                b.pack_b_occ(j0, jw, k0, kw, cfg.nr, &mut bpack[..jw * kw], b_occ);
            } else {
                a.pack_a(i0, ih, k0, kw, &mut apack[..ih * kw]);
                b.pack_b(j0, jw, k0, kw, cfg.nr, &mut bpack[..jw * kw]);
            }
            for i in (0..ih).step_by(cfg.mr) {
                let mh = cfg.mr.min(ih - i);
                let a_live = !skip || a_occ.get(i / cfg.mr);
                let a_rows = &apack[i * kw..(i + mh) * kw];
                // walk the B panel strip by strip (strip s of width w
                // starts at s*nr*kw — the PackB interleaved layout)
                let mut strip = 0;
                let mut j = 0;
                let mut s = 0;
                while j < jw {
                    let w = cfg.nr.min(jw - j);
                    pairs += 1;
                    if skip && !(a_live && b_occ.get(s)) {
                        // dead pair: every elided product has a ±0.0
                        // operand, so under the zero-identity gate every
                        // elided add is a bitwise no-op on this (+0.0-
                        // seeded, never −0.0) accumulator chain
                        skips += 1;
                        strip += kw * w;
                        j += w;
                        s += 1;
                        continue;
                    }
                    let b_strip = &bpack[strip..strip + kw * w];
                    if mh == 1 && w == 1 {
                        // A 1x1 micro-tile IS the per-element drain:
                        // continue this element's accumulator through the
                        // 4-wide-unrolled dot instead (bit-identical by
                        // the dot_panel_acc contract, faster for the
                        // degenerate shape). With cfg.mr == cfg.nr == 1 —
                        // width-1 strips are plain contiguous columns —
                        // this reproduces the pre-micro-kernel tile drain
                        // exactly, which is what the bench's mr1nr1
                        // ablation row measures.
                        //
                        // SAFETY: same disjoint-rectangle argument as the
                        // micro-tile path below.
                        let c_elem = unsafe {
                            std::slice::from_raw_parts_mut(c.0.add((i0 + i) * n + j0 + j), 1)
                        };
                        c_elem[0] = mul.dot_panel_acc(c_elem[0], a_rows, b_strip);
                        strip += kw;
                        j += 1;
                        s += 1;
                        continue;
                    }
                    let acc_t = &mut acc[..mh * w];
                    for r in 0..mh {
                        // SAFETY: this row segment (row i0+i+r, cols
                        // j0+j .. j0+j+w) lies inside the tile's
                        // rectangle. Tiles partition C into disjoint
                        // rectangles, the pool's chunk cursor dispenses
                        // each tile index to exactly one lane, and
                        // run_chunks blocks until every tile completes —
                        // so no two live `&mut` slices ever overlap while
                        // `c` is borrowed.
                        let c_row = unsafe {
                            std::slice::from_raw_parts_mut(
                                c.0.add((i0 + i + r) * n + j0 + j),
                                w,
                            )
                        };
                        acc_t[r * w..(r + 1) * w].copy_from_slice(c_row);
                    }
                    mul.mul_microtile(acc_t, a_rows, b_strip, mh, w, kw);
                    for r in 0..mh {
                        // SAFETY: same disjoint rectangle as the load above.
                        let c_row = unsafe {
                            std::slice::from_raw_parts_mut(
                                c.0.add((i0 + i + r) * n + j0 + j),
                                w,
                            )
                        };
                        c_row.copy_from_slice(&acc_t[r * w..(r + 1) * w]);
                    }
                    strip += kw * w;
                    j += w;
                    s += 1;
                }
            }
        }
        (pairs, skips)
    });
    note_panel_drain(pairs, skips);
}

/// Warm-up: fan one rendezvous chunk per pool lane so each lane
/// allocates its thread-local pack buffers for the default
/// [`TileConfig`] before the first timed step. Without the rendezvous
/// the submitting thread would drain all the no-op chunks before the
/// parked workers even wake, leaving their buffers unallocated until
/// the first real (timed) tile. Called from the trainer and the
/// batching server right after they touch the global pool.
pub fn warm_tiled() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    // Serialize rendezvous jobs: two *concurrent* warms on the shared
    // global pool could otherwise each pin a subset of the lanes and
    // wait on the other forever (worker channels can receive the two
    // jobs in crossed orders). One warm at a time always completes:
    // every worker eventually drains its queue down to this job.
    static WARM_LOCK: Mutex<()> = Mutex::new(());
    let _guard = WARM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let pool = threads::global();
    let lanes = pool.width();
    let cfg = TileConfig::DEFAULT;
    let arrived = AtomicUsize::new(0);
    pool.run_chunks(lanes, lanes, |_, _, _| {
        with_pack_buffers_occ(cfg.mc * cfg.kc, cfg.kc * cfg.nc, |_, _, a_occ, b_occ| {
            // size the occupancy words too, so a later zero-skipping
            // (sparse) GEMM doesn't pay its first growth on a timed tile
            a_occ.reset(cfg.mc.div_ceil(cfg.mr));
            b_occ.reset(cfg.nc.div_ceil(cfg.nr));
        });
        // Hold this chunk until every lane has claimed one, so exactly
        // one chunk runs on each distinct lane (otherwise the submitting
        // thread drains the whole no-op queue before workers wake). The
        // wait is normally worker wake-up latency (microseconds); the
        // iteration bound turns any unforeseen schedule into a benign
        // partial warm instead of a hang.
        arrived.fetch_add(1, Ordering::SeqCst);
        let mut spins = 0u32;
        while arrived.load(Ordering::SeqCst) < lanes && spins < 100_000 {
            std::thread::yield_now();
            spins += 1;
        }
    });
}

/// Row-sliced panel GEMM — the pre-tiling hot path, kept as the bench's
/// comparison partner for [`gemm_tiled`]. Packs only `B` (per `BK x BN`
/// block, re-packed once per row-slice) and walks `A` in place. Same
/// running-accumulator contract, so it is also bit-identical to
/// [`gemm_scalar_reference`].
pub fn gemm_panel(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    gemm_panel_threaded(mul, a, b, c, m, k, n, 1);
}

/// [`gemm_panel`] with output row-blocks distributed over `threads` lanes
/// of the persistent pool (the 1D threading scheme [`gemm_tiled_with`]
/// replaces). Bit-identical to the single-threaded result for every
/// strategy and thread count.
pub fn gemm_panel_threaded(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let threads = threads.max(1).min(m);
    if threads == 1 {
        gemm_rows_into(mul, a, b, c, 0, m, k, n);
        return;
    }
    let base = SendMutPtr(c.as_mut_ptr());
    threads::global().run_chunks(m, threads, |_, m0, m1| {
        // SAFETY: run_chunks hands out disjoint row ranges [m0, m1) and
        // blocks until all chunks complete, so each C row is written by
        // exactly one lane while `c` is alive.
        let c_block = unsafe { std::slice::from_raw_parts_mut(base.0.add(m0 * n), (m1 - m0) * n) };
        gemm_rows_into(mul, a, b, c_block, m0, m1, k, n);
    });
}

/// Blocked GEMM of global rows `[m0, m1)` written into a C sub-slice that
/// starts at row `m0`. The B panel `[k0..kn, j0..jn]` is packed
/// contiguously and transposed so the inner dot walks both operands with
/// stride 1; each output element's accumulator is continued across `BK`
/// blocks (`dot_panel_acc`), preserving the crate-wide ascending-k order.
fn gemm_rows_into(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c_block: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
) {
    let mut b_panel = vec![0.0f32; BK * BN];
    for j0 in (0..n).step_by(BN) {
        let jn = (j0 + BN).min(n);
        for k0 in (0..k).step_by(BK) {
            let kn = (k0 + BK).min(k);
            let kw = kn - k0;
            for j in j0..jn {
                for kk in k0..kn {
                    b_panel[(j - j0) * kw + (kk - k0)] = b[kk * n + j];
                }
            }
            for i in m0..m1 {
                let a_row = &a[i * k + k0..i * k + kn];
                let c_row = &mut c_block[(i - m0) * n + j0..(i - m0) * n + jn];
                for (jj, c_val) in c_row.iter_mut().enumerate() {
                    let b_col = &b_panel[jj * kw..jj * kw + kw];
                    *c_val = mul.dot_panel_acc(*c_val, a_row, b_col);
                }
            }
        }
    }
}

/// The per-element-dispatch oracle: a naive row-major triple loop where
/// every multiply goes through the scalar [`MulKernel::mul`] enum
/// dispatch and every output element is one running FP32 accumulator
/// over ascending `k`. This *defines* the accumulation order of the
/// crate-wide contract (module docs); [`gemm_panel`] and [`gemm_tiled`]
/// must reproduce it bit for bit.
///
/// Doubles as the bench baseline (`BENCH_gemm.json`, strategy
/// `lut_scalar_dispatch`): unamortized per-multiply dispatch with no
/// cache blocking — the AdaPT-style cost the batched tiled panels close.
pub fn gemm_scalar_reference(
    mul: &MulKernel,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += mul.mul(a[i * k + kk], b[kk * n + j]);
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amsim::AmSim;
    use crate::lut::MantissaLut;
    use crate::mult::fpbits::quantize_mantissa;
    use crate::mult::registry;
    use crate::util::rng::Pcg32;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn rand_vec(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.range(-2.0, 2.0)).collect()
    }

    #[test]
    fn native_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 64, 64), (65, 70, 130)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
            let mut c = vec![0.0f32; m * n];
            gemm(&MulKernel::Native, &a, &b, &mut c, m, k, n);
            let want = naive_gemm(&a, &b, m, k, n);
            for i in 0..m * n {
                assert_eq!(
                    c[i].to_bits(),
                    want[i].to_bits(),
                    "({m},{k},{n}) idx {i}: {} vs {}",
                    c[i],
                    want[i]
                );
            }
        }
    }

    /// Smoke version of the acceptance contract (the full shape x
    /// geometry sweep lives in `tests/batched_vs_scalar.rs`): bit-identity
    /// to the scalar oracle for all three strategies at a degenerate and
    /// a block-straddling shape, with degenerate and oversized tiles.
    #[test]
    fn every_path_matches_scalar_reference_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let shapes = [(5, 17, 9), (21, 65, 19)];
        let configs = [
            TileConfig { mc: 3, kc: 5, nc: 2, mr: 2, nr: 3 },
            TileConfig::DEFAULT,
            TileConfig { mc: 256, kc: 512, nc: 256, mr: 16, nr: 16 },
        ];
        for &(m, k, n) in &shapes {
            let mut rng = Pcg32::seeded(2100 + (m * k * n) as u64);
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            for mul in [
                MulKernel::Native,
                MulKernel::Direct(model.as_ref()),
                MulKernel::Lut(AmSim::new(&lut)),
            ] {
                let mut want = vec![0.0f32; m * n];
                gemm_scalar_reference(&mul, &a, &b, &mut want, m, k, n);
                let mut got = vec![0.0f32; m * n];
                gemm_panel(&mul, &a, &b, &mut got, m, k, n);
                assert_bits_eq(&got, &want, &format!("panel {} ({m},{k},{n})", mul.describe()));
                for cfg in configs {
                    gemm_tiled_with(&mul, cfg, &a, &b, &mut got, m, k, n, 1);
                    assert_bits_eq(
                        &got,
                        &want,
                        &format!("tiled {cfg:?} {} ({m},{k},{n})", mul.describe()),
                    );
                }
            }
        }
    }

    fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
        for i in 0..got.len() {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "{what} idx {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn lut_and_direct_agree_bitwise() {
        // ATxG and ATxC must produce identical numbers — the paper's own
        // validation methodology (§VI footnote 2).
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(22);
        let (m, k, n) = (19, 31, 23);
        let a: Vec<f32> =
            (0..m * k).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let mut c_direct = vec![0.0f32; m * n];
        let mut c_lut = vec![0.0f32; m * n];
        gemm(&MulKernel::Direct(model.as_ref()), &a, &b, &mut c_direct, m, k, n);
        gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_lut, m, k, n);
        for i in 0..m * n {
            assert_eq!(c_direct[i].to_bits(), c_lut[i].to_bits(), "idx {i}");
        }
    }

    #[test]
    fn approx_gemm_is_close_to_exact() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(23);
        let (m, k, n) = (16, 64, 16);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut c_exact = vec![0.0f32; m * n];
        let mut c_approx = vec![0.0f32; m * n];
        gemm(&MulKernel::Native, &a, &b, &mut c_exact, m, k, n);
        gemm(&MulKernel::Lut(AmSim::new(&lut)), &a, &b, &mut c_approx, m, k, n);
        let scale = (k as f32).sqrt();
        for i in 0..m * n {
            assert!(
                (c_exact[i] - c_approx[i]).abs() < 0.05 * scale,
                "idx {i}: {} vs {}",
                c_exact[i],
                c_approx[i]
            );
        }
    }

    #[test]
    fn tiled_pool_matches_single_lane_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(24);
        let (m, k, n) = (37, 41, 29);
        let a: Vec<f32> =
            (0..m * k).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| quantize_mantissa(rng.range(-2.0, 2.0), 7)).collect();
        // a small-tile config so the threaded run has plenty of tiles to
        // race over even on a narrow pool
        let cfg = TileConfig { mc: 8, kc: 16, nc: 8, mr: 3, nr: 5 };
        for mul in [
            MulKernel::Native,
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(AmSim::new(&lut)),
        ] {
            let mut c1 = vec![0.0f32; m * n];
            gemm_tiled_with(&mul, cfg, &a, &b, &mut c1, m, k, n, 1);
            for threads in [2, 3, 8, 64] {
                let mut ct = vec![0.0f32; m * n];
                gemm_tiled_with(&mul, cfg, &a, &b, &mut ct, m, k, n, threads);
                for i in 0..m * n {
                    assert_eq!(
                        c1[i].to_bits(),
                        ct[i].to_bits(),
                        "{} threads={threads} idx {i}",
                        mul.describe()
                    );
                }
            }
        }
    }

    #[test]
    fn panel_pool_matches_single_thread_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(26);
        let (m, k, n) = (37, 41, 29);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mul = MulKernel::Lut(AmSim::new(&lut));
        let mut c1 = vec![0.0f32; m * n];
        gemm_panel_threaded(&mul, &a, &b, &mut c1, m, k, n, 1);
        for threads in [2, 3, 8, 64] {
            let mut ct = vec![0.0f32; m * n];
            gemm_panel_threaded(&mul, &a, &b, &mut ct, m, k, n, threads);
            for i in 0..m * n {
                assert_eq!(c1[i].to_bits(), ct[i].to_bits(), "threads={threads} idx {i}");
            }
        }
    }

    #[test]
    fn auto_matches_fixed_thread_counts() {
        let mut rng = Pcg32::seeded(25);
        // large enough to cross AUTO_THREAD_MACS with k=96
        let (m, k, n) = (72, 96, 72);
        let a: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut c_auto = vec![0.0f32; m * n];
        let mut c_one = vec![0.0f32; m * n];
        gemm_auto(&MulKernel::Native, &a, &b, &mut c_auto, m, k, n);
        gemm(&MulKernel::Native, &a, &b, &mut c_one, m, k, n);
        for i in 0..m * n {
            assert_eq!(c_auto[i].to_bits(), c_one[i].to_bits(), "idx {i}");
        }
    }

    /// A logical `A` computed on the fly must behave exactly like a
    /// `SliceA` over its materialization — the implicit-GEMM guarantee at
    /// the GEMM level, independent of the im2col sources.
    #[test]
    fn computed_panel_source_matches_materialized_slice_bitwise() {
        struct Gen {
            k: usize,
        }
        impl PackA for Gen {
            fn pack_a(&self, i0: usize, ih: usize, k0: usize, kw: usize, out: &mut [f32]) {
                for i in 0..ih {
                    for kk in 0..kw {
                        out[i * kw + kk] =
                            ((i0 + i) * self.k + k0 + kk) as f32 * 0.37 - 2.1;
                    }
                }
            }
        }
        let (m, k, n) = (23, 39, 17);
        let gen = Gen { k };
        let mut a = vec![0.0f32; m * k];
        gen.pack_a(0, m, 0, k, &mut a);
        let mut rng = Pcg32::seeded(27);
        let b = rand_vec(&mut rng, k * n);
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        for mul in [
            MulKernel::Native,
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(AmSim::new(&lut)),
        ] {
            let mut want = vec![0.0f32; m * n];
            gemm_scalar_reference(&mul, &a, &b, &mut want, m, k, n);
            for cfg in [TileConfig { mc: 5, kc: 7, nc: 4, mr: 2, nr: 2 }, TileConfig::DEFAULT] {
                for threads in [1, 4] {
                    let mut got = vec![0.0f32; m * n];
                    gemm_tiled_src(
                        &mul,
                        cfg,
                        &gen,
                        &SliceB { data: &b, n },
                        &mut got,
                        m,
                        k,
                        n,
                        threads,
                    );
                    assert_bits_eq(
                        &got,
                        &want,
                        &format!("src {cfg:?} t={threads} {}", mul.describe()),
                    );
                }
            }
        }
    }

    /// The `nr`-strip interleaved `pack_b` layout: element (kk, c) of
    /// strip s sits at `s*nr*kw + kk*w + c`, and `nr = 1` reproduces the
    /// old transposed column-major layout exactly.
    #[test]
    fn slice_b_packs_the_interleaved_strip_layout() {
        let (k, n) = (7usize, 13usize);
        let b: Vec<f32> = (0..k * n).map(|i| i as f32).collect();
        let src = SliceB { data: &b, n };
        for (j0, jw, k0, kw) in [(0usize, n, 0usize, k), (3, 9, 2, 4), (11, 2, 0, 7)] {
            for nr in [1usize, 3, 8, 16] {
                let mut out = vec![-1.0f32; jw * kw];
                src.pack_b(j0, jw, k0, kw, nr, &mut out);
                let mut base = 0;
                let mut j = 0;
                while j < jw {
                    let w = nr.min(jw - j);
                    for kk in 0..kw {
                        for c in 0..w {
                            assert_eq!(
                                out[base + kk * w + c],
                                b[(k0 + kk) * n + j0 + j + c],
                                "nr={nr} window ({j0},{jw},{k0},{kw}) strip at {j}"
                            );
                        }
                    }
                    base += w * kw;
                    j += w;
                }
                if nr == 1 {
                    // degenerate check against the old layout formula
                    for jj in 0..jw {
                        for kk in 0..kw {
                            assert_eq!(out[jj * kw + kk], b[(k0 + kk) * n + j0 + jj]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn warm_tiled_is_idempotent() {
        warm_tiled();
        warm_tiled();
    }

    /// The pack-then-scan defaults: group/strip bits follow exactly the
    /// documented `mr`-row-group / `nr`-strip geometry, `±0.0` is dead,
    /// NaN is live.
    #[test]
    fn scan_helpers_mark_live_groups_and_strips() {
        // A panel: 7 rows x 3 cols, mr = 2 -> groups {0,1},{2,3},{4,5},{6}
        let mut a = vec![0.0f32; 7 * 3];
        a[1 * 3 + 2] = 1.5; // group 0 live via row 1
        a[5 * 3] = -0.0; // negative zero stays dead
        a[6 * 3 + 1] = f32::NAN; // NaN is live (conservative)
        let mut occ = Occupancy::default();
        scan_packed_a(&a, 7, 3, 2, &mut occ);
        assert_eq!(occ.panels(), 4);
        assert!(occ.get(0) && !occ.get(1) && !occ.get(2) && occ.get(3));
        assert_eq!(occ.live(), 2);

        // B panel: jw = 5, kw = 2, nr = 2 -> strips of width 2,2,1 at
        // interleaved offsets 0, 4, 8
        let mut b = vec![0.0f32; 5 * 2];
        b[4 + 3] = 2.0; // strip 1 live
        scan_packed_b(&b, 5, 2, 2, &mut occ);
        assert_eq!(occ.panels(), 3);
        assert!(!occ.get(0) && occ.get(1) && !occ.get(2));

        // and via the trait defaults on materialized slices: identical
        let mut packed = vec![0.0f32; 7 * 3];
        let mut occ2 = Occupancy::default();
        SliceA { data: &a, k: 3 }.pack_a_occ(0, 7, 0, 3, 2, &mut packed, &mut occ2);
        for g in 0..4 {
            assert_eq!(occ2.get(g), [true, false, false, true][g], "group {g}");
        }
    }

    /// Zero-skipping smoke (the full occupancy-residue × sparsity ×
    /// SIMD × threads net lives in `tests/sparse_gemm.rs`): with
    /// structured-sparse operands, every gated strategy stays
    /// bit-identical to the dense scalar oracle, and the dense-fallback
    /// native path is untouched by construction.
    #[test]
    fn zero_skipping_matches_dense_oracle_bitwise() {
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let (m, k, n) = (21, 37, 27);
        let mut rng = Pcg32::seeded(31);
        let mut a = rand_vec(&mut rng, m * k);
        let mut b = rand_vec(&mut rng, k * n);
        // kill whole A rows and whole B columns (structured sparsity —
        // what magnitude-pruned row/column blocks look like to the packer)
        for i in [0, 1, 2, 3, 9, 20] {
            a[i * k..(i + 1) * k].fill(0.0);
        }
        for j in [4, 5, 6, 7, 8, 9, 10, 11, 26] {
            for kk in 0..k {
                b[kk * n + j] = 0.0;
            }
        }
        let cfg = TileConfig { mc: 8, kc: 16, nc: 8, mr: 2, nr: 4 };
        for mul in [
            MulKernel::Native, // dense fallback, still bit-exact
            MulKernel::Direct(model.as_ref()),
            MulKernel::Lut(AmSim::new(&lut)),
        ] {
            let mut want = vec![0.0f32; m * n];
            gemm_scalar_reference(&mul, &a, &b, &mut want, m, k, n);
            for threads in [1, 4] {
                let mut got = vec![0.0f32; m * n];
                gemm_tiled_with(&mul, cfg, &a, &b, &mut got, m, k, n, threads);
                assert_bits_eq(&got, &want, &format!("sparse {} t={threads}", mul.describe()));
            }
        }
    }

    #[test]
    fn empty_dims() {
        let mut c = vec![0.0f32; 0];
        gemm(&MulKernel::Native, &[], &[], &mut c, 0, 5, 0);
        gemm_panel(&MulKernel::Native, &[], &[], &mut c, 0, 5, 0);
        gemm_scalar_reference(&MulKernel::Native, &[], &[], &mut c, 0, 5, 0);
        gemm_tiled_with(&MulKernel::Native, TileConfig::DEFAULT, &[], &[], &mut c, 0, 5, 0, 4);
    }
}
