"""AOT artifact emitter — lowers every (model x phase x mode) computation
plus the standalone GEMM/matvec benchmark kernels to HLO **text** under
``artifacts/``, and writes ``manifest.json`` describing each artifact's
positional signature (names, roles, shapes, dtypes, init metadata).

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never runs after that.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import lutgen, mults
from .kernels.amsim_gemm import am_gemm
from .kernels.amsim_matvec import am_matvec
from .layers import MulCfg
from .models import lenet, resnet
from .train import make_forward, make_train_step

LUT_LEN = 1 << 14  # m = 7


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def tensor_meta(name, role, shape, dtype="f32", **extra):
    d = {"name": name, "role": role, "shape": list(shape), "dtype": dtype}
    d.update(extra)
    return d


# The four system configurations of Tables V/VI:
#   tf     -> TFnG analog (stock XLA ops, native multiplier)
#   custom -> ATnG (custom Pallas kernels, native multiplier)
#   lut    -> ATxG (custom Pallas kernels, AMSim LUT)
#   direct:afm32 -> the non-tabulatable AFM32 design, in-graph bit math
MODEL_MODES = ["tf", "custom", "lut", "direct:afm32"]


def model_catalog(tiny: bool):
    """(manifest_model_name, model, batch) triples. `tiny` shrinks batch
    sizes for quick CI runs."""
    b1 = 32 if tiny else 64
    b2 = 16 if tiny else 32
    b3 = 8 if tiny else 16
    return [
        ("lenet300", lenet.lenet300((28, 28, 1), 10), b1),
        ("lenet5", lenet.lenet5((28, 28, 1), 10), b1),
        ("resnet18", resnet.resnet18((16, 16, 3), 10, width=8), b2),
        ("resnet34", resnet.resnet34((16, 16, 3), 10, width=8), b2),
        ("resnet50", resnet.resnet50((16, 16, 3), 10, width=8), b2),
        ("resnet50i", resnet.resnet50((32, 32, 3), 20, width=8), b3),
    ]


def emit(out_dir, name, lowered, meta, manifest):
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    meta = dict(meta)
    meta.update({"name": name, "file": fname})
    manifest["artifacts"].append(meta)
    print(f"  wrote {fname} ({len(text)} chars)")


def lower_model_artifacts(out_dir, manifest, model_name, model, batch, modes):
    h, w, c = model.input_shape
    param_meta = [
        tensor_meta(s.name, "param", s.shape, init=s.init, fan_in=s.fan_in)
        for s in model.params
    ]
    param_specs = [f32(*s.shape) for s in model.params]
    for mode in modes:
        cfg = MulCfg(mode=mode, m=7)
        tag = mode.replace("direct:", "")
        needs_lut = mode == "lut"
        lut_spec = [u32(LUT_LEN)] if needs_lut else []
        lut_meta = ([tensor_meta("lut", "lut", (LUT_LEN,), "u32")] if needs_lut else [])

        # ---- forward (inference) artifact ----
        fwd = make_forward(model, cfg)

        def fwd_flat(*args, _fwd=fwd, _n=len(model.params), _needs_lut=needs_lut):
            params = list(args[:_n])
            x = args[_n]
            lut = args[_n + 1] if _needs_lut else None
            return _fwd(params, x, lut)

        t0 = time.time()
        lowered = jax.jit(fwd_flat).lower(*param_specs, f32(batch, h, w, c), *lut_spec)
        emit(out_dir, f"{model_name}_fwd_{tag}", lowered, {
            "model": model_name, "phase": "fwd", "mode": mode, "mantissa_bits": 7,
            "batch": batch,
            "inputs": param_meta
                      + [tensor_meta("x", "input", (batch, h, w, c))] + lut_meta,
            "outputs": [tensor_meta("logits", "logits", (batch, model.classes))],
        }, manifest)

        # ---- fused train-step artifact ----
        step = make_train_step(model, cfg)

        def step_flat(*args, _step=step, _n=len(model.params), _needs_lut=needs_lut):
            params = list(args[:_n])
            vels = list(args[_n:2 * _n])
            x, y = args[2 * _n], args[2 * _n + 1]
            lut = args[2 * _n + 2] if _needs_lut else None
            lr = args[-1]
            new_p, new_v, loss, acc = _step(params, vels, x, y, lut, lr)
            return (*new_p, *new_v, loss, acc)

        vel_meta = [
            tensor_meta(f"vel:{s.name}", "velocity", s.shape) for s in model.params
        ]
        lowered = jax.jit(step_flat).lower(
            *param_specs, *param_specs, f32(batch, h, w, c), i32(batch),
            *lut_spec, f32())
        emit(out_dir, f"{model_name}_train_{tag}", lowered, {
            "model": model_name, "phase": "train", "mode": mode, "mantissa_bits": 7,
            "batch": batch,
            "inputs": param_meta + vel_meta
                      + [tensor_meta("x", "input", (batch, h, w, c)),
                         tensor_meta("y", "label", (batch,), "i32")]
                      + lut_meta + [tensor_meta("lr", "hyper", ())],
            "outputs": param_meta + vel_meta
                       + [tensor_meta("loss", "metric", ()),
                          tensor_meta("acc", "metric", ())],
        }, manifest)
        print(f"  [{model_name}/{mode}] lowered in {time.time() - t0:.1f}s")


GEMM_MODES = ["tf", "native", "lut", "direct:afm16", "direct:mit16",
              "direct:realm16", "direct:bfloat16"]


def lower_gemm_artifacts(out_dir, manifest, sizes):
    """Standalone GEMM artifacts for the Fig 6 benchmark."""
    for n in sizes:
        for mode in GEMM_MODES:
            tag = mode.replace("direct:", "d_")
            needs_lut = mode == "lut"

            def gemm_fn(a, b, *rest, _mode=mode):
                if _mode == "tf":
                    return (jnp.dot(a, b),)
                lut = rest[0] if rest else None
                if _mode == "lut":
                    return (am_gemm(a, b, "lut", lut, 7),)
                return (am_gemm(a, b, _mode),)

            specs = [f32(n, n), f32(n, n)] + ([u32(LUT_LEN)] if needs_lut else [])
            lowered = jax.jit(gemm_fn).lower(*specs)
            lut_meta = ([tensor_meta("lut", "lut", (LUT_LEN,), "u32")]
                        if needs_lut else [])
            emit(out_dir, f"gemm{n}_{tag}", lowered, {
                "model": f"gemm{n}", "phase": "gemm", "mode": mode,
                "mantissa_bits": 7,
                "inputs": [tensor_meta("a", "input", (n, n)),
                           tensor_meta("b", "input", (n, n))] + lut_meta,
                "outputs": [tensor_meta("c", "logits", (n, n))],
            }, manifest)


def lower_matvec_artifact(out_dir, manifest, n_in=784, n_out=300):
    """Matrix-vector kernel artifact (paper §VI-C dense-layer kernel),
    used by the single-request path of the serving example."""
    def mv(w, x, lut):
        return (am_matvec(w, x, "lut", lut, 7),)

    lowered = jax.jit(mv).lower(f32(n_out, n_in), f32(n_in), u32(LUT_LEN))
    emit(out_dir, f"matvec{n_out}x{n_in}_lut", lowered, {
        "model": f"matvec{n_out}x{n_in}", "phase": "matvec", "mode": "lut",
        "mantissa_bits": 7,
        "inputs": [tensor_meta("w", "input", (n_out, n_in)),
                   tensor_meta("x", "input", (n_in,)),
                   tensor_meta("lut", "lut", (LUT_LEN,), "u32")],
        "outputs": [tensor_meta("y", "logits", (n_out,))],
    }, manifest)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="substring filter on model names")
    ap.add_argument("--tiny", action="store_true", help="smaller batches")
    ap.add_argument("--gemm-sizes", nargs="*", type=int, default=[128, 256, 512])
    ap.add_argument("--merge", action="store_true",
                    help="update entries in an existing manifest instead of replacing it")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}
    prior = []
    manifest_path = os.path.join(args.out, "manifest.json")
    if args.merge and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            prior = json.load(f)["artifacts"]

    # mantissa LUTs for every tabulatable multiplier
    lut_dir = os.path.join(args.out, "luts")
    os.makedirs(lut_dir, exist_ok=True)
    for mname in mults.LUT_ABLE:
        lutgen.write_lut(mults.by_name(mname), os.path.join(lut_dir, f"{mname}.lut"))
    print(f"wrote {len(mults.LUT_ABLE)} LUTs to {lut_dir}")

    if not args.only or "gemm" in args.only:
        lower_gemm_artifacts(args.out, manifest, args.gemm_sizes)
        lower_matvec_artifact(args.out, manifest)

    for model_name, model, batch in model_catalog(args.tiny):
        if args.only and args.only not in model_name and args.only != "models":
            continue
        print(f"lowering {model_name} (batch {batch}) ...")
        lower_model_artifacts(args.out, manifest, model_name, model, batch,
                              MODEL_MODES)

    if prior:
        fresh = {a["name"] for a in manifest["artifacts"]}
        manifest["artifacts"] = [a for a in prior if a["name"] not in fresh] \
            + manifest["artifacts"]
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
