//! Small self-contained utilities: deterministic RNG, a minimal JSON
//! parser/emitter (no external deps are available offline), statistics,
//! timing, a scoped thread-pool helper, and runtime SIMD-level
//! detection/override.
pub mod json;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod threads;
pub mod timer;

/// Round `v` up to the next multiple of `m` (m > 0).
pub fn round_up(v: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    v.div_ceil(m) * m
}

/// Human-readable duration, paper-table style ("3 ms", "23 s").
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.2} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn fmt_duration_picks_unit() {
        assert_eq!(fmt_duration(2.0), "2.00 s");
        assert_eq!(fmt_duration(0.002), "2.00 ms");
        assert_eq!(fmt_duration(0.000002), "2.00 us");
    }
}
