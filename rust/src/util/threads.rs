//! Persistent worker pool + data-parallel helpers.
//!
//! The paper's CUDA kernels get their throughput from fine-grained GPU
//! parallelism; on the CPU substrate the analogous lever is chunked
//! multi-threading. Earlier revisions spawned a fresh `std::thread::scope`
//! per GEMM call, which put thread create/join on the per-layer hot path —
//! exactly the kind of per-call overhead the paper's AMSim design
//! amortizes away. The [`ThreadPool`] here is spawned once (see
//! [`global`]) and reused across every GEMM/kernel invocation by the
//! trainer and the batching server.
//!
//! (The benchmark machine for this reproduction exposes very few cores, so
//! `available_threads()` frequently returns 1 or 2 and the pool degrades
//! to inline loops — the multi-worker path is still exercised by tests
//! with explicit thread counts.)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Number of worker threads to use by default.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A `*mut f32` that can be captured by `Sync` closures. The caller is
/// responsible for ensuring every concurrent access lands on a disjoint
/// region — the pattern used by all kernel loops (one output row-range per
/// chunk).
#[derive(Clone, Copy)]
pub struct SendMutPtr(pub *mut f32);

// SAFETY: moving the wrapper between threads moves only the pointer value;
// a raw pointer carries no aliasing claim by itself, and every kernel hands
// each worker a disjoint output range, so no two threads dereference
// overlapping targets.
unsafe impl Send for SendMutPtr {}
// SAFETY: sharing `&SendMutPtr` is sound for the same reason — the pointer
// is `Copy`, and all concurrent writes through copies land on disjoint
// per-chunk offsets (the `run_chunks` contract).
unsafe impl Sync for SendMutPtr {}

/// One fan-out/fan-in unit of work: a borrowed closure plus an atomic
/// chunk cursor. The closure reference is lifetime-erased; soundness comes
/// from [`ThreadPool::run_chunks`] not returning (or unwinding) until
/// `pending` hits 0, so no worker can observe the closure after the
/// caller's frame ends. [`execute`] never unwinds — a panicking chunk is
/// caught, recorded, and re-raised by the submitting thread *after* the
/// completion wait, mirroring `std::thread::scope` semantics.
struct JobState {
    func: &'static (dyn Fn(usize, usize, usize) + Sync),
    items: usize,
    chunk: usize,
    chunks: usize,
    next: AtomicUsize,
    pending: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    done: Mutex<bool>,
    cv: Condvar,
}

type Job = Arc<JobState>;

fn execute(job: &JobState) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.chunks {
            return;
        }
        let start = i * job.chunk;
        let end = ((i + 1) * job.chunk).min(job.items);
        // AssertUnwindSafe: on Err the payload is stashed and re-raised on
        // the submitting thread once every chunk has finished, so no one
        // observes the possibly-inconsistent captures in the meantime.
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.func)(i, start, end)));
        if let Err(payload) = result {
            let mut slot = job.panic.lock().unwrap();
            slot.get_or_insert(payload); // keep the first panic
        }
        // decremented on the panic path too — a poisoned chunk must never
        // leave the submitter waiting forever or shrink the pool
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            *job.done.lock().unwrap() = true;
            job.cv.notify_all();
        }
    }
}

/// Persistent worker pool. Jobs are broadcast to every worker; workers and
/// the submitting thread race over an atomic chunk cursor, so the fastest
/// threads naturally take more chunks (the CUDA-grid work-stealing analog).
pub struct ThreadPool {
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool with `threads` total execution lanes: `threads - 1` spawned
    /// workers plus the submitting thread, which always participates.
    pub fn new(threads: usize) -> ThreadPool {
        let workers = threads.max(1) - 1;
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("amsim-pool-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            execute(&job);
                        }
                    })
                    .expect("spawning pool worker"),
            );
        }
        ThreadPool { senders, handles }
    }

    /// Total execution lanes (spawned workers + the submitting thread).
    pub fn width(&self) -> usize {
        self.handles.len() + 1
    }

    /// Run `f(task_index)` once for each of `tasks` indices, blocking
    /// until all complete. A degenerate [`run_chunks`](Self::run_chunks)
    /// with chunk size 1: every lane races the shared cursor for whole
    /// task indices, so the *assignment* of tasks to threads is
    /// schedule-dependent but the set of tasks (and anything keyed only
    /// on the task index, like the data-parallel trainer's worker
    /// shares) is not. Panic semantics are those of `run_chunks`.
    pub fn run_tasks<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_chunks(tasks, tasks, |i, start, end| {
            debug_assert_eq!((start, end), (i, i + 1));
            f(i)
        });
    }

    /// Split `items` into up to `chunks` contiguous ranges and run
    /// `f(chunk_index, start, end)` over them, blocking until all chunks
    /// complete. The submitting thread executes chunks too, so the call
    /// makes progress even when every worker is busy (nested submissions
    /// included). `f` only needs to borrow from the caller's frame.
    pub fn run_chunks<F>(&self, items: usize, chunks: usize, f: F)
    where
        F: Fn(usize, usize, usize) + Sync,
    {
        if items == 0 {
            return;
        }
        let chunk = items.div_ceil(chunks.max(1));
        let chunks = items.div_ceil(chunk);
        if chunks == 1 || self.handles.is_empty() {
            for i in 0..chunks {
                f(i, i * chunk, ((i + 1) * chunk).min(items));
            }
            return;
        }
        let f_ref: &(dyn Fn(usize, usize, usize) + Sync) = &f;
        // SAFETY: lifetime erasure only. `execute` never unwinds (chunk
        // panics are caught and stashed), so the wait loop below always
        // runs and guarantees the reference is dead — no worker can still
        // be inside `f` — before this frame returns or unwinds.
        let f_static: &'static (dyn Fn(usize, usize, usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        let job: Job = Arc::new(JobState {
            func: f_static,
            items,
            chunk,
            chunks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(chunks),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            cv: Condvar::new(),
        });
        for tx in &self.senders {
            // a worker that exited (only possible at teardown) just means
            // fewer lanes; the cursor still drains via the caller
            let _ = tx.send(job.clone());
        }
        execute(&job);
        let mut finished = job.done.lock().unwrap();
        while !*finished {
            finished = job.cv.wait(finished).unwrap();
        }
        drop(finished);
        // every chunk is done; re-raise the first chunk panic on the
        // submitting thread (std::thread::scope behaviour)
        if let Some(payload) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels; workers exit their recv loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-wide pool, spawned on first use with [`available_threads`]
/// lanes. The trainer and the batching server touch this at startup so
/// worker spawn cost never lands inside a timed step (see
/// `coordinator::trainer` / `coordinator::server`).
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(available_threads()))
}

/// Run `f(chunk_index, start, end)` over `n` items split into `threads`
/// contiguous ranges on the global pool. `f` must be `Sync` since it is
/// shared across threads.
pub fn parallel_ranges<F: Fn(usize, usize, usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    global().run_chunks(n, threads, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranges_cover_everything_once() {
        for threads in [1, 2, 3, 7] {
            let hits = AtomicUsize::new(0);
            parallel_ranges(100, threads, |_, s, e| {
                hits.fetch_add(e - s, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), 100, "threads={threads}");
        }
    }

    #[test]
    fn ranges_handle_zero() {
        parallel_ranges(0, 4, |_, s, e| assert_eq!(s, e));
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        // the point of the persistent pool: many cheap jobs, no per-job
        // thread spawning
        let pool = ThreadPool::new(3);
        assert_eq!(pool.width(), 3);
        let total = AtomicUsize::new(0);
        for round in 1..=50usize {
            pool.run_chunks(round, 3, |_, s, e| {
                total.fetch_add(e - s, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), (1..=50).sum::<usize>());
    }

    #[test]
    fn pool_handles_more_chunks_than_workers() {
        let pool = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        pool.run_chunks(97, 13, |_, s, e| {
            hits.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 97);
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        let pool = global();
        let total = AtomicUsize::new(0);
        pool.run_chunks(4, 4, |_, s, e| {
            for _ in s..e {
                // a chunk that itself fans out (layer calling a threaded
                // GEMM): the submitting lane participates, so this always
                // drains even when all workers are busy
                pool.run_chunks(8, 2, |_, s2, e2| {
                    total.fetch_add(e2 - s2, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks(8, 4, |i, _, _| {
                if i == 2 {
                    panic!("chunk 2 boom");
                }
            });
        }));
        // the panic reaches the submitting thread (scope semantics)...
        assert!(result.is_err());
        // ...and the pool neither deadlocks nor loses lanes
        assert_eq!(pool.width(), 3);
        let hits = AtomicUsize::new(0);
        pool.run_chunks(10, 4, |_, s, e| {
            hits.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn run_tasks_visits_every_index_once() {
        let pool = ThreadPool::new(3);
        let seen: Vec<AtomicUsize> = (0..17).map(|_| AtomicUsize::new(0)).collect();
        pool.run_tasks(17, |i| {
            seen[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, s) in seen.iter().enumerate() {
            assert_eq!(s.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run_chunks(10, 4, |_, s, e| {
            hits.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }
}
