"""IEEE-754 single-precision bit plumbing — Python mirror of
``rust/src/mult/fpbits.rs``. Used at build time only (LUT generation, kernel
oracles, pytest); never on the request path.

All helpers operate on numpy ``uint32`` arrays (or scalars) so that the LUT
generator and the jnp kernel reference share exact integer semantics with
the Rust implementation.
"""

from __future__ import annotations

import numpy as np

SIGN_MASK = np.uint32(0x8000_0000)
EXP_MASK = np.uint32(0x7F80_0000)
MANT_MASK = np.uint32(0x007F_FFFF)
EXP_BIAS = 127
MANT_BITS = 23


def to_bits(x) -> np.ndarray:
    """f32 -> u32 bit pattern."""
    return np.asarray(x, dtype=np.float32).view(np.uint32)


def from_bits(b) -> np.ndarray:
    """u32 bit pattern -> f32."""
    return np.asarray(b, dtype=np.uint32).view(np.float32)


def decompose(x):
    """Return (sign, biased_exp, mantissa23) as uint32 arrays."""
    b = to_bits(x)
    return b >> np.uint32(31), (b & EXP_MASK) >> np.uint32(MANT_BITS), b & MANT_MASK


def compose(sign, exp, mant):
    s = np.asarray(sign, dtype=np.uint32)
    e = np.asarray(exp, dtype=np.uint32)
    m = np.asarray(mant, dtype=np.uint32)
    return from_bits((s << np.uint32(31)) | (e << np.uint32(MANT_BITS)) | m)


def quantize_mantissa(x, m: int):
    """Round-to-nearest-even quantization of the mantissa to ``m`` bits —
    mirror of ``fpbits::quantize_mantissa`` (subnormals flush to zero,
    rounding carry propagates into the exponent, overflow to inf)."""
    assert 1 <= m <= MANT_BITS
    x = np.asarray(x, dtype=np.float32)
    if m == MANT_BITS:
        # still flush subnormals for consistency
        sign, exp, mant = decompose(x)
        flush = (exp == 0) & np.isfinite(x)
        out = np.where(flush, compose(sign, 0, 0), x)
        return out.astype(np.float32)
    sign, exp, mant = decompose(x)
    drop = MANT_BITS - m
    half = np.uint32(1 << (drop - 1))
    low = mant & np.uint32((1 << drop) - 1)
    kept = (mant >> np.uint32(drop)).astype(np.uint64)
    round_up = (low > half) | ((low == half) & ((kept & 1) == 1))
    kept = kept + round_up.astype(np.uint64)
    overflow = (kept >> np.uint64(m)) != 0
    kept = np.where(overflow, np.uint64(0), kept)
    exp = exp.astype(np.int64) + overflow.astype(np.int64)
    to_inf = exp >= 255
    result = compose(sign, np.where(to_inf, 255, exp).astype(np.uint32),
                     np.where(to_inf, 0, (kept << np.uint64(drop))).astype(np.uint32))
    # zeros, subnormals -> signed zero; non-finite pass through
    sign0, exp0, _ = decompose(x)
    flush = exp0 == 0
    result = np.where(flush, compose(sign0, 0, 0), result)
    nonfinite = ~np.isfinite(x)
    result = np.where(nonfinite, x, result)
    return result.astype(np.float32)
