//! Cross-cutting property tests over the multiplier/AMSim substrate
//! (hand-rolled `util::prop` harness; no proptest offline).

use approxtrain::amsim::AmSim;
use approxtrain::lut::MantissaLut;
use approxtrain::mult::fpbits::quantize_mantissa;
use approxtrain::mult::registry;
use approxtrain::util::prop::for_all;

const M7_DESIGNS: [&str; 6] = ["bfloat16", "afm16", "mit16", "realm16", "trunc16", "comp16"];

/// All implemented mantissa approximations are symmetric in their
/// operands, so the full multiply must commute (bitwise).
#[test]
fn multiplication_commutes() {
    for name in M7_DESIGNS {
        let model = registry::by_name(name).unwrap();
        for_all(
            &format!("commute-{name}"),
            7,
            5000,
            |r| (quantize_mantissa(r.finite_f32(), 7), quantize_mantissa(r.finite_f32(), 7)),
            |&(a, b)| {
                let ab = model.mul(a, b);
                let ba = model.mul(b, a);
                if ab.to_bits() == ba.to_bits() {
                    Ok(())
                } else {
                    Err(format!("{a}*{b} = {ab} but {b}*{a} = {ba}"))
                }
            },
        );
    }
}

/// Scaling an operand by a power of two only touches the exponent, which
/// every design computes exactly: amsim(2a, b) == 2 * amsim(a, b)
/// (whenever neither side over/underflows).
#[test]
fn power_of_two_scale_invariance() {
    for name in M7_DESIGNS {
        let model = registry::by_name(name).unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        for_all(
            &format!("pow2-scale-{name}"),
            8,
            5000,
            |r| {
                (
                    quantize_mantissa(r.range(-100.0, 100.0), 7),
                    quantize_mantissa(r.range(-100.0, 100.0), 7),
                )
            },
            |&(a, b)| {
                let base = sim.mul(a, b);
                let scaled = sim.mul(2.0 * a, b);
                if base == 0.0 || !base.is_finite() || !scaled.is_finite() {
                    return Ok(()); // flush/overflow edge excluded by contract
                }
                if (2.0 * base).to_bits() == scaled.to_bits() {
                    Ok(())
                } else {
                    Err(format!("2*({a}*{b}) = {} but (2{a})*{b} = {scaled}", 2.0 * base))
                }
            },
        );
    }
}

/// Sign algebra: amsim(-a, b) == -amsim(a, b) exactly (sign is XOR'd).
#[test]
fn sign_antisymmetry() {
    for name in M7_DESIGNS {
        let model = registry::by_name(name).unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        for_all(
            &format!("sign-{name}"),
            9,
            5000,
            |r| (quantize_mantissa(r.finite_f32(), 7), quantize_mantissa(r.finite_f32(), 7)),
            |&(a, b)| {
                let pos = sim.mul(a, b);
                let neg = sim.mul(-a, b);
                // AMSim flushes to unsigned zero, so compare magnitudes +
                // sign only for non-zero results
                if pos == 0.0 && neg == 0.0 {
                    return Ok(());
                }
                if (-pos).to_bits() == neg.to_bits() {
                    Ok(())
                } else {
                    Err(format!("-({a}*{b}) = {} but (-{a})*{b} = {neg}", -pos))
                }
            },
        );
    }
}

/// Monotonicity on positive operands: if 0 < a1 < a2 then
/// amsim(a1, b) <= amsim(a2, b) for fixed positive b. Holds for designs
/// whose mantissa product is monotone in each operand — sums, truncated
/// products, monotone corrections. `comp16` is deliberately excluded: its
/// bitwise-AND compensation term is non-monotone (e.g. 81.5*11.8125 >
/// 96*11.8125 under comp16), a real hazard of AND-compensated designs
/// that this suite documents.
#[test]
fn monotone_in_positive_operand() {
    for name in ["bfloat16", "afm16", "mit16", "realm16", "trunc16"] {
        let model = registry::by_name(name).unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let sim = AmSim::new(&lut);
        for_all(
            &format!("monotone-{name}"),
            10,
            3000,
            |r| {
                let a1 = quantize_mantissa(r.range(0.1, 100.0), 7);
                let a2 = quantize_mantissa(a1 * r.range(1.0, 4.0), 7);
                let b = quantize_mantissa(r.range(0.1, 100.0), 7);
                (a1, a2.max(a1), b)
            },
            |&(a1, a2, b)| {
                let y1 = sim.mul(a1, b);
                let y2 = sim.mul(a2, b);
                if y1 <= y2 {
                    Ok(())
                } else {
                    Err(format!("{a1}*{b} = {y1} > {a2}*{b} = {y2}"))
                }
            },
        );
    }
}

/// LUT round-trip through bytes is the identity for every design.
#[test]
fn lut_serialization_identity() {
    for name in M7_DESIGNS {
        let model = registry::by_name(name).unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let back = MantissaLut::from_bytes(&lut.to_bytes()).unwrap();
        assert_eq!(lut, back, "{name}");
    }
}
