//! Pure-Rust ResNet executor — completes the ATxC ("CPU direct
//! simulation") system of Tables V/VI for the ResNet rows, so no column
//! needs extrapolation. Forward and full backward (conv, batchnorm with
//! batch statistics, residual adds, pooling), every multiply routed
//! through a [`MulKernel`].
//!
//! Mirrors `python/compile/models/resnet.py`: basic blocks for 18/34,
//! bottlenecks for 50, `width` scaling knob, NHWC layout.

use std::collections::BTreeMap;

use crate::kernels::pool::{global_avgpool, global_avgpool_backward};
use crate::kernels::MulKernel;
use crate::layers::activations::{relu, relu_backward};
use crate::layers::softmax::cross_entropy_sum_with_grad;
use crate::layers::{amconv2d, amdense, batchnorm};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Architecture spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Depth {
    R18,
    R34,
    R50,
}

impl Depth {
    pub fn stages(&self) -> &'static [usize] {
        match self {
            Depth::R18 => &[2, 2, 2, 2],
            Depth::R34 | Depth::R50 => &[3, 4, 6, 3],
        }
    }
    pub fn bottleneck(&self) -> bool {
        matches!(self, Depth::R50)
    }
}

/// One conv + BN unit's parameters.
#[derive(Clone)]
struct ConvBn {
    w: Tensor,
    gamma: Tensor,
    beta: Tensor,
}

/// A ResNet with named parameters (mirrors the manifest naming).
#[derive(Clone)]
pub struct CpuResnet {
    pub depth: Depth,
    pub width: usize,
    pub input: (usize, usize, usize),
    pub classes: usize,
    units: BTreeMap<String, ConvBn>,
    fc_w: Tensor,
    fc_b: Tensor,
}

fn he(shape: &[usize], fan_in: usize, rng: &mut Pcg32) -> Tensor {
    let std = (2.0 / fan_in as f32).sqrt();
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| std * rng.normal()).collect())
}

impl CpuResnet {
    pub fn init(depth: Depth, input: (usize, usize, usize), classes: usize, width: usize,
                seed: u64) -> CpuResnet {
        let mut rng = Pcg32::new(seed, 0x2E5);
        let mut units = BTreeMap::new();
        let mut add_unit = |name: &str, kh: usize, c_in: usize, c_out: usize,
                            rng: &mut Pcg32| {
            units.insert(
                name.to_string(),
                ConvBn {
                    w: he(&[kh, kh, c_in, c_out], kh * kh * c_in, rng),
                    gamma: Tensor::filled(&[c_out], 1.0),
                    beta: Tensor::zeros(&[c_out]),
                },
            );
        };
        add_unit("stem", 3, input.2, width, &mut rng);
        let mut c_in = width;
        for (si, &n_blocks) in depth.stages().iter().enumerate() {
            let c_stage = width * (1 << si);
            for bi in 0..n_blocks {
                let p = format!("s{si}b{bi}");
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let c_out = if depth.bottleneck() { 4 * c_stage } else { c_stage };
                if depth.bottleneck() {
                    add_unit(&format!("{p}/c1"), 1, c_in, c_stage, &mut rng);
                    add_unit(&format!("{p}/c2"), 3, c_stage, c_stage, &mut rng);
                    add_unit(&format!("{p}/c3"), 1, c_stage, c_out, &mut rng);
                } else {
                    add_unit(&format!("{p}/c1"), 3, c_in, c_stage, &mut rng);
                    add_unit(&format!("{p}/c2"), 3, c_stage, c_stage, &mut rng);
                }
                if stride != 1 || c_in != c_out {
                    add_unit(&format!("{p}/down"), 1, c_in, c_out, &mut rng);
                }
                c_in = c_out;
            }
        }
        let fc_w = he(&[c_in, classes], c_in, &mut rng);
        CpuResnet {
            depth,
            width,
            input,
            classes,
            units,
            fc_w,
            fc_b: Tensor::zeros(&[classes]),
        }
    }

    fn unit_fwd(
        &self,
        mul: &MulKernel,
        name: &str,
        x: &Tensor,
        stride: usize,
        pad: usize,
    ) -> Tensor {
        let u = &self.units[name];
        let y = amconv2d::forward(mul, x, &u.w, stride, pad);
        let (bn, _, _) = batchnorm::forward(
            &y,
            &u.gamma,
            &u.beta,
        );
        bn
    }

    /// Forward only (inference benchmark path). `x` is NHWC.
    pub fn forward(&self, mul: &MulKernel, x: &Tensor) -> Tensor {
        let mut h = relu(&self.unit_fwd(mul, "stem", x, 1, 1));
        let mut c_in = self.width;
        for (si, &n_blocks) in self.depth.stages().iter().enumerate() {
            let c_stage = self.width * (1 << si);
            for bi in 0..n_blocks {
                let p = format!("s{si}b{bi}");
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let c_out = if self.depth.bottleneck() { 4 * c_stage } else { c_stage };
                let y = if self.depth.bottleneck() {
                    let y = relu(&self.unit_fwd(mul, &format!("{p}/c1"), &h, 1, 0));
                    let y = relu(&self.unit_fwd(mul, &format!("{p}/c2"), &y, stride, 1));
                    self.unit_fwd(mul, &format!("{p}/c3"), &y, 1, 0)
                } else {
                    let y = relu(&self.unit_fwd(mul, &format!("{p}/c1"), &h, stride, 1));
                    self.unit_fwd(mul, &format!("{p}/c2"), &y, 1, 1)
                };
                let skip = if stride != 1 || c_in != c_out {
                    self.unit_fwd(mul, &format!("{p}/down"), &h, stride, 0)
                } else {
                    h.clone()
                };
                let mut sum = y;
                for (s, v) in sum.data.iter_mut().zip(&skip.data) {
                    *s += v;
                }
                h = relu(&sum);
                c_in = c_out;
            }
        }
        let (b, hh, ww, cc) = (h.shape[0], h.shape[1], h.shape[2], h.shape[3]);
        let pooled = Tensor::from_vec(&[b, cc], global_avgpool(&h.data, b, hh, ww, cc));
        amdense::forward(mul, &pooled, &self.fc_w, Some(&self.fc_b))
    }

    /// Total parameter elements in the canonical flat layout: units in
    /// `BTreeMap` name order (`w`, `gamma`, `beta` per unit), then
    /// `fc_w`, `fc_b`.
    pub fn param_count(&self) -> usize {
        self.units
            .values()
            .map(|u| u.w.data.len() + u.gamma.data.len() + u.beta.data.len())
            .sum::<usize>()
            + self.fc_w.data.len()
            + self.fc_b.data.len()
    }

    /// Start offset of each unit's `w` within the flat layout; the fc
    /// head sits after every unit.
    fn unit_offsets(&self) -> (BTreeMap<String, usize>, usize) {
        let mut map = BTreeMap::new();
        let mut off = 0usize;
        for (name, u) in &self.units {
            map.insert(name.clone(), off);
            off += u.w.data.len() + u.gamma.data.len() + u.beta.data.len();
        }
        (map, off)
    }

    /// Snapshot every parameter into one flat vector (canonical order).
    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for u in self.units.values() {
            out.extend_from_slice(&u.w.data);
            out.extend_from_slice(&u.gamma.data);
            out.extend_from_slice(&u.beta.data);
        }
        out.extend_from_slice(&self.fc_w.data);
        out.extend_from_slice(&self.fc_b.data);
        out
    }

    /// Walk the canonical layout applying `f(param, flat_value)`.
    fn scatter_flat(&mut self, flat: &[f32], mut f: impl FnMut(&mut f32, f32)) {
        let want = self.param_count();
        assert_eq!(flat.len(), want, "flat vector has {} elements, model has {want}", flat.len());
        let mut off = 0usize;
        let mut apply = |t: &mut Tensor, off: &mut usize, f: &mut dyn FnMut(&mut f32, f32)| {
            for (p, &v) in t.data.iter_mut().zip(&flat[*off..*off + t.data.len()]) {
                f(p, v);
            }
            *off += t.data.len();
        };
        for u in self.units.values_mut() {
            apply(&mut u.w, &mut off, &mut f);
            apply(&mut u.gamma, &mut off, &mut f);
            apply(&mut u.beta, &mut off, &mut f);
        }
        apply(&mut self.fc_w, &mut off, &mut f);
        apply(&mut self.fc_b, &mut off, &mut f);
    }

    /// Overwrite every parameter from a flat vector (canonical order).
    pub fn load_flat(&mut self, flat: &[f32]) {
        self.scatter_flat(flat, |p, v| *p = v);
    }

    /// Plain SGD over a flat gradient: `p -= lr * g` per element.
    pub fn apply_grads(&mut self, flat: &[f32], lr: f32) {
        self.scatter_flat(flat, |p, g| *p -= lr * g);
    }

    /// One full training step (forward + backward + SGD), used by the
    /// Table V ATxC column; exactly [`CpuResnet::grad_step`] +
    /// [`CpuResnet::apply_grads`], so the single-replica path and the
    /// data-parallel path share every float op.
    pub fn train_step(&mut self, mul: &MulKernel, x: &Tensor, labels: &[u32], lr: f32)
                      -> (f32, f32) {
        let b = x.shape[0];
        let (loss_sum, correct, grads) = self.grad_step(mul, x, labels, b);
        self.apply_grads(&grads, lr);
        let inv_b = 1.0 / b as f32;
        (loss_sum * inv_b, correct as f32 * inv_b)
    }

    /// Compute-only step: forward + backward without touching parameters
    /// (`&self` — a panic mid-step can never tear an update). Returns the
    /// loss **sum**, correct **count**, and the flat gradient (canonical
    /// order) with the loss gradient scaled by `1/divisor`. Gradients flow
    /// through every conv/BN/skip; BN uses the standard batch-stats
    /// backward (`layers::batchnorm::backward`), so BN statistics are
    /// computed over *this call's* rows — data-parallel shards therefore
    /// see shard-local batch statistics (see `coordinator::data_parallel`
    /// for why that is still deterministic).
    pub fn grad_step(&self, mul: &MulKernel, x: &Tensor, labels: &[u32], divisor: usize)
                     -> (f32, usize, Vec<f32>) {
        // To bound implementation complexity the backward pass is computed
        // per *unit* via recomputation: forward is run twice, once caching
        // unit inputs. This is the paper-faithful cost model (same kernels
        // dominate), with ~1.3x extra forward arithmetic.
        struct Saved {
            name: String,
            input: Tensor,
            stride: usize,
            pad: usize,
            pre_bn: Tensor,
            mean: Vec<f32>,
            inv_std: Vec<f32>,
        }
        let mut saved: Vec<Saved> = Vec::new();
        let save_fwd = |this: &CpuResnet,
                            mul: &MulKernel,
                            name: &str,
                            x: &Tensor,
                            stride: usize,
                            pad: usize,
                            saved: &mut Vec<Saved>| {
            let u = &this.units[name];
            let pre_bn = amconv2d::forward(mul, x, &u.w, stride, pad);
            let (bn, mean, inv_std) = batchnorm::forward(&pre_bn, &u.gamma, &u.beta);
            saved.push(Saved {
                name: name.to_string(),
                input: x.clone(),
                stride,
                pad,
                pre_bn,
                mean,
                inv_std,
            });
            bn
        };

        // ---- forward with caching ----
        let mut blocks: Vec<(String, usize, usize, usize, Tensor, Tensor, Tensor)> = Vec::new();
        // (prefix, stride, c_in, c_out, block_input, pre_relu_sum, skip)
        let mut h = relu(&save_fwd(self, mul, "stem", x, 1, 1, &mut saved));
        let mut c_in = self.width;
        for (si, &n_blocks) in self.depth.stages().iter().enumerate() {
            let c_stage = self.width * (1 << si);
            for bi in 0..n_blocks {
                let p = format!("s{si}b{bi}");
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let c_out = if self.depth.bottleneck() { 4 * c_stage } else { c_stage };
                let block_in = h.clone();
                let y = if self.depth.bottleneck() {
                    let y = relu(&save_fwd(self, mul, &format!("{p}/c1"), &h, 1, 0, &mut saved));
                    let y = relu(&save_fwd(self, mul, &format!("{p}/c2"), &y, stride, 1,
                                           &mut saved));
                    save_fwd(self, mul, &format!("{p}/c3"), &y, 1, 0, &mut saved)
                } else {
                    let y =
                        relu(&save_fwd(self, mul, &format!("{p}/c1"), &h, stride, 1, &mut saved));
                    save_fwd(self, mul, &format!("{p}/c2"), &y, 1, 1, &mut saved)
                };
                let skip = if stride != 1 || c_in != c_out {
                    save_fwd(self, mul, &format!("{p}/down"), &block_in, stride, 0, &mut saved)
                } else {
                    block_in.clone()
                };
                let mut sum = y;
                for (s, v) in sum.data.iter_mut().zip(&skip.data) {
                    *s += v;
                }
                blocks.push((p, stride, c_in, c_out, block_in, sum.clone(), skip));
                h = relu(&sum);
                c_in = c_out;
            }
        }
        let (b, hh, ww, cc) = (h.shape[0], h.shape[1], h.shape[2], h.shape[3]);
        let pooled = Tensor::from_vec(&[b, cc], global_avgpool(&h.data, b, hh, ww, cc));
        let logits = amdense::forward(mul, &pooled, &self.fc_w, Some(&self.fc_b));
        let (loss_sum, correct, dlogits) = cross_entropy_sum_with_grad(&logits, labels, divisor);

        // ---- backward ----
        let dw_fc = amdense::weight_grad(mul, &pooled, &dlogits);
        let db_fc = amdense::bias_grad(&dlogits);
        let dpooled = amdense::input_grad(mul, &dlogits, &self.fc_w);
        let mut dh = Tensor::from_vec(
            &[b, hh, ww, cc],
            global_avgpool_backward(&dpooled.data, b, hh, ww, cc),
        );

        // gradient application accumulator: (unit name, dW, dgamma, dbeta)
        let mut grads: Vec<(String, Tensor, Tensor, Tensor)> = Vec::new();
        let mut saved_iter: Vec<Saved> = saved; // consumed back-to-front
        let unit_bwd = |this: &CpuResnet,
                            mul: &MulKernel,
                            dy: &Tensor,
                            saved: &mut Vec<Saved>,
                            grads: &mut Vec<(String, Tensor, Tensor, Tensor)>|
         -> Tensor {
            let s = saved.pop().expect("unit stack underflow");
            let u = &this.units[&s.name];
            let (dbn, dgamma, dbeta) =
                batchnorm::backward(dy, &s.pre_bn, &u.gamma, &s.mean, &s.inv_std);
            let dw = amconv2d::weight_grad(mul, &s.input, &dbn, &u.w.shape, s.stride, s.pad);
            let dx = amconv2d::input_grad(mul, &dbn, &u.w, &s.input.shape, s.stride, s.pad);
            grads.push((s.name.clone(), dw, dgamma, dbeta));
            dx
        };

        for (p, stride, c_in_b, c_out_b, block_in, sum, _skip) in blocks.into_iter().rev() {
            // through the post-sum relu
            let dsum = relu_backward(&dh, &sum);
            // skip path
            let dskip = if stride != 1 || c_in_b != c_out_b {
                unit_bwd(self, mul, &dsum, &mut saved_iter, &mut grads)
            } else {
                dsum.clone()
            };
            // main path (reverse order of units); recompute intermediate
            // relu pre-activations from the saved unit stack
            let dmain = if self.depth.bottleneck() {
                let d3 = unit_bwd(self, mul, &dsum, &mut saved_iter, &mut grads);
                // d3 is grad at relu(c2 output); saved top is now c2
                let pre2 = {
                    let s = saved_iter.last().unwrap();
                    let u = &self.units[&s.name];
                    batchnorm::forward(&s.pre_bn, &u.gamma, &u.beta).0
                };
                let d2 = unit_bwd(self, mul, &relu_backward(&d3, &pre2), &mut saved_iter,
                                  &mut grads);
                let pre1 = {
                    let s = saved_iter.last().unwrap();
                    let u = &self.units[&s.name];
                    batchnorm::forward(&s.pre_bn, &u.gamma, &u.beta).0
                };
                unit_bwd(self, mul, &relu_backward(&d2, &pre1), &mut saved_iter, &mut grads)
            } else {
                let d2 = unit_bwd(self, mul, &dsum, &mut saved_iter, &mut grads);
                let pre1 = {
                    let s = saved_iter.last().unwrap();
                    let u = &self.units[&s.name];
                    batchnorm::forward(&s.pre_bn, &u.gamma, &u.beta).0
                };
                unit_bwd(self, mul, &relu_backward(&d2, &pre1), &mut saved_iter, &mut grads)
            };
            dh = Tensor::from_vec(&block_in.shape, dmain.data.clone());
            for (d, s) in dh.data.iter_mut().zip(&dskip.data) {
                *d += s;
            }
        }
        // stem (through its relu)
        let pre_stem = {
            let s = saved_iter.last().unwrap();
            let u = &self.units[&s.name];
            batchnorm::forward(&s.pre_bn, &u.gamma, &u.beta).0
        };
        let dstem = relu_backward(&dh, &pre_stem);
        let _ = unit_bwd(self, mul, &dstem, &mut saved_iter, &mut grads);
        assert!(saved_iter.is_empty(), "unit stack not fully consumed");

        // ---- assemble the canonical flat gradient ----
        let (offsets, fc_off) = self.unit_offsets();
        let mut flat = vec![0.0f32; self.param_count()];
        let mut seen = 0usize;
        for (name, dw, dgamma, dbeta) in grads {
            let mut off = offsets[&name];
            for t in [&dw, &dgamma, &dbeta] {
                flat[off..off + t.data.len()].copy_from_slice(&t.data);
                off += t.data.len();
            }
            seen += 1;
        }
        assert_eq!(seen, self.units.len(), "every unit contributes exactly one gradient");
        let mut off = fc_off;
        flat[off..off + dw_fc.data.len()].copy_from_slice(&dw_fc.data);
        off += dw_fc.data.len();
        flat[off..off + db_fc.data.len()].copy_from_slice(&db_fc.data);
        (loss_sum, correct, flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_all_depths() {
        for depth in [Depth::R18, Depth::R34, Depth::R50] {
            let net = CpuResnet::init(depth, (16, 16, 3), 10, 4, 1);
            let x = Tensor::filled(&[2, 16, 16, 3], 0.5);
            let y = net.forward(&MulKernel::Native, &x);
            assert_eq!(y.shape, vec![2, 10], "{depth:?}");
            assert!(y.data.iter().all(|v| v.is_finite()), "{depth:?}");
        }
    }

    #[test]
    fn train_step_learns_one_batch() {
        let mut net = CpuResnet::init(Depth::R18, (8, 8, 3), 4, 4, 2);
        let mut rng = Pcg32::seeded(3);
        let x = Tensor::from_vec(&[8, 8, 8, 3], (0..8 * 8 * 8 * 3).map(|_| rng.uniform()).collect());
        let labels: Vec<u32> = (0..8).map(|i| i % 4).collect();
        let (l0, _) = net.train_step(&MulKernel::Native, &x, &labels, 0.05);
        let mut last = l0;
        for _ in 0..6 {
            let (l, _) = net.train_step(&MulKernel::Native, &x, &labels, 0.05);
            last = l;
        }
        assert!(last < l0, "loss {l0} -> {last}");
    }

    #[test]
    fn split_step_is_bitwise_train_step_and_flat_roundtrips() {
        let mut rng = Pcg32::seeded(11);
        let x =
            Tensor::from_vec(&[4, 8, 8, 3], (0..4 * 8 * 8 * 3).map(|_| rng.uniform()).collect());
        let labels: Vec<u32> = (0..4).map(|i| i % 4).collect();
        let mul = MulKernel::Native;
        let mut a = CpuResnet::init(Depth::R18, (8, 8, 3), 4, 4, 6);
        let mut b = a.clone();
        let (loss_a, acc_a) = a.train_step(&mul, &x, &labels, 0.05);
        let (loss_sum, correct, grads) = b.grad_step(&mul, &x, &labels, 4);
        assert_eq!(grads.len(), b.param_count());
        b.apply_grads(&grads, 0.05);
        assert_eq!(loss_a.to_bits(), (loss_sum * 0.25).to_bits());
        assert_eq!(acc_a.to_bits(), (correct as f32 * 0.25).to_bits());
        let (fa, fb) = (a.flat_params(), b.flat_params());
        assert_eq!(fa.len(), a.param_count());
        for i in 0..fa.len() {
            assert_eq!(fa[i].to_bits(), fb[i].to_bits(), "param {i}");
        }
        // load_flat overwrites a differently-seeded net completely
        let mut c = CpuResnet::init(Depth::R18, (8, 8, 3), 4, 4, 999);
        c.load_flat(&fa);
        assert_eq!(c.flat_params(), fa);
    }

    #[test]
    fn approx_multiplier_forward_close_to_exact() {
        use crate::amsim::AmSim;
        use crate::lut::MantissaLut;
        use crate::mult::registry;
        let net = CpuResnet::init(Depth::R18, (8, 8, 3), 4, 4, 5);
        let x = Tensor::filled(&[2, 8, 8, 3], 0.3);
        let exact = net.forward(&MulKernel::Native, &x);
        let model = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let approx = net.forward(&MulKernel::Lut(AmSim::new(&lut)), &x);
        // BN renormalizes per layer, so approximate logits stay close
        assert!(exact.max_abs_diff(&approx) < 1.0, "{}", exact.max_abs_diff(&approx));
    }
}
