"""Layer-1 Pallas GEMM kernel with pluggable approximate multiplication —
the reproduction of the paper's custom CUDA GEMM kernel (§VI-D) carrying
the AMSim device function (§V-B).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the CUDA version
stages 16x16 operand tiles in shared memory and reads the LUT through the
texture cache. Here the ``BlockSpec`` grid expresses the HBM<->VMEM
schedule: the grid is (M/bm, N/bn, K/bk), operand blocks of (bm, bk) and
(bk, bn) live in VMEM, the output block is revisited across the K grid
dimension (sequential on TPU/interpret), and the whole mantissa LUT (64 KiB
at m=7) is mapped into VMEM for every grid cell — the scratchpad analog of
the texture cache. The approximate multiply is elementwise integer ALU
work, so it targets the VPU; the ``native`` mode keeps ``jnp.dot`` in the
same schedule so it can use the MXU (the paper's custom-kernel +
native-multiplier midpoint, ATnG).

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is what
the Rust runtime loads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bitmath

# Default VMEM tile sizes: (64 x 64) f32 blocks are 16 KiB each; with the
# (bm, bk, bn) product tensor of the approximate path this stays ~1 MiB,
# far under VMEM. Fig 6's ablation sweeps these.
DEFAULT_BLOCK = (64, 64, 64)

# Budget for the materialized (bm, bk, bn) product block of the elementwise
# modes, in elements (~8 MiB of f32). On a real TPU this would be the VMEM
# ceiling; in interpret mode it bounds working-set size while amortizing the
# per-grid-step overhead of the interpreter (measured ~0.2 ms/step — §Perf).
ELEMWISE_BLOCK_BUDGET = 1 << 21


def pick_block(M: int, K: int, N: int, mode: str) -> tuple:
    """Adaptive block sizes: use as few grid steps as the block-memory
    budget allows. Native mode has no product tensor, so it can take whole
    operands (single grid step) up to a generous cap."""
    if mode in ("native", "custom"):
        # cap operand blocks at ~32 Mi elements
        bm = min(M, max(1, (1 << 25) // max(K, 1)))
        return (_round8(bm), K, N)
    bk = min(K, 512)
    bn = min(N, 512)
    bm = min(M, max(8, ELEMWISE_BLOCK_BUDGET // max(bk * bn, 1)))
    return (_round8(bm), bk, bn)


def _round8(v: int) -> int:
    return max(8, (v // 8) * 8) if v >= 8 else v


def _mul_block(a_blk, b_blk, mode: str, lut, m: int):
    """One (bm, bk) x (bk, bn) block product with FP32 accumulation."""
    if mode == "native":
        return jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)
    if mode == "lut":
        prod = bitmath.amsim_mul(a_blk[:, :, None], b_blk[None, :, :], lut, m)
    elif mode.startswith("direct:"):
        prod = bitmath.direct_mul(a_blk[:, :, None], b_blk[None, :, :],
                                  mode.split(":", 1)[1])
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return jnp.sum(prod, axis=1, dtype=jnp.float32)


def _kernel_lut(a_ref, b_ref, lut_ref, o_ref, *, mode: str, m: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    lut = lut_ref[...]
    o_ref[...] += _mul_block(a_ref[...], b_ref[...], mode, lut, m)


def _kernel_nolut(a_ref, b_ref, o_ref, *, mode: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += _mul_block(a_ref[...], b_ref[...], mode, None, 0)


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def am_gemm(a, b, mode: str = "native", lut=None, m: int = 7,
            block: Optional[tuple] = None):
    """``c[M, N] = a[M, K] @ b[K, N]`` with multiplies routed per ``mode``:

    * ``"native"`` — hardware ``*`` (ATnG / TFnG custom-kernel analog)
    * ``"lut"`` — AMSim with the mantissa LUT operand (ATxG)
    * ``"direct:<mult>"`` — in-graph bit-manipulation model (Fig 6 direct)

    Shapes are padded up to block multiples (zero padding is exact for all
    modes: AMSim flushes any product with a zero operand to zero).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, f"GEMM contraction mismatch {K} vs {K2}"
    bm, bk, bn = block or pick_block(M, K, N, mode)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    Mp = -(-M // bm) * bm
    Kp = -(-K // bk) * bk
    Np = -(-N // bn) * bn
    a_p = _pad_to(a, Mp, Kp)
    b_p = _pad_to(b, Kp, Np)
    grid = (Mp // bm, Np // bn, Kp // bk)
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    out_shape = jax.ShapeDtypeStruct((Mp, Np), jnp.float32)
    if mode == "lut":
        assert lut is not None, "lut mode needs the mantissa LUT operand"
        lut_spec = pl.BlockSpec((lut.shape[0],), lambda i, j, k: (0,))
        out = pl.pallas_call(
            functools.partial(_kernel_lut, mode=mode, m=m),
            grid=grid,
            in_specs=[a_spec, b_spec, lut_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(a_p, b_p, lut)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_nolut, mode=mode),
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(a_p, b_p)
    return out[:M, :N]


def vmem_footprint_bytes(mode: str, m: int = 7, block: Optional[tuple] = None) -> int:
    """Analytic VMEM footprint of one grid cell — used by the §Perf
    real-TPU estimate in EXPERIMENTS.md (interpret mode has no real VMEM)."""
    bm, bk, bn = block or DEFAULT_BLOCK
    operands = 4 * (bm * bk + bk * bn + bm * bn)
    lut = 4 * (1 << (2 * m)) if mode == "lut" else 0
    # the elementwise path materializes a (bm, bk, bn) product block
    product = 0 if mode == "native" else 4 * bm * bk * bn
    return operands + lut + product
