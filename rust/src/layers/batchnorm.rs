//! Batch normalization over the channel axis of NHWC tensors (exact: BN's
//! multiplies are not routed through AMSim — the paper approximates only
//! the Conv2D/Dense ops; see DESIGN.md). Uses batch statistics in both
//! training and evaluation, which is adequate at the reproduction's batch
//! sizes and keeps the train-step artifact state-free.

use crate::tensor::Tensor;

pub const BN_EPS: f32 = 1e-5;

/// Forward. `gamma`/`beta` are `[c]`. Returns `(y, saved_mean, saved_inv_std)`.
pub fn forward(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> (Tensor, Vec<f32>, Vec<f32>) {
    assert_eq!(x.rank(), 4);
    let c = x.shape[3];
    assert_eq!(gamma.len(), c);
    assert_eq!(beta.len(), c);
    let n = x.len() / c;
    let mut mean = vec![0.0f32; c];
    for (i, &v) in x.data.iter().enumerate() {
        mean[i % c] += v;
    }
    for m in &mut mean {
        *m /= n as f32;
    }
    let mut var = vec![0.0f32; c];
    for (i, &v) in x.data.iter().enumerate() {
        let d = v - mean[i % c];
        var[i % c] += d * d;
    }
    let inv_std: Vec<f32> = var.iter().map(|v| 1.0 / (v / n as f32 + BN_EPS).sqrt()).collect();
    let mut y = Tensor::zeros(&x.shape);
    for (i, &v) in x.data.iter().enumerate() {
        let ch = i % c;
        y.data[i] = (v - mean[ch]) * inv_std[ch] * gamma.data[ch] + beta.data[ch];
    }
    (y, mean, inv_std)
}

/// Backward: returns `(dx, dgamma, dbeta)` given saved statistics.
pub fn backward(
    dy: &Tensor,
    x: &Tensor,
    gamma: &Tensor,
    mean: &[f32],
    inv_std: &[f32],
) -> (Tensor, Tensor, Tensor) {
    let c = x.shape[3];
    let n = (x.len() / c) as f32;
    let mut dgamma = Tensor::zeros(&[c]);
    let mut dbeta = Tensor::zeros(&[c]);
    let mut sum_dy = vec![0.0f32; c];
    let mut sum_dy_xhat = vec![0.0f32; c];
    for (i, (&g, &xv)) in dy.data.iter().zip(&x.data).enumerate() {
        let ch = i % c;
        let xhat = (xv - mean[ch]) * inv_std[ch];
        dgamma.data[ch] += g * xhat;
        dbeta.data[ch] += g;
        sum_dy[ch] += g;
        sum_dy_xhat[ch] += g * xhat;
    }
    let mut dx = Tensor::zeros(&x.shape);
    for (i, (&g, &xv)) in dy.data.iter().zip(&x.data).enumerate() {
        let ch = i % c;
        let xhat = (xv - mean[ch]) * inv_std[ch];
        dx.data[i] = gamma.data[ch] * inv_std[ch] / n
            * (n * g - sum_dy[ch] - xhat * sum_dy_xhat[ch]);
    }
    (dx, dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn normalizes_per_channel() {
        let mut rng = Pcg32::seeded(81);
        let x = Tensor::from_vec(
            &[2, 3, 3, 2],
            (0..36).map(|_| rng.range(-5.0, 5.0)).collect(),
        );
        let gamma = Tensor::filled(&[2], 1.0);
        let beta = Tensor::zeros(&[2]);
        let (y, _, _) = forward(&x, &gamma, &beta);
        for ch in 0..2 {
            let vals: Vec<f32> = y.data.iter().skip(ch).step_by(2).cloned().collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(82);
        let x = Tensor::from_vec(&[2, 2, 2, 2], (0..16).map(|_| rng.range(-2.0, 2.0)).collect());
        let gamma = Tensor::from_vec(&[2], vec![1.3, 0.7]);
        let beta = Tensor::from_vec(&[2], vec![0.1, -0.2]);
        let dy = Tensor::from_vec(&x.shape, (0..16).map(|_| rng.range(-1.0, 1.0)).collect());
        let (_, mean, inv_std) = forward(&x, &gamma, &beta);
        let (dx, dgamma, dbeta) = backward(&dy, &x, &gamma, &mean, &inv_std);
        let loss = |x: &Tensor, g: &Tensor, b: &Tensor| -> f32 {
            let (y, _, _) = forward(x, g, b);
            y.data.iter().zip(&dy.data).map(|(a, d)| a * d).sum()
        };
        let eps = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * eps);
            assert!((num - dx.data[i]).abs() < 2e-2, "dx[{i}]: {num} vs {}", dx.data[i]);
        }
        for i in 0..2 {
            let mut gp = gamma.clone();
            gp.data[i] += eps;
            let mut gm = gamma.clone();
            gm.data[i] -= eps;
            let num = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * eps);
            assert!((num - dgamma.data[i]).abs() < 2e-2, "dgamma[{i}]");
            let mut bp = beta.clone();
            bp.data[i] += eps;
            let mut bm = beta.clone();
            bm.data[i] -= eps;
            let num = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * eps);
            assert!((num - dbeta.data[i]).abs() < 2e-2, "dbeta[{i}]");
        }
    }
}
