//! Training metrics: per-epoch records and CSV/console emission (the data
//! behind the Fig 10 training curves).

use std::fmt::Write as _;

/// One epoch of a training run.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub test_acc: f32,
    pub seconds: f64,
}

/// A full training curve for one (model, dataset, multiplier) cell.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub label: String,
    pub epochs: Vec<EpochRecord>,
}

impl RunLog {
    pub fn new(label: &str) -> RunLog {
        RunLog { label: label.to_string(), epochs: Vec::new() }
    }

    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    pub fn best_test_acc(&self) -> f32 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f32::max)
    }

    /// CSV with a `label` column so multiple runs concatenate into one file.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("label,epoch,train_loss,train_acc,test_acc,seconds\n");
        for e in &self.epochs {
            writeln!(
                out,
                "{},{},{:.6},{:.4},{:.4},{:.3}",
                self.label, e.epoch, e.train_loss, e.train_acc, e.test_acc, e.seconds
            )
            .unwrap();
        }
        out
    }
}

/// Count of correctly-classified rows (argmax == label) from logits
/// (row-major `[batch, classes]`). The count form lets callers weight
/// accuracy per *sample* across unevenly-filled batches — a per-batch
/// average of rates would overweight a padded final batch.
pub fn correct_from_logits(logits: &[f32], labels: &[u32], classes: usize) -> usize {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == label as usize {
            correct += 1;
        }
    }
    correct
}

/// Compute classification accuracy from logits (row-major `[batch, classes]`).
pub fn accuracy_from_logits(logits: &[f32], labels: &[u32], classes: usize) -> f32 {
    correct_from_logits(logits, labels, classes) as f32 / labels.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        let logits = [1.0, 0.0, 0.0, 9.0];
        assert_eq!(accuracy_from_logits(&logits, &[0, 1], 2), 1.0);
        assert_eq!(accuracy_from_logits(&logits, &[1, 0], 2), 0.0);
    }

    #[test]
    fn runlog_csv_and_best() {
        let mut log = RunLog::new("lenet5/afm16");
        log.epochs.push(EpochRecord { epoch: 0, train_loss: 2.0, train_acc: 0.3, test_acc: 0.4, seconds: 1.0 });
        log.epochs.push(EpochRecord { epoch: 1, train_loss: 1.0, train_acc: 0.7, test_acc: 0.6, seconds: 1.0 });
        assert_eq!(log.final_test_acc(), 0.6);
        assert_eq!(log.best_test_acc(), 0.6);
        let csv = log.to_csv();
        assert!(csv.contains("lenet5/afm16,1,1.000000,0.7000,0.6000"));
    }
}
