"""Training-step construction (Layer-2).

One artifact = one fused train step: forward + backward + SGD-momentum
update, all lowered into a single HLO module so the Rust coordinator makes
exactly one PJRT call per batch (no per-layer round trips — the §Perf L2
requirement). Accumulation and the optimizer run in FP32 (the paper's
mixed-precision rule, §VII *Datatype*); only Conv2D/Dense multiplies are
approximate.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

MOMENTUM = 0.9


def cross_entropy(logits, labels, classes: int):
    """Mean softmax cross-entropy against int labels + accuracy."""
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, classes, dtype=jnp.float32)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc


def make_forward(model, cfg):
    """(params..., x, [lut]) -> (logits,)"""

    def forward(param_list, x, lut):
        p = dict(zip([s.name for s in model.params], param_list))
        return (model.apply(cfg, p, x, lut),)

    return forward


def make_train_step(model, cfg):
    """(params..., velocities..., x, y, [lut], lr) ->
    (new_params..., new_velocities..., loss, acc)."""
    names = [s.name for s in model.params]

    def train_step(param_list, vel_list, x, y, lut, lr):
        def loss_fn(param_list):
            p = dict(zip(names, param_list))
            logits = model.apply(cfg, p, x, lut)
            loss, acc = cross_entropy(logits, y, model.classes)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(param_list)
        new_vels = [MOMENTUM * v + g for v, g in zip(vel_list, grads)]
        new_params = [p - lr * v for p, v in zip(param_list, new_vels)]
        return new_params, new_vels, loss, acc

    return train_step


def init_params(model, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """He-normal initialization — used by pytest only; the Rust coordinator
    has its own initializer driven by the manifest init metadata."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for spec in model.params:
        key, sub = jax.random.split(key)
        if spec.init == "he_normal":
            std = (2.0 / max(spec.fan_in, 1)) ** 0.5
            out[spec.name] = std * jax.random.normal(sub, spec.shape, jnp.float32)
        elif spec.init == "zeros":
            out[spec.name] = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "ones":
            out[spec.name] = jnp.ones(spec.shape, jnp.float32)
        else:
            raise ValueError(f"unknown init {spec.init!r}")
    return out
