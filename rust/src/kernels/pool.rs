//! Pooling kernels. The paper notes pooling layers involve no
//! multiplications (§III-A, Table I) — they run exactly in every
//! configuration; they exist here so the CPU (ATxC) path can execute
//! complete LeNet/ResNet models.

/// 2x2 max-pool, stride 2, NHWC. Returns `(output, argmax_indices)`;
/// the indices feed the backward pass.
///
/// NaN semantics match TF's maxpool: a window containing NaN outputs NaN
/// (`max` with a NaN operand is NaN — a diverged run stays visibly
/// diverged instead of being laundered into a finite value), and the
/// argmax is well-defined for every window: the **first** NaN when one is
/// present, otherwise the **first** maximum. The earlier `v > best` scan
/// silently dropped NaN (never selected), and an all-NaN window produced
/// `-inf` with argmax pointing at flat index 0 — routing that window's
/// gradient to an arbitrary element of a *different* window.
pub fn maxpool2x2(
    input: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<f32>, Vec<usize>) {
    assert_eq!(input.len(), batch * h * w * c);
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2x2 needs even spatial dims");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; batch * oh * ow * c];
    let mut arg = vec![0usize; out.len()];
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let first = ((b * h + oy * 2) * w + ox * 2) * c + ch;
                    let mut best = input[first];
                    let mut best_idx = first;
                    'window: for dy in 0..2 {
                        for dx in 0..2 {
                            let idx =
                                ((b * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch;
                            let v = input[idx];
                            if v.is_nan() {
                                best = v;
                                best_idx = idx;
                                break 'window; // NaN propagates; first NaN wins
                            }
                            if v > best {
                                best = v;
                                best_idx = idx;
                            }
                        }
                    }
                    let o = ((b * oh + oy) * ow + ox) * c + ch;
                    out[o] = best;
                    arg[o] = best_idx;
                }
            }
        }
    }
    (out, arg)
}

/// Backward of [`maxpool2x2`]: routes each output gradient to its argmax.
pub fn maxpool2x2_backward(dy: &[f32], argmax: &[usize], input_len: usize) -> Vec<f32> {
    assert_eq!(dy.len(), argmax.len());
    let mut dx = vec![0.0f32; input_len];
    for (g, &idx) in dy.iter().zip(argmax) {
        dx[idx] += g;
    }
    dx
}

/// Global average pool over the spatial dims: `[b, h, w, c] -> [b, c]`.
pub fn global_avgpool(input: &[f32], batch: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    assert_eq!(input.len(), batch * h * w * c);
    let mut out = vec![0.0f32; batch * c];
    let inv = 1.0 / (h * w) as f32;
    for b in 0..batch {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    out[b * c + ch] += input[((b * h + y) * w + x) * c + ch];
                }
            }
        }
    }
    for v in &mut out {
        *v *= inv;
    }
    out
}

/// Backward of [`global_avgpool`].
pub fn global_avgpool_backward(
    dy: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    assert_eq!(dy.len(), batch * c);
    let inv = 1.0 / (h * w) as f32;
    let mut dx = vec![0.0f32; batch * h * w * c];
    for b in 0..batch {
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    dx[((b * h + y) * w + x) * c + ch] = dy[b * c + ch] * inv;
                }
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_picks_max_and_routes_grad() {
        // single 2x2 image, one channel
        let input = vec![1.0, 4.0, 2.0, 3.0];
        let (out, arg) = maxpool2x2(&input, 1, 2, 2, 1);
        assert_eq!(out, vec![4.0]);
        assert_eq!(arg, vec![1]);
        let dx = maxpool2x2_backward(&[5.0], &arg, 4);
        assert_eq!(dx, vec![0.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn avgpool_and_backward() {
        let input = vec![1.0, 2.0, 3.0, 4.0]; // 1x2x2x1
        let out = global_avgpool(&input, 1, 2, 2, 1);
        assert_eq!(out, vec![2.5]);
        let dx = global_avgpool_backward(&out, 1, 2, 2, 1);
        assert_eq!(dx, vec![0.625; 4]);
    }

    #[test]
    fn maxpool_propagates_nan_with_defined_argmax() {
        // one NaN in the window: output NaN, argmax = that NaN, gradient
        // routed there (stays in the diverged element, not laundered)
        let input = vec![1.0, f32::NAN, 2.0, 3.0];
        let (out, arg) = maxpool2x2(&input, 1, 2, 2, 1);
        assert!(out[0].is_nan(), "NaN must propagate, got {}", out[0]);
        assert_eq!(arg, vec![1], "argmax is the first NaN");
        let dx = maxpool2x2_backward(&[7.0], &arg, 4);
        assert_eq!(dx, vec![0.0, 7.0, 0.0, 0.0]);

        // all-NaN window: output NaN, argmax = the window's own first
        // element (the old code output -inf with argmax at flat index 0)
        let mut input = vec![f32::NAN; 4 * 4];
        input[0] = 5.0; // window (0,0) is fine; window (0,1) is all NaN
        input[1] = 1.0;
        input[4] = 2.0;
        input[5] = 3.0;
        let (out, arg) = maxpool2x2(&input, 1, 4, 4, 1);
        assert_eq!(out[0], 5.0);
        assert!(out[1].is_nan());
        assert_eq!(arg[1], 2, "all-NaN window argmax stays inside the window");

        // NaN in one channel never leaks into the other
        let input = vec![
            1.0,
            f32::NAN, //
            2.0,
            f32::NAN, //
            3.0,
            f32::NAN, //
            4.0,
            f32::NAN,
        ];
        let (out, _) = maxpool2x2(&input, 1, 2, 2, 2);
        assert_eq!(out[0], 4.0);
        assert!(out[1].is_nan());
    }

    #[test]
    fn maxpool_ties_pick_first() {
        let input = vec![2.0, 2.0, 2.0, 2.0];
        let (out, arg) = maxpool2x2(&input, 1, 2, 2, 1);
        assert_eq!(out, vec![2.0]);
        assert_eq!(arg, vec![0], "tied windows route the gradient to the first element");
    }

    #[test]
    fn maxpool_channels_independent() {
        // 2x2, 2 channels interleaved
        let input = vec![
            1.0, 40.0, //
            2.0, 30.0, //
            3.0, 20.0, //
            4.0, 10.0,
        ];
        let (out, _) = maxpool2x2(&input, 1, 2, 2, 2);
        assert_eq!(out, vec![4.0, 40.0]);
    }
}
