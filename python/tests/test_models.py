"""Model definitions: shapes, parameter inventories, and one-batch
training sanity for every model at tiny scale."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import lutgen, mults
from compile.layers import MulCfg
from compile.models import by_name, lenet, resnet
from compile.train import cross_entropy, init_params, make_train_step

LUT = jnp.asarray(lutgen.generate(mults.by_name("afm16")))


@pytest.mark.parametrize("name", ["lenet300", "lenet5", "resnet18", "resnet34",
                                  "resnet50"])
def test_forward_shapes(name):
    model = by_name(name)()
    p = init_params(model, 0)
    h, w, c = model.input_shape
    x = jnp.zeros((2, h, w, c), jnp.float32)
    logits = model.apply(MulCfg("tf"), p, x, None)
    assert logits.shape == (2, model.classes)


def test_lenet300_parameter_count():
    m = lenet.lenet300()
    n = sum(int(np.prod(s.shape)) for s in m.params)
    # 784*300+300 + 300*100+100 + 100*10+10 = 266610 (the classic MLP)
    assert n == 266610


def test_lenet5_flatten_geometry():
    m = lenet.lenet5()
    fc1 = next(s for s in m.params if s.name == "fc1/w")
    assert fc1.shape == (400, 120)  # 5*5*16


def test_resnet_depth_ordering():
    n18 = len(resnet.resnet18().params)
    n34 = len(resnet.resnet34().params)
    n50 = len(resnet.resnet50().params)
    assert n18 < n34 < n50


def test_resnet_strided_downsampling_params_exist():
    m = resnet.resnet18()
    names = {s.name for s in m.params}
    assert "s1b0/down/w" in names  # stage transition needs projection
    assert "s0b0/down/w" not in names  # same-shape block has none


@pytest.mark.parametrize("name,mode", [("lenet300", "lut"), ("lenet5", "lut"),
                                       ("resnet18", "direct:afm32")])
def test_one_batch_overfits(name, mode):
    """A few steps on one batch must reduce the loss — for approximate
    modes too (the paper's core trainability claim in miniature)."""
    model = by_name(name)()
    p = init_params(model, 0)
    plist = [p[s.name] for s in model.params]
    vlist = [jnp.zeros_like(v) for v in plist]
    h, w, c = model.input_shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, (8, h, w, c)).astype(np.float32))
    y = jnp.asarray(np.arange(8) % model.classes, dtype=jnp.int32)
    step = jax.jit(make_train_step(model, MulCfg(mode, 7)))
    losses = []
    for _ in range(6):
        plist, vlist, loss, acc = step(plist, vlist, x, y, LUT, 0.05)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_cross_entropy_at_uniform():
    logits = jnp.zeros((4, 10), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    loss, acc = cross_entropy(logits, y, 10)
    assert abs(float(loss) - np.log(10)) < 1e-5


def test_init_metadata_complete():
    for name in ["lenet300", "lenet5", "resnet50"]:
        model = by_name(name)()
        for s in model.params:
            assert s.init in ("he_normal", "zeros", "ones"), s
            if s.init == "he_normal":
                assert s.fan_in > 0, s
