//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust coordinator (which drives
//! training/inference purely from this metadata — no Python at runtime).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Role of an artifact input/output tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// trainable parameter (fed back between steps)
    Param,
    /// optimizer momentum buffer (fed back between steps)
    Velocity,
    /// batch images
    Input,
    /// batch labels (i32)
    Label,
    /// mantissa-product LUT (u32)
    Lut,
    /// scalar hyper-parameter (learning rate)
    Hyper,
    /// scalar metric output (loss/accuracy)
    Metric,
    /// logits output
    Logits,
}

impl Role {
    pub fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "velocity" => Role::Velocity,
            "input" => Role::Input,
            "label" => Role::Label,
            "lut" => Role::Lut,
            "hyper" => Role::Hyper,
            "metric" => Role::Metric,
            "logits" => Role::Logits,
            other => bail!("unknown tensor role {other:?}"),
        })
    }
}

/// Dtype of an artifact tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// One named tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub role: Role,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing name"))?
            .to_string();
        let role = Role::parse(
            j.get("role").and_then(Json::as_str).ok_or_else(|| anyhow!("{name}: missing role"))?,
        )?;
        let dtype = Dtype::parse(j.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?;
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{name}: missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("{name}: bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, role, shape, dtype })
    }
}

/// One compiled artifact (an HLO-text file plus its signature).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub model: String,
    pub phase: String,
    pub mode: String,
    pub mantissa_bits: u32,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl Artifact {
    /// Indices of inputs with the given role, in positional order.
    pub fn input_indices(&self, role: Role) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, t)| t.role == role)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut artifacts = BTreeMap::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let art = Artifact {
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: missing file"))?
                    .to_string(),
                model: a.get("model").and_then(Json::as_str).unwrap_or("").to_string(),
                phase: a.get("phase").and_then(Json::as_str).unwrap_or("").to_string(),
                mode: a.get("mode").and_then(Json::as_str).unwrap_or("").to_string(),
                mantissa_bits: a
                    .get("mantissa_bits")
                    .and_then(Json::as_usize)
                    .unwrap_or(7) as u32,
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                name: name.clone(),
            };
            artifacts.insert(name, art);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, art: &Artifact) -> PathBuf {
        self.dir.join(&art.file)
    }

    /// Artifacts filtered by (model, phase, mode).
    pub fn find(&self, model: &str, phase: &str, mode: &str) -> Option<&Artifact> {
        self.artifacts
            .values()
            .find(|a| a.model == model && a.phase == phase && a.mode == mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "lenet5_train_lut", "file": "lenet5_train_lut.hlo.txt",
         "model": "lenet5", "phase": "train", "mode": "lut", "mantissa_bits": 7,
         "inputs": [
           {"name": "conv1/w", "role": "param", "shape": [5,5,1,6], "dtype": "f32"},
           {"name": "vel:conv1/w", "role": "velocity", "shape": [5,5,1,6], "dtype": "f32"},
           {"name": "x", "role": "input", "shape": [64,28,28,1], "dtype": "f32"},
           {"name": "y", "role": "label", "shape": [64], "dtype": "i32"},
           {"name": "lut", "role": "lut", "shape": [16384], "dtype": "u32"},
           {"name": "lr", "role": "hyper", "shape": [], "dtype": "f32"}
         ],
         "outputs": [
           {"name": "conv1/w", "role": "param", "shape": [5,5,1,6], "dtype": "f32"},
           {"name": "vel:conv1/w", "role": "velocity", "shape": [5,5,1,6], "dtype": "f32"},
           {"name": "loss", "role": "metric", "shape": [], "dtype": "f32"},
           {"name": "acc", "role": "metric", "shape": [], "dtype": "f32"}
         ]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        let a = m.get("lenet5_train_lut").unwrap();
        assert_eq!(a.model, "lenet5");
        assert_eq!(a.inputs.len(), 6);
        assert_eq!(a.input_indices(Role::Param), vec![0]);
        assert_eq!(a.input_indices(Role::Lut), vec![4]);
        assert_eq!(a.output_index("loss"), Some(2));
        assert_eq!(a.inputs[4].dtype, Dtype::U32);
        assert_eq!(a.inputs[2].elements(), 64 * 28 * 28);
        assert!(m.find("lenet5", "train", "lut").is_some());
        assert!(m.find("lenet5", "train", "native").is_none());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"artifacts":[{"file":"x"}]}"#).is_err());
        let bad_role = SAMPLE.replace("\"param\"", "\"banana\"");
        assert!(Manifest::parse(Path::new("/tmp"), &bad_role).is_err());
    }
}
