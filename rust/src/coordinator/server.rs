//! Batching inference server — the deployment-shaped consumer of the
//! inference path (ApproxTrain "also supports inference using approximate
//! multipliers", §I).
//!
//! Architecture (vLLM-router-like, scaled to this crate): client threads
//! submit single requests to a queue; a batcher thread collects up to
//! `batch` requests (padding with zero rows when the timeout fires), runs
//! the forward artifact once, and distributes per-request results. The
//! tokio crate is not available offline, so the event loop is
//! std::sync::mpsc + threads — same topology.

use std::sync::mpsc::{self, Receiver, Sender};

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::nn::metrics::accuracy_from_logits;
use crate::runtime::executor::{Engine, Value};

/// One inference request: an image and a oneshot-style reply channel.
/// (fields used by the serve loop)
pub struct Request {
    image: Vec<f32>,
    reply: Sender<Reply>,
    submitted: Instant,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// how many real requests shared the batch (for metrics)
    pub batch_fill: usize,
}

/// Server handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    image_elems: usize,
}

impl Client {
    /// Blocking inference call.
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply> {
        assert_eq!(image.len(), self.image_elems, "image size");
        let (reply_tx, reply_rx) = mpsc::channel();
        let submitted = Instant::now();
        self.tx
            .send(Request { image, reply: reply_tx, submitted })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))
    }
}

/// Server statistics.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub requests: usize,
    pub batches: usize,
    pub latencies_s: Vec<f64>,
    pub fills: Vec<usize>,
}

/// Run the batching server loop until the request channel closes.
/// `fwd_artifact` must be a forward artifact; `fixed_inputs` are the
/// params (+ optional LUT) in positional order around the image input.
pub fn serve(
    engine: &mut Engine,
    fwd_artifact: &str,
    params: Vec<Value>,
    lut: Option<Vec<u32>>,
    rx: Receiver<Request>,
    batch: usize,
    image_elems: usize,
    classes: usize,
    max_wait: Duration,
) -> Result<Stats> {
    // Warm the persistent kernel worker pool before the serving loop so
    // first-request latency never includes thread spawning, and
    // pre-allocate the per-lane pack buffers of the tiled GEMM (best
    // effort); all batched CPU kernel work behind the forward pass shares
    // this pool across batches.
    crate::kernels::gemm::warm_tiled();
    let mut stats = Stats::default();
    loop {
        // collect up to `batch` requests, waiting at most max_wait after
        // the first arrives (the paper-world "dynamic batching" policy)
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all clients done
        };
        let deadline = Instant::now() + max_wait;
        let mut pending = vec![first];
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // assemble the fixed-shape batch (zero padding for empty slots)
        let fill = pending.len();
        let mut images = vec![0.0f32; batch * image_elems];
        for (i, r) in pending.iter().enumerate() {
            images[i * image_elems..(i + 1) * image_elems].copy_from_slice(&r.image);
        }
        let mut inputs = params.clone();
        inputs.push(Value::F32(images));
        if let Some(l) = &lut {
            inputs.push(Value::U32(l.clone()));
        }
        let out = engine.run(fwd_artifact, &inputs)?;
        let logits = out[0].as_f32()?;
        for (i, r) in pending.into_iter().enumerate() {
            let latency = r.submitted.elapsed();
            stats.requests += 1;
            stats.latencies_s.push(latency.as_secs_f64());
            let _ = r.reply.send(Reply {
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency,
                batch_fill: fill,
            });
        }
        stats.batches += 1;
        stats.fills.push(fill);
    }
    Ok(stats)
}

/// Convenience: run the batcher/executor loop on the *current* thread (the
/// PJRT client is not `Send`) while the `load` closure drives traffic from
/// a spawned thread. When `load` returns and drops its `Client`, the
/// request channel closes and the server loop exits.
pub fn with_server<F>(
    mut engine: Engine,
    fwd_artifact: &str,
    params: Vec<Value>,
    lut: Option<Vec<u32>>,
    batch: usize,
    image_elems: usize,
    classes: usize,
    max_wait: Duration,
    load: F,
) -> Result<Stats>
where
    F: FnOnce(Client) + Send,
{
    let (tx, rx) = mpsc::channel::<Request>();
    let client = Client { tx, image_elems };
    std::thread::scope(|s| -> Result<Stats> {
        let loader = s.spawn(move || load(client));
        let stats =
            serve(&mut engine, fwd_artifact, params, lut, rx, batch, image_elems, classes, max_wait)?;
        loader.join().expect("load thread panicked");
        Ok(stats)
    })
}

/// Classify a reply against a label (test helper + example metric).
pub fn reply_correct(reply: &Reply, label: u32) -> bool {
    accuracy_from_logits(&reply.logits, &[label], reply.logits.len()) > 0.5
}
