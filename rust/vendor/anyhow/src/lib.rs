//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! This build environment has no registry access, so the workspace carries
//! the slice of anyhow it actually uses: [`Error`], [`Result`], the
//! [`Context`] extension trait (on both `Result` and `Option`), and the
//! `anyhow!` / `bail!` macros. Semantics match upstream where it matters:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//!   via `?` (the blanket `From` impl);
//! * [`Error`] itself does **not** implement `std::error::Error` (same
//!   coherence reason as upstream: the blanket `From` would conflict);
//! * `.context(...)` wraps the message, keeping the source for `Debug`.
//!
//! Deliberately omitted: downcasting, backtraces, `ensure!`.

use std::fmt;

/// Error type: a display message plus an optional boxed source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a display-able message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message. Unlike upstream anyhow (which
    /// shows only the outermost layer in `Display`), the full chain is
    /// concatenated `outer: inner` — strictly more informative, and every
    /// caller in this workspace only does `contains(...)` checks.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_ref().map(|e| e.as_ref() as &dyn std::error::Error);
        while let Some(e) = src {
            write!(f, "\n\ncaused by: {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — also usable as `Result<T, E>` thanks to the
/// defaulted parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

// Layering context onto an already-anyhow Result (no coherence overlap
// with the generic impl: `Error` does not implement `std::error::Error`).
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("missing thing"));
    }

    #[test]
    fn context_layers_display() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        let s = e.to_string();
        assert!(s.contains("reading manifest") && s.contains("missing thing"), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.with_context(|| format!("--{} required", "mult")).unwrap_err();
        assert!(err.to_string().contains("--mult required"));
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert!(inner(true).unwrap_err().to_string().contains("bad value 7"));
        let e = anyhow!("plain {}", "msg");
        assert_eq!(e.to_string(), "plain msg");
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner failure");
        }
        let err = inner().with_context(|| "outer step").unwrap_err();
        let s = err.to_string();
        assert!(s.contains("outer step") && s.contains("inner failure"), "{s}");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::new(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("caused by"), "{dbg}");
    }
}
