//! Minimal JSON parser + emitter.
//!
//! The offline vendored dependency set has no `serde`, and the artifact
//! manifest written by `python/compile/aot.py` is JSON, so the coordinator
//! carries its own ~300-line implementation. It supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- constructors ----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.s.len() && (self.s[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.s[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---- emission --------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arts":[{"name":"m","shape":[64,28,28,1],"ok":true}],"n":3.5}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn deep_nesting_and_ws() {
        let v = Json::parse(" [ [ [ 1 ] , [ ] ] ] ").unwrap();
        assert_eq!(v, Json::arr([Json::arr([Json::arr([Json::num(1.0)]), Json::arr([])])]));
    }
}
