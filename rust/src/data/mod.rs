//! Dataset pipeline.
//!
//! The paper trains on MNIST, CIFAR-10 and ImageNet. Those downloads are
//! unavailable in this environment (repro band 0), so the pipeline provides
//! deterministic *synthetic* datasets of the same rank and shape
//! (DESIGN.md §Substitutions #2): each class has a fixed random template
//! pattern; samples are the template plus a random spatial shift plus
//! Gaussian pixel noise. The task is fully learnable, and — crucially for
//! the paper's claims — every multiplier configuration sees bit-identical
//! data because generation is seeded.
//!
//! A loader for the real MNIST IDX format is included ([`idx`]); if the
//! files are present under `data/mnist/` the coordinator uses them instead.
pub mod idx;
pub mod synth;

use crate::util::rng::Pcg32;

/// Pad an assembled batch image buffer holding `real` rows of
/// `row_elems` f32s up to `batch` rows **by cycling the real rows** —
/// the crate-wide padding policy for fixed-shape batches
/// ([`EvalBatcher`], the serving lanes, the `bench-serve` reference):
/// repeated real rows keep the padded batch drawn from the data
/// distribution, whereas zero rows would fold into every real row's
/// normalization through batch-statistics batchnorm.
pub fn pad_batch_by_cycling(images: &mut Vec<f32>, real: usize, batch: usize, row_elems: usize) {
    assert!(real > 0 && real <= batch, "real rows {real} vs batch {batch}");
    debug_assert_eq!(images.len(), real * row_elems);
    for pad in 0..batch - real {
        let src = (pad % real) * row_elems;
        images.extend_from_within(src..src + row_elems);
    }
}

/// An in-memory image-classification dataset, NHWC f32 images in [0, 1].
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub images: Vec<f32>,
    pub labels: Vec<u32>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.h * self.w * self.c;
        &self.images[i * sz..(i + 1) * sz]
    }

    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Split off the last `n_test` samples as a test set.
    pub fn split(mut self, n_test: usize) -> (Dataset, Dataset) {
        assert!(n_test < self.n);
        let n_train = self.n - n_test;
        let sz = self.image_len();
        let test = Dataset {
            name: format!("{}-test", self.name),
            images: self.images.split_off(n_train * sz),
            labels: self.labels.split_off(n_train),
            n: n_test,
            ..self.clone_meta()
        };
        self.n = n_train;
        (self, test)
    }

    fn clone_meta(&self) -> Dataset {
        Dataset {
            name: self.name.clone(),
            images: Vec::new(),
            labels: Vec::new(),
            n: 0,
            h: self.h,
            w: self.w,
            c: self.c,
            classes: self.classes,
        }
    }
}

/// Mini-batch iterator with deterministic per-epoch shuffling.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> Batcher<'a> {
    /// `epoch` seeds the shuffle so runs are reproducible *and* epochs
    /// differ.
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64, epoch: u64) -> Batcher<'a> {
        assert!(batch > 0 && batch <= ds.n, "batch {} vs n {}", batch, ds.n);
        let mut order: Vec<usize> = (0..ds.n).collect();
        let mut rng = Pcg32::new(seed, 0xBA7C + epoch);
        rng.shuffle(&mut order);
        Batcher { ds, order, batch, pos: 0 }
    }

    /// Number of full batches per epoch (trailing partial batch dropped, as
    /// the fixed-shape compiled artifacts require static batch sizes).
    pub fn batches(&self) -> usize {
        self.ds.n / self.batch
    }
}

impl<'a> Iterator for Batcher<'a> {
    /// (images `[batch, h, w, c]` flattened, labels `[batch]`)
    type Item = (Vec<f32>, Vec<u32>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos + self.batch > self.ds.n {
            return None;
        }
        let sz = self.ds.image_len();
        let mut images = Vec::with_capacity(self.batch * sz);
        let mut labels = Vec::with_capacity(self.batch);
        for &i in &self.order[self.pos..self.pos + self.batch] {
            images.extend_from_slice(self.ds.image(i));
            labels.push(self.ds.labels[i]);
        }
        self.pos += self.batch;
        Some((images, labels))
    }
}

/// Evaluation batch iterator: walks the dataset **in order** (no shuffle —
/// evaluation is order-independent, and determinism is clearer unshuffled)
/// and always yields full `batch`-shaped image buffers, padding the
/// trailing partial batch by **cycling that batch's real samples** (not
/// zeros: a forward artifact whose batchnorm uses batch statistics would
/// fold all-zero rows into every real sample's normalization — repeated
/// real images keep the padded batch drawn from the data distribution).
/// The label slice only covers the *real* samples, so callers can weight
/// metrics per sample and never score padding rows.
///
/// Unlike [`Batcher`] this covers 100% of the dataset (including a final
/// partial batch, and datasets smaller than one batch) — the shape the
/// fixed-batch compiled forward artifacts need for test-set evaluation.
pub struct EvalBatcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    pos: usize,
}

impl<'a> EvalBatcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize) -> EvalBatcher<'a> {
        assert!(batch > 0, "batch must be positive");
        EvalBatcher { ds, batch, pos: 0 }
    }

    /// Number of batches the iterator will yield (`ceil(n / batch)`).
    pub fn batches(&self) -> usize {
        self.ds.n.div_ceil(self.batch)
    }
}

impl<'a> Iterator for EvalBatcher<'a> {
    /// (images `[batch, h, w, c]` flattened, padded by cycling the real
    /// samples; real labels `[1..=batch]`)
    type Item = (Vec<f32>, &'a [u32]);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.ds.n {
            return None;
        }
        let real = (self.ds.n - self.pos).min(self.batch);
        let sz = self.ds.image_len();
        let mut images = Vec::with_capacity(self.batch * sz);
        images.extend_from_slice(&self.ds.images[self.pos * sz..(self.pos + real) * sz]);
        pad_batch_by_cycling(&mut images, real, self.batch, sz);
        let labels = &self.ds.labels[self.pos..self.pos + real];
        self.pos += real;
        Some((images, labels))
    }
}

#[cfg(test)]
mod tests {
    use super::synth::{mnist_like, SynthSpec};
    use super::*;

    fn tiny() -> Dataset {
        mnist_like(&SynthSpec { n: 64, seed: 7, ..SynthSpec::mnist_like_default() })
    }

    #[test]
    fn split_preserves_samples() {
        let ds = tiny();
        let total = ds.n;
        let (train, test) = ds.split(16);
        assert_eq!(train.n + test.n, total);
        assert_eq!(test.n, 16);
        assert_eq!(train.images.len(), train.n * train.image_len());
    }

    #[test]
    fn batcher_is_exhaustive_and_deterministic() {
        let ds = tiny();
        let b1: Vec<_> = Batcher::new(&ds, 16, 1, 0).collect();
        let b2: Vec<_> = Batcher::new(&ds, 16, 1, 0).collect();
        assert_eq!(b1.len(), 4);
        assert_eq!(b1, b2);
        let b3: Vec<_> = Batcher::new(&ds, 16, 1, 1).collect();
        assert_ne!(b1, b3, "different epochs must shuffle differently");
        // every sample appears exactly once per epoch
        let mut seen = vec![0u32; ds.n];
        for (_, labels) in &b1 {
            assert_eq!(labels.len(), 16);
        }
        let mut order_flat: Vec<usize> = Vec::new();
        let batcher = Batcher::new(&ds, 16, 1, 0);
        order_flat.extend(&batcher.order);
        for &i in &order_flat {
            seen[i] += 1;
        }
        assert!(seen.iter().all(|&s| s == 1));
    }

    #[test]
    fn partial_batches_dropped() {
        let ds = tiny();
        let b: Vec<_> = Batcher::new(&ds, 30, 1, 0).collect();
        assert_eq!(b.len(), 2);
    }

    /// The evaluation iterator must cover every sample exactly once, in
    /// dataset order, padding only the final batch — the `Batcher`
    /// trailing-sample drop this replaces was a silent evaluation bug.
    #[test]
    fn eval_batcher_covers_everything_with_padded_tail() {
        let ds = tiny(); // n = 64
        let eb = EvalBatcher::new(&ds, 30);
        assert_eq!(eb.batches(), 3);
        let batches: Vec<_> = eb.collect();
        assert_eq!(batches.len(), 3);
        let sz = ds.image_len();
        let mut label_count = 0usize;
        for (bi, (images, labels)) in batches.iter().enumerate() {
            assert_eq!(images.len(), 30 * sz, "batch {bi}: fixed image shape");
            // labels are the real samples, in order
            for (j, &l) in labels.iter().enumerate() {
                assert_eq!(l, ds.labels[bi * 30 + j], "batch {bi} label {j}");
            }
            label_count += labels.len();
        }
        assert_eq!(label_count, ds.n, "every sample scored exactly once");
        // final batch: 4 real samples, the rest pads by cycling those 4
        let (last_imgs, last_labels) = &batches[2];
        assert_eq!(last_labels.len(), 4);
        for pad in 0..30 - 4 {
            let want = ds.image(60 + pad % 4);
            assert_eq!(&last_imgs[(4 + pad) * sz..(5 + pad) * sz], want, "pad row {pad}");
        }
        // real image data is copied through unchanged
        assert_eq!(&last_imgs[..sz], ds.image(60));
    }

    /// A dataset smaller than one batch — which used to panic in
    /// `Batcher::new` — evaluates as a single padded batch.
    #[test]
    fn eval_batcher_handles_dataset_smaller_than_batch() {
        let ds = tiny();
        let batches: Vec<_> = EvalBatcher::new(&ds, 100).collect();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].1.len(), 64);
        assert_eq!(batches[0].0.len(), 100 * ds.image_len());
        // and an empty dataset yields no batches at all
        let empty = Dataset {
            name: "empty".into(),
            images: Vec::new(),
            labels: Vec::new(),
            n: 0,
            h: ds.h,
            w: ds.w,
            c: ds.c,
            classes: ds.classes,
        };
        assert_eq!(EvalBatcher::new(&empty, 8).count(), 0);
    }
}
