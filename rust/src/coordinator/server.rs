//! Multi-lane batching inference server — the deployment-shaped consumer
//! of the inference path (ApproxTrain "also supports inference using
//! approximate multipliers", §I), scaled from a single batcher thread to
//! the shape production serving systems use (vLLM's continuous-batching
//! router, Clipper's adaptive-batching layer): N worker **lanes**, each
//! owning an [`InferBackend`] replica and running the size/timeout
//! dynamic-batching policy, all fed from one shared dispatcher with a
//! **bounded** admission queue.
//!
//! * **Admission** — [`Client::infer`] enqueues into the bounded queue;
//!   when the queue is at `queue_depth` the caller gets a typed
//!   [`InferError::Rejected`] immediately (backpressure) instead of
//!   growing an unbounded channel.
//! * **Lanes** — each lane pops the first waiting request, then fills its
//!   batch for at most `max_wait` (dynamic batching), pads a
//!   partially-filled batch **by cycling the batch's real request
//!   images** (never zero rows: a batch-statistics batchnorm folds every
//!   padding row into every real row's normalization — same policy and
//!   rationale as [`crate::data::EvalBatcher`]), runs the backend once,
//!   and replies to the real requests only.
//! * **Stats** — per-lane [`Stats`] merge into one deterministic
//!   aggregate: streaming fields are summed, latency reservoirs merged by
//!   a seen-weighted interleave.
//!
//! The tokio crate is not available offline, so the event machinery is
//! `Mutex` + `Condvar` + threads — same topology.

use std::collections::VecDeque;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::backend::InferBackend;
use super::wire::Priority;
use crate::nn::metrics::accuracy_from_logits;
use crate::util::rng::Pcg32;
use crate::util::stats as ustats;

/// One inference request: an image and a oneshot-style reply channel.
struct Request {
    image: Vec<f32>,
    reply: Sender<Reply>,
    submitted: Instant,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// how many real requests shared the batch (for metrics)
    pub batch_fill: usize,
}

/// Typed client-side failure: the admission decision is part of the API,
/// not an anonymous string. The in-process path uses
/// `Rejected`/`Stopped`; the networked tier ([`super::net`]) adds the
/// deadline/shed/tenant/transport outcomes. Of these, only
/// [`Shed`](InferError::Shed) and [`Overloaded`](InferError::Overloaded)
/// are idempotent rejections (the request was never enqueued) — they are
/// the only variants [`super::net::NetClient`] will retry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// The bounded admission queue was full — backpressure, try later.
    Rejected {
        /// the configured admission-queue depth that was exceeded
        queue_depth: usize,
    },
    /// The server stopped (or failed) before replying.
    Stopped,
    /// The request's deadline expired — at admission, in-queue, or at
    /// reply time. A late result is never silently returned stale.
    DeadlineExceeded,
    /// Shed at admission under queue pressure (priority below the
    /// surviving classes). Idempotent: safe to retry.
    Shed {
        /// the class the shed request carried
        priority: Priority,
    },
    /// Networked admission queue at hard depth. Idempotent: safe to retry.
    Overloaded,
    /// The tenant is not in the server's model registry.
    UnknownTenant(String),
    /// The tenant's outstanding-request quota is exhausted.
    QuotaExceeded,
    /// Admission closed for graceful drain; in-flight work is finishing.
    Draining,
    /// The server rejected the frame or request contents as malformed.
    BadRequest(String),
    /// Transport-level failure before the request reached the server
    /// (connect/encode) — the request was definitely not executed.
    Transport(String),
    /// The connection died with the request possibly in flight. NOT
    /// retried: the server may have executed it.
    Ambiguous(String),
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::Rejected { queue_depth } => {
                write!(f, "request rejected: admission queue full (depth {queue_depth})")
            }
            InferError::Stopped => write!(f, "server stopped before replying"),
            InferError::DeadlineExceeded => write!(f, "deadline exceeded"),
            InferError::Shed { priority } => {
                write!(f, "shed at admission ({} priority under queue pressure)", priority.describe())
            }
            InferError::Overloaded => write!(f, "admission queue full"),
            InferError::UnknownTenant(t) => write!(f, "unknown tenant {t:?}"),
            InferError::QuotaExceeded => write!(f, "tenant quota exceeded"),
            InferError::Draining => write!(f, "server draining; admission closed"),
            InferError::BadRequest(m) => write!(f, "bad request: {m}"),
            InferError::Transport(m) => write!(f, "transport failure: {m}"),
            InferError::Ambiguous(m) => {
                write!(f, "connection lost with request in flight (not retried): {m}")
            }
        }
    }
}

impl std::error::Error for InferError {}

/// Serving policy knobs shared by every lane.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// dynamic batching: wait at most this long after the first request
    /// of a batch for more to arrive
    pub max_wait: Duration,
    /// bounded admission-queue depth; submissions beyond it are rejected
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { max_wait: Duration::from_millis(5), queue_depth: 64 }
    }
}

// ---------------------------------------------------------------------------
// Bounded admission queue (the shared dispatcher)
// ---------------------------------------------------------------------------

struct QueueState {
    q: VecDeque<Request>,
    closed: bool,
    rejected: u64,
}

/// Bounded MPMC request queue: clients submit (rejecting at `depth`),
/// lanes pop dynamic batches. `Condvar`-based so idle lanes sleep.
struct AdmissionQueue {
    depth: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl AdmissionQueue {
    fn new(depth: usize) -> AdmissionQueue {
        assert!(depth > 0, "queue depth must be positive");
        AdmissionQueue {
            depth,
            state: Mutex::new(QueueState { q: VecDeque::new(), closed: false, rejected: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Admit or reject a request. O(1), never blocks the client.
    fn submit(&self, req: Request) -> Result<(), InferError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(InferError::Stopped);
        }
        if st.q.len() >= self.depth {
            st.rejected += 1;
            return Err(InferError::Rejected { queue_depth: self.depth });
        }
        st.q.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Lane side: block for the first request, then fill up to `batch`
    /// for at most `max_wait`. Returns `None` when the queue is closed
    /// and fully drained (lane shutdown).
    fn pop_batch(&self, batch: usize, max_wait: Duration) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(first) = st.q.pop_front() {
                let mut pending = vec![first];
                let deadline = Instant::now() + max_wait;
                while pending.len() < batch {
                    if let Some(r) = st.q.pop_front() {
                        pending.push(r);
                        continue;
                    }
                    if st.closed {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                    if timeout.timed_out() {
                        // take anything that raced in with the timeout
                        while pending.len() < batch {
                            match st.q.pop_front() {
                                Some(r) => pending.push(r),
                                None => break,
                            }
                        }
                        break;
                    }
                }
                if !st.q.is_empty() {
                    // more work waiting: wake a sibling lane before we
                    // leave for the backend call
                    self.cv.notify_one();
                }
                return Some(pending);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop admitting; lanes drain what is queued and exit.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Fail-stop: close *and* drop everything queued, so blocked clients
    /// observe [`InferError::Stopped`] instead of hanging. Used when a
    /// lane's backend errors.
    fn fail(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        st.q.clear(); // dropping a Request drops its reply sender
        drop(st);
        self.cv.notify_all();
    }

    fn rejected(&self) -> u64 {
        self.state.lock().unwrap().rejected
    }
}

/// Server handle for submitting requests (cheap to clone; one per client
/// thread).
#[derive(Clone)]
pub struct Client {
    queue: Arc<AdmissionQueue>,
    image_elems: usize,
}

/// An admitted request's in-flight reply (oneshot).
pub struct PendingReply(mpsc::Receiver<Reply>);

impl PendingReply {
    /// Block until the serving lane replies.
    pub fn wait(self) -> Result<Reply, InferError> {
        self.0.recv().map_err(|_| InferError::Stopped)
    }
}

impl Client {
    /// Submit without waiting: the admission decision is immediate — a
    /// full queue returns [`InferError::Rejected`], an admitted request
    /// returns a [`PendingReply`] to wait on.
    pub fn submit(&self, image: Vec<f32>) -> Result<PendingReply, InferError> {
        assert_eq!(image.len(), self.image_elems, "image size");
        let (reply_tx, reply_rx) = mpsc::channel();
        self.queue.submit(Request { image, reply: reply_tx, submitted: Instant::now() })?;
        Ok(PendingReply(reply_rx))
    }

    /// Blocking inference call ([`submit`](Client::submit) + wait).
    pub fn infer(&self, image: Vec<f32>) -> Result<Reply, InferError> {
        self.submit(image)?.wait()
    }
}

// ---------------------------------------------------------------------------
// Latency reservoir + stats
// ---------------------------------------------------------------------------

/// Default sample capacity of the latency [`Reservoir`]: 4096 `f64`s =
/// 32 KiB, enough for stable tail percentiles, constant forever.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Bounded uniform reservoir sample (Vitter's Algorithm R): after any
/// number of `push`es it holds a uniform random sample of at most `cap`
/// of the values seen, so a long-lived server keeps O(1) stats memory
/// while percentiles stay representative. Deterministically seeded — two
/// servers fed the same stream report the same percentiles.
#[derive(Clone, Debug)]
pub struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    cap: usize,
    rng: Pcg32,
}

/// Algorithm R's replacement draw at 1-based stream position `seen`:
/// uniform in `[0, seen)`. Both branches are bias-free — the wide branch
/// uses [`Pcg32::below_u64`]; a plain `next_u64() % seen` would skew the
/// retained sample toward low replacement slots once `seen` stops
/// dividing `2^64`.
fn replacement_index(rng: &mut Pcg32, seen: u64) -> u64 {
    if seen <= u32::MAX as u64 {
        rng.below(seen as u32) as u64
    } else {
        rng.below_u64(seen)
    }
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir { samples: Vec::new(), seen: 0, cap, rng: Pcg32::new(0x5EED, 0x4E5) }
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
            return;
        }
        // replace a random slot with probability cap/seen (Algorithm R)
        let j = replacement_index(&mut self.rng, self.seen);
        if (j as usize) < self.cap {
            self.samples[j as usize] = v;
        }
    }

    /// Merge another reservoir into this one (same `cap`) so the result
    /// approximates a uniform sample of the concatenated streams: when
    /// the union fits, concatenate; otherwise interleave draws-without-
    /// replacement weighted by how many stream values each retained
    /// sample represents (`seen / len`). Deterministic: the merge RNG is
    /// seeded from the two `seen` counts, so equal per-lane stats always
    /// produce the same aggregate.
    pub fn merge(&mut self, other: &Reservoir) {
        assert_eq!(self.cap, other.cap, "reservoir capacity mismatch");
        if other.seen == 0 {
            return;
        }
        let total = self.seen + other.seen;
        if self.samples.len() + other.samples.len() <= self.cap {
            self.samples.extend_from_slice(&other.samples);
            self.seen = total;
            return;
        }
        let mut rng =
            Pcg32::new(self.seen.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ other.seen, 0x4D52);
        let mut a = std::mem::take(&mut self.samples);
        let mut b = other.samples.clone();
        // per-sample stream weight: each retained value stands for
        // seen/len values of its lane's stream
        let wa = self.seen as f64 / a.len().max(1) as f64;
        let wb = other.seen as f64 / b.len().max(1) as f64;
        let mut merged = Vec::with_capacity(self.cap);
        while merged.len() < self.cap && (!a.is_empty() || !b.is_empty()) {
            let ta = a.len() as f64 * wa;
            let tb = b.len() as f64 * wb;
            let from_a = if b.is_empty() {
                true
            } else if a.is_empty() {
                false
            } else {
                (rng.uniform() as f64) * (ta + tb) < ta
            };
            let src = if from_a { &mut a } else { &mut b };
            let i = rng.below(src.len() as u32) as usize;
            merged.push(src.swap_remove(i));
        }
        self.samples = merged;
        self.seen = total;
    }

    /// Total values offered (not the retained sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The retained sample (≤ cap values, unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Percentile over the retained sample; exact until `cap` values have
    /// been seen, an unbiased estimate after. 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            ustats::percentile(&self.samples, p)
        }
    }
}

/// Server statistics — O(1) memory regardless of lifetime: latencies go
/// into a bounded [`Reservoir`] plus exact streaming sum/max accumulators,
/// batch fills into a streaming sum. Per-lane instances merge into one
/// aggregate via [`Stats::merge`].
#[derive(Clone, Debug)]
pub struct Stats {
    pub requests: usize,
    pub batches: usize,
    /// submissions turned away by the bounded admission queue (aggregate
    /// only; per-lane stats report 0)
    pub rejected: u64,
    /// bounded latency sample, seconds (percentile queries)
    pub latencies: Reservoir,
    latency_sum_s: f64,
    latency_max_s: f64,
    fill_sum: u64,
}

impl Default for Stats {
    fn default() -> Stats {
        Stats {
            requests: 0,
            batches: 0,
            rejected: 0,
            latencies: Reservoir::new(LATENCY_RESERVOIR_CAP),
            latency_sum_s: 0.0,
            latency_max_s: 0.0,
            fill_sum: 0,
        }
    }
}

impl Stats {
    pub(crate) fn record_request(&mut self, latency_s: f64) {
        self.requests += 1;
        self.latencies.push(latency_s);
        self.latency_sum_s += latency_s;
        self.latency_max_s = self.latency_max_s.max(latency_s);
    }

    pub(crate) fn record_batch(&mut self, fill: usize) {
        self.batches += 1;
        self.fill_sum += fill as u64;
    }

    /// Fold another lane's stats into this aggregate: exact streaming
    /// fields are summed (max is maxed), reservoirs merged by the
    /// seen-weighted interleave of [`Reservoir::merge`]. Deterministic
    /// for a fixed merge order (lanes merge in lane order).
    pub fn merge(&mut self, other: &Stats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.rejected += other.rejected;
        self.latency_sum_s += other.latency_sum_s;
        self.latency_max_s = self.latency_max_s.max(other.latency_max_s);
        self.fill_sum += other.fill_sum;
        self.latencies.merge(&other.latencies);
    }

    /// Latency percentile in seconds (reservoir estimate; exact for the
    /// first [`LATENCY_RESERVOIR_CAP`] requests).
    pub fn latency_percentile_s(&self, p: f64) -> f64 {
        self.latencies.percentile(p)
    }

    /// Exact mean latency in seconds (streaming, not reservoir-based).
    pub fn mean_latency_s(&self) -> f64 {
        self.latency_sum_s / (self.requests.max(1)) as f64
    }

    /// Exact maximum latency in seconds.
    pub fn max_latency_s(&self) -> f64 {
        self.latency_max_s
    }

    /// Exact mean batch fill (real requests per executed batch).
    pub fn mean_fill(&self) -> f64 {
        self.fill_sum as f64 / (self.batches.max(1)) as f64
    }

    /// Reject rate over everything offered (accepted + rejected).
    pub fn reject_rate(&self) -> f64 {
        let offered = self.requests as u64 + self.rejected;
        if offered == 0 {
            0.0
        } else {
            self.rejected as f64 / offered as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Lanes + drivers
// ---------------------------------------------------------------------------

/// One worker lane: dynamic batching over an owned backend replica until
/// the queue closes and drains. Partial batches are padded by cycling
/// the real request images; padding rows are never replied to.
fn serve_lane(
    backend: &mut dyn InferBackend,
    queue: &AdmissionQueue,
    max_wait: Duration,
) -> Result<Stats> {
    let batch = backend.batch();
    let image_elems = backend.image_elems();
    let classes = backend.classes();
    let mut stats = Stats::default();
    let mut images: Vec<f32> = Vec::with_capacity(batch * image_elems);
    while let Some(pending) = queue.pop_batch(batch, max_wait) {
        let fill = pending.len();
        debug_assert!(fill > 0 && fill <= batch);
        images.clear();
        for r in &pending {
            images.extend_from_slice(&r.image);
        }
        // pad to the backend's fixed shape by cycling this batch's real
        // images — zero rows would corrupt batch-statistics batchnorm
        crate::data::pad_batch_by_cycling(&mut images, fill, batch, image_elems);
        let logits = backend.run_batch(&images)?;
        if logits.len() != batch * classes {
            anyhow::bail!(
                "{}: backend returned {} logits, expected {}",
                backend.describe(),
                logits.len(),
                batch * classes
            );
        }
        for (i, r) in pending.into_iter().enumerate() {
            let latency = r.submitted.elapsed();
            stats.record_request(latency.as_secs_f64());
            let _ = r.reply.send(Reply {
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency,
                batch_fill: fill,
            });
        }
        stats.record_batch(fill);
    }
    Ok(stats)
}

fn check_uniform_backends(backends: &[&dyn InferBackend]) -> Result<(usize, usize, usize)> {
    let first = backends.first().ok_or_else(|| anyhow!("serve_pool needs >= 1 backend"))?;
    let shape = (first.batch(), first.image_elems(), first.classes());
    for b in backends {
        let s = (b.batch(), b.image_elems(), b.classes());
        if s != shape {
            anyhow::bail!(
                "lane backends disagree on shape: {} is {:?}, {} is {:?}",
                backends[0].describe(),
                shape,
                b.describe(),
                s
            );
        }
    }
    Ok(shape)
}

/// Run an N-lane server (one lane per backend replica) while `load`
/// drives traffic through a [`Client`]; when `load` returns, admission
/// closes, the lanes drain the queue and exit. Returns the merged
/// aggregate [`Stats`] and `load`'s return value.
///
/// A lane whose backend errors fail-stops the server (admission closes,
/// queued requests observe [`InferError::Stopped`]) and the error is
/// returned after the remaining lanes wind down.
pub fn serve_pool<B, F, R>(
    backends: &mut [B],
    cfg: ServeConfig,
    load: F,
) -> Result<(Stats, R)>
where
    B: InferBackend + Send,
    F: FnOnce(Client) -> R + Send,
    R: Send,
{
    let (_, image_elems, _) = {
        let refs: Vec<&dyn InferBackend> =
            backends.iter().map(|b| b as &dyn InferBackend).collect();
        check_uniform_backends(&refs)?
    };
    // Warm the persistent kernel worker pool (and the tiled-GEMM pack
    // buffers, best effort) once, before any lane spawns: first-request
    // latency never includes thread spawning, and all lanes' CPU kernel
    // work shares the one pool.
    crate::kernels::gemm::warm_tiled();
    let queue = Arc::new(AdmissionQueue::new(cfg.queue_depth));
    let client = Client { queue: Arc::clone(&queue), image_elems };
    let max_wait = cfg.max_wait;
    std::thread::scope(|s| -> Result<(Stats, R)> {
        let lanes: Vec<_> = backends
            .iter_mut()
            .map(|b| {
                let queue = &queue;
                s.spawn(move || {
                    let r = serve_lane(b, queue, max_wait);
                    if r.is_err() {
                        queue.fail();
                    }
                    r
                })
            })
            .collect();
        let loader = s.spawn(move || load(client));
        // close before joining the lanes, else they would wait on the
        // queue forever (and the scope's implicit join with them)
        let load_res = loader.join();
        queue.close();
        // a lane's backend error is the root cause — report it in
        // preference to the load-side panic it usually triggers (clients
        // observing Stopped tend to unwrap)
        let mut agg = Stats::default();
        let mut first_err = None;
        for lane in lanes {
            match lane.join() {
                Ok(Ok(lane_stats)) => agg.merge(&lane_stats),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => first_err = first_err.or(Some(anyhow!("serving lane panicked"))),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let load_out = load_res.map_err(|_| anyhow!("load thread panicked"))?;
        agg.rejected += queue.rejected();
        Ok((agg, load_out))
    })
}

/// Single-lane variant for backends that must stay on the caller's
/// thread (the PJRT client is not `Send`): the lane loop runs *here*
/// while `load` drives traffic from a spawned thread. Same admission,
/// batching, padding and stats semantics as one [`serve_pool`] lane.
pub fn serve_on_caller<F, R>(
    backend: &mut dyn InferBackend,
    cfg: ServeConfig,
    load: F,
) -> Result<(Stats, R)>
where
    F: FnOnce(Client) -> R + Send,
    R: Send,
{
    crate::kernels::gemm::warm_tiled();
    let queue = Arc::new(AdmissionQueue::new(cfg.queue_depth));
    let client = Client { queue: Arc::clone(&queue), image_elems: backend.image_elems() };
    let max_wait = cfg.max_wait;
    std::thread::scope(|s| -> Result<(Stats, R)> {
        let close_queue = Arc::clone(&queue);
        let loader = s.spawn(move || {
            // close on every exit path (panic included): the caller
            // thread is inside serve_lane and only a closed, drained
            // queue lets it return
            struct CloseOnDrop(Arc<AdmissionQueue>);
            impl Drop for CloseOnDrop {
                fn drop(&mut self) {
                    self.0.close();
                }
            }
            let _close = CloseOnDrop(close_queue);
            load(client)
        });
        let served = serve_lane(backend, &queue, max_wait);
        if served.is_err() {
            queue.fail(); // unblock clients waiting on queued requests
        }
        let load_res = loader.join();
        // the backend error is the root cause — report it in preference
        // to the load-side panic it usually triggers
        let mut stats = served?;
        let load_out = load_res.map_err(|_| anyhow!("load thread panicked"))?;
        stats.rejected += queue.rejected();
        Ok((stats, load_out))
    })
}

/// Classify a reply against a label (test helper + example metric).
pub fn reply_correct(reply: &Reply, label: u32) -> bool {
    accuracy_from_logits(&reply.logits, &[label], reply.logits.len()) > 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_is_bounded_and_exact_below_cap() {
        let mut r = Reservoir::new(8);
        for i in 0..5 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 5);
        assert_eq!(r.samples().len(), 5);
        // below cap the sample is the full stream: percentiles are exact
        assert_eq!(r.percentile(0.0), 0.0);
        assert_eq!(r.percentile(100.0), 4.0);
        assert_eq!(r.percentile(50.0), 2.0);
        for i in 5..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.seen(), 10_000);
        assert_eq!(r.samples().len(), 8, "memory stays bounded at cap");
        for &s in r.samples() {
            assert!((0.0..10_000.0).contains(&s));
        }
    }

    #[test]
    fn reservoir_sample_stays_representative() {
        // push a stream whose median is ~500; the reservoir median of a
        // 256-sample reservoir must land in the right region
        let mut r = Reservoir::new(256);
        for i in 0..100_000u64 {
            r.push((i % 1000) as f64);
        }
        let p50 = r.percentile(50.0);
        assert!((300.0..700.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn replacement_index_is_in_range_both_branches() {
        let mut rng = Pcg32::seeded(21);
        // 32-bit branch: in range and covering
        let mut seen_slot = [false; 7];
        for _ in 0..500 {
            let j = replacement_index(&mut rng, 7);
            assert!(j < 7);
            seen_slot[j as usize] = true;
        }
        assert!(seen_slot.iter().all(|&s| s));
        // wide branch (seen > u32::MAX): bias-free bounded draw, in range
        let wide = u32::MAX as u64 * 2 + 3;
        for _ in 0..500 {
            assert!(replacement_index(&mut rng, wide) < wide);
        }
        // the wide branch still lands replacements inside a cap-sized
        // prefix sometimes over many draws (probability cap/seen each);
        // just assert determinism across identically-seeded rngs
        let mut r1 = Pcg32::seeded(5);
        let mut r2 = Pcg32::seeded(5);
        for _ in 0..100 {
            assert_eq!(replacement_index(&mut r1, wide), replacement_index(&mut r2, wide));
        }
    }

    #[test]
    fn reservoir_merge_invariants() {
        // below combined cap: exact concatenation
        let mut a = Reservoir::new(16);
        let mut b = Reservoir::new(16);
        for i in 0..6 {
            a.push(i as f64);
        }
        for i in 100..106 {
            b.push(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.seen(), 12);
        assert_eq!(a.samples().len(), 12);
        // above cap: bounded, seen counts sum, all values from the union
        let mut a = Reservoir::new(32);
        let mut b = Reservoir::new(32);
        for i in 0..1000 {
            a.push(i as f64);
        }
        for i in 1000..4000 {
            b.push(i as f64);
        }
        let mut a2 = a.clone();
        a.merge(&b);
        assert_eq!(a.seen(), 4000);
        assert_eq!(a.samples().len(), 32);
        for &v in a.samples() {
            assert!((0.0..4000.0).contains(&v));
        }
        // weighted interleave: b's stream is 3x a's, so most retained
        // samples come from b's range
        let from_b = a.samples().iter().filter(|&&v| v >= 1000.0).count();
        assert!(from_b > 16, "seen-weighting lost: {from_b}/32 from the 3x stream");
        // deterministic: merging identical inputs twice gives identical
        // samples
        a2.merge(&b);
        assert_eq!(a.samples(), a2.samples());
        // merging an empty reservoir is a no-op
        let before = a.samples().to_vec();
        a.merge(&Reservoir::new(32));
        assert_eq!(a.samples(), &before[..]);
        assert_eq!(a.seen(), 4000);
    }

    #[test]
    fn stats_merge_sums_streaming_fields_exactly() {
        let mut a = Stats::default();
        let mut b = Stats::default();
        for i in 0..10 {
            a.record_request(0.010 + i as f64 * 1e-4);
        }
        a.record_batch(10);
        for i in 0..6 {
            b.record_request(0.020 + i as f64 * 1e-4);
        }
        b.record_batch(4);
        b.record_batch(2);
        b.rejected = 3;
        let sum_all = a.latency_sum_s + b.latency_sum_s;
        a.merge(&b);
        assert_eq!(a.requests, 16);
        assert_eq!(a.batches, 3);
        assert_eq!(a.rejected, 3);
        assert_eq!(a.fill_sum, 16, "every request lands in exactly one batch");
        assert!((a.mean_latency_s() - sum_all / 16.0).abs() < 1e-15);
        assert!((a.max_latency_s() - 0.0205).abs() < 1e-12);
        assert_eq!(a.latencies.seen(), 16);
        let offered = 16.0 + 3.0;
        assert!((a.reject_rate() - 3.0 / offered).abs() < 1e-15);
    }

    #[test]
    fn stats_memory_is_constant_and_report_fields_exact() {
        let n = LATENCY_RESERVOIR_CAP * 3;
        let mut s = Stats::default();
        let mut sum = 0.0f64;
        for i in 0..n {
            let v = 0.001 * (i % 100) as f64;
            s.record_request(v);
            sum += v;
            if i % 4 == 3 {
                s.record_batch(4);
            }
        }
        assert_eq!(s.requests, n);
        assert_eq!(s.latencies.samples().len(), LATENCY_RESERVOIR_CAP);
        // streaming fields are exact regardless of the reservoir
        assert_eq!(s.batches, n / 4);
        assert_eq!(s.mean_fill(), 4.0);
        assert!((s.max_latency_s() - 0.099).abs() < 1e-12);
        assert!((s.mean_latency_s() - sum / n as f64).abs() < 1e-12);
        // empty stats report zeros, not NaN/panic
        let empty = Stats::default();
        assert_eq!(empty.latency_percentile_s(99.0), 0.0);
        assert_eq!(empty.mean_latency_s(), 0.0);
        assert_eq!(empty.mean_fill(), 0.0);
        assert_eq!(empty.reject_rate(), 0.0);
    }
}
