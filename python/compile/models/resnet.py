"""ResNet-18/34/50 (He et al. [16]) with AMCONV2D/AMDENSE layers.

Faithful block structure (basic blocks for 18/34, bottlenecks for 50) with
a `width` scaling knob: the paper trains full-width ResNets on a V100
cluster; this reproduction defaults to width=8 ("tiny") so the interpret-
mode Pallas stack trains in CPU-feasible time (DESIGN.md §Substitutions #3).
Depth ordering and block topology are unchanged.
"""

from __future__ import annotations

from .. import layers
from .base import Model, bn_specs, conv_spec, dense_specs


def _basic_block(specs, prefix, c_in, c_out, stride):
    specs += [conv_spec(f"{prefix}/conv1/w", 3, 3, c_in, c_out)]
    specs += bn_specs(f"{prefix}/bn1", c_out)
    specs += [conv_spec(f"{prefix}/conv2/w", 3, 3, c_out, c_out)]
    specs += bn_specs(f"{prefix}/bn2", c_out)
    if stride != 1 or c_in != c_out:
        specs += [conv_spec(f"{prefix}/down/w", 1, 1, c_in, c_out)]
        specs += bn_specs(f"{prefix}/downbn", c_out)


def _bottleneck_block(specs, prefix, c_in, c_mid, stride):
    c_out = 4 * c_mid
    specs += [conv_spec(f"{prefix}/conv1/w", 1, 1, c_in, c_mid)]
    specs += bn_specs(f"{prefix}/bn1", c_mid)
    specs += [conv_spec(f"{prefix}/conv2/w", 3, 3, c_mid, c_mid)]
    specs += bn_specs(f"{prefix}/bn2", c_mid)
    specs += [conv_spec(f"{prefix}/conv3/w", 1, 1, c_mid, c_out)]
    specs += bn_specs(f"{prefix}/bn3", c_out)
    if stride != 1 or c_in != c_out:
        specs += [conv_spec(f"{prefix}/down/w", 1, 1, c_in, c_out)]
        specs += bn_specs(f"{prefix}/downbn", c_out)


def _apply_basic(cfg, p, x, lut, prefix, c_in, c_out, stride):
    y = layers.amconv2d(cfg, x, p[f"{prefix}/conv1/w"], stride, 1, lut)
    y = layers.relu(layers.batchnorm(y, p[f"{prefix}/bn1/gamma"], p[f"{prefix}/bn1/beta"]))
    y = layers.amconv2d(cfg, y, p[f"{prefix}/conv2/w"], 1, 1, lut)
    y = layers.batchnorm(y, p[f"{prefix}/bn2/gamma"], p[f"{prefix}/bn2/beta"])
    if stride != 1 or c_in != c_out:
        x = layers.amconv2d(cfg, x, p[f"{prefix}/down/w"], stride, 0, lut)
        x = layers.batchnorm(x, p[f"{prefix}/downbn/gamma"], p[f"{prefix}/downbn/beta"])
    return layers.relu(x + y)


def _apply_bottleneck(cfg, p, x, lut, prefix, c_in, c_mid, stride):
    c_out = 4 * c_mid
    y = layers.amconv2d(cfg, x, p[f"{prefix}/conv1/w"], 1, 0, lut)
    y = layers.relu(layers.batchnorm(y, p[f"{prefix}/bn1/gamma"], p[f"{prefix}/bn1/beta"]))
    y = layers.amconv2d(cfg, y, p[f"{prefix}/conv2/w"], stride, 1, lut)
    y = layers.relu(layers.batchnorm(y, p[f"{prefix}/bn2/gamma"], p[f"{prefix}/bn2/beta"]))
    y = layers.amconv2d(cfg, y, p[f"{prefix}/conv3/w"], 1, 0, lut)
    y = layers.batchnorm(y, p[f"{prefix}/bn3/gamma"], p[f"{prefix}/bn3/beta"])
    if stride != 1 or c_in != c_out:
        x = layers.amconv2d(cfg, x, p[f"{prefix}/down/w"], stride, 0, lut)
        x = layers.batchnorm(x, p[f"{prefix}/downbn/gamma"], p[f"{prefix}/downbn/beta"])
    return layers.relu(x + y)


def _resnet(name, stages, bottleneck, input_shape, classes, width):
    h, w, c = input_shape
    specs = [conv_spec("stem/w", 3, 3, c, width)]
    specs += bn_specs("stembn", width)
    plan = []  # (prefix, c_in, c_mid_or_out, stride)
    c_in = width
    for si, n_blocks in enumerate(stages):
        c_stage = width * (2 ** si)
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            prefix = f"s{si}b{bi}"
            if bottleneck:
                _bottleneck_block(specs, prefix, c_in, c_stage, stride)
                c_in = 4 * c_stage
            else:
                _basic_block(specs, prefix, c_in, c_stage, stride)
                c_in = c_stage
            plan.append((prefix, stride))
    specs += dense_specs("fc", c_in, classes)

    def apply(cfg, p, x, lut):
        x = layers.amconv2d(cfg, x, p["stem/w"], 1, 1, lut)
        x = layers.relu(layers.batchnorm(x, p["stembn/gamma"], p["stembn/beta"]))
        ci = width
        for si, n_blocks in enumerate(stages):
            c_stage = width * (2 ** si)
            for bi in range(n_blocks):
                stride = 2 if (si > 0 and bi == 0) else 1
                prefix = f"s{si}b{bi}"
                if bottleneck:
                    x = _apply_bottleneck(cfg, p, x, lut, prefix, ci, c_stage, stride)
                    ci = 4 * c_stage
                else:
                    x = _apply_basic(cfg, p, x, lut, prefix, ci, c_stage, stride)
                    ci = c_stage
        x = layers.global_avgpool(x)
        return layers.amdense(cfg, x, p["fc/w"], p["fc/b"], lut)

    return Model(name, input_shape, classes, specs, apply)


def resnet18(input_shape=(16, 16, 3), classes=10, width=8) -> Model:
    return _resnet("resnet18", [2, 2, 2, 2], False, input_shape, classes, width)


def resnet34(input_shape=(16, 16, 3), classes=10, width=8) -> Model:
    return _resnet("resnet34", [3, 4, 6, 3], False, input_shape, classes, width)


def resnet50(input_shape=(16, 16, 3), classes=10, width=8) -> Model:
    return _resnet("resnet50", [3, 4, 6, 3], True, input_shape, classes, width)
