//! AMCONV2D — the approximate Conv2D op (paper §VI-B): forward and both
//! backward gradients via the IM2COL+GEMM restructuring, with fused
//! dilation in the weight gradient and fused pad+dilate plus a
//! transpose-and-reverse pre-pass in the preceding-layer gradient.
//!
//! All three GEMMs run as **implicit GEMMs** ([`gemm_auto_src`]): the
//! tiled kernel packs its `MC x KC` panels *directly from the NHWC
//! tensors* through the im2col panel sources
//! ([`crate::kernels::im2col`]), so no `col_rows x col_cols` matrix is
//! ever materialized — conv memory overhead drops from `O(cols)` to
//! `O(tile)` and the steady-state forward/backward packs through the
//! recycled per-thread buffers with no per-call cols allocation. Tiles
//! are drained by the register-blocked `MR x NR` micro-kernel
//! ([`crate::kernels::MulBackend::mul_microtile`]): the implicit im2col
//! panels feed its `A` side unchanged, while the weight/error `B`
//! operands are packed into `NR`-wide interleaved strips by [`SliceB`]. The
//! pre-fusion route is kept as [`forward_materialized`] /
//! [`weight_grad_materialized`] / [`input_grad_materialized`] — the
//! oracle and bench comparison partner (`bench-conv`).
//!
//! Outputs are bit-identical between the implicit and materialized
//! routes, regardless of lane count and tile geometry, and bit-identical
//! to [`crate::kernels::gemm::gemm_scalar_reference`] run over the
//! materialized im2col matrices (`tests/conv_grads.rs`).

use crate::kernels::gemm::{gemm_auto, gemm_auto_src, SliceB};
use crate::kernels::im2col::{
    im2col_forward, im2col_plg, im2col_weight_grad, Im2colForwardSrc, Im2colPlgSrc,
    Im2colWeightGradSrc,
};
use crate::kernels::transpose_reverse::{transpose_reverse, transpose_reverse_into};
use crate::kernels::{with_scratch, Conv2dGeom, MulKernel};
use crate::tensor::Tensor;

/// Forward propagation (paper Alg. 3): `y = conv2d(x, w)` with NHWC input
/// `[b, h, w, c]` and HWIO filter `[kh, kw, c, oc]`. Implicit GEMM:
/// `y = im2col(x) * w` with the im2col packed on the fly.
pub fn forward(mul: &MulKernel, x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Tensor {
    let g = geom(x, w, stride, pad);
    let mut y = Tensor::zeros(&[g.batch, g.out_h(), g.out_w(), g.out_c]);
    gemm_auto_src(
        mul,
        &Im2colForwardSrc::new(&g, &x.data),
        &SliceB { data: &w.data, n: g.out_c },
        &mut y.data,
        g.col_rows(),
        g.col_cols(),
        g.out_c,
    );
    y
}

/// [`forward`] through the pre-fusion materialized-im2col route (full
/// cols matrix + slice GEMM) — bit-identical oracle / bench partner.
pub fn forward_materialized(
    mul: &MulKernel,
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    pad: usize,
) -> Tensor {
    let g = geom(x, w, stride, pad);
    let mut cols = vec![0.0f32; g.col_rows() * g.col_cols()];
    im2col_forward(&g, &x.data, &mut cols);
    let mut y = Tensor::zeros(&[g.batch, g.out_h(), g.out_w(), g.out_c]);
    gemm_auto(mul, &cols, &w.data, &mut y.data, g.col_rows(), g.col_cols(), g.out_c);
    y
}

/// Weight gradient (paper Alg. 4 lines 4-5): `dw[kh, kw, c, oc]` from the
/// layer input `x` and the back-propagated error `dy`, with the dilation of
/// `dy` fused into the (implicitly packed) im2col indexing.
pub fn weight_grad(
    mul: &MulKernel,
    x: &Tensor,
    dy: &Tensor,
    w_shape: &[usize],
    stride: usize,
    pad: usize,
) -> Tensor {
    let g = wg_geom(x, dy, w_shape, stride, pad);
    let q = g.batch * g.out_h() * g.out_w();
    let mut dw = Tensor::zeros(w_shape);
    gemm_auto_src(
        mul,
        &Im2colWeightGradSrc::new(&g, &x.data),
        &SliceB { data: &dy.data, n: g.out_c },
        &mut dw.data,
        g.col_cols(),
        q,
        g.out_c,
    );
    dw
}

/// [`weight_grad`] through the materialized-im2col route — bit-identical
/// oracle / bench partner.
pub fn weight_grad_materialized(
    mul: &MulKernel,
    x: &Tensor,
    dy: &Tensor,
    w_shape: &[usize],
    stride: usize,
    pad: usize,
) -> Tensor {
    let g = wg_geom(x, dy, w_shape, stride, pad);
    let q = g.batch * g.out_h() * g.out_w();
    let mut cols = vec![0.0f32; g.col_cols() * q];
    im2col_weight_grad(&g, &x.data, &mut cols);
    let mut dw = Tensor::zeros(w_shape);
    gemm_auto(mul, &cols, &dy.data, &mut dw.data, g.col_cols(), q, g.out_c);
    dw
}

/// Preceding-layer gradient (paper Alg. 4 lines 6-8): `dx[b, h, w, c]` via
/// fused pad+dilate im2col of `dy` (packed implicitly) and a GEMM against
/// the transposed-and-reversed weights (built once per call into the
/// recycled scratch — paper §VI-B.2: a separate rearranging pass is worth
/// it for coalesced GEMM reads).
pub fn input_grad(
    mul: &MulKernel,
    dy: &Tensor,
    w: &Tensor,
    x_shape: &[usize],
    stride: usize,
    pad: usize,
) -> Tensor {
    let g = plg_geom(dy, w, x_shape, stride, pad);
    let rows = g.batch * g.in_h * g.in_w;
    let rlen = g.k_h * g.k_w * g.out_c;
    let mut dx = Tensor::zeros(x_shape);
    with_scratch(w.data.len(), |wrt| {
        transpose_reverse_into(&w.data, g.k_h, g.k_w, g.in_c, g.out_c, wrt);
        gemm_auto_src(
            mul,
            &Im2colPlgSrc::new(&g, &dy.data),
            &SliceB { data: wrt, n: g.in_c },
            &mut dx.data,
            rows,
            rlen,
            g.in_c,
        );
    });
    dx
}

/// [`input_grad`] through the materialized-im2col route — bit-identical
/// oracle / bench partner.
pub fn input_grad_materialized(
    mul: &MulKernel,
    dy: &Tensor,
    w: &Tensor,
    x_shape: &[usize],
    stride: usize,
    pad: usize,
) -> Tensor {
    let g = plg_geom(dy, w, x_shape, stride, pad);
    let rows = g.batch * g.in_h * g.in_w;
    let rlen = g.k_h * g.k_w * g.out_c;
    let mut cols = vec![0.0f32; rows * rlen];
    im2col_plg(&g, &dy.data, &mut cols);
    let wrt = transpose_reverse(&w.data, g.k_h, g.k_w, g.in_c, g.out_c);
    let mut dx = Tensor::zeros(x_shape);
    gemm_auto(mul, &cols, &wrt, &mut dx.data, rows, rlen, g.in_c);
    dx
}

fn wg_geom(x: &Tensor, dy: &Tensor, w_shape: &[usize], stride: usize, pad: usize) -> Conv2dGeom {
    let g = Conv2dGeom {
        batch: x.shape[0],
        in_h: x.shape[1],
        in_w: x.shape[2],
        in_c: x.shape[3],
        k_h: w_shape[0],
        k_w: w_shape[1],
        out_c: w_shape[3],
        stride,
        pad,
    };
    debug_assert_eq!(dy.shape, vec![g.batch, g.out_h(), g.out_w(), g.out_c]);
    g
}

fn plg_geom(dy: &Tensor, w: &Tensor, x_shape: &[usize], stride: usize, pad: usize) -> Conv2dGeom {
    let g = Conv2dGeom {
        batch: x_shape[0],
        in_h: x_shape[1],
        in_w: x_shape[2],
        in_c: x_shape[3],
        k_h: w.shape[0],
        k_w: w.shape[1],
        out_c: w.shape[3],
        stride,
        pad,
    };
    debug_assert_eq!(dy.shape, vec![g.batch, g.out_h(), g.out_w(), g.out_c]);
    g
}

fn geom(x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Conv2dGeom {
    assert_eq!(x.rank(), 4, "input must be NHWC");
    assert_eq!(w.rank(), 4, "filter must be HWIO");
    assert_eq!(x.shape[3], w.shape[2], "channel mismatch");
    Conv2dGeom {
        batch: x.shape[0],
        in_h: x.shape[1],
        in_w: x.shape[2],
        in_c: x.shape[3],
        k_h: w.shape[0],
        k_w: w.shape[1],
        out_c: w.shape[3],
        stride,
        pad,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_tensor(shape: &[usize], rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range(-1.0, 1.0)).collect())
    }

    /// Direct (definition-based) convolution for validation.
    fn conv_ref(x: &Tensor, w: &Tensor, stride: usize, pad: usize) -> Tensor {
        let g = geom(x, w, stride, pad);
        let (oh, ow) = (g.out_h(), g.out_w());
        let mut y = Tensor::zeros(&[g.batch, oh, ow, g.out_c]);
        for b in 0..g.batch {
            for oy in 0..oh {
                for ox in 0..ow {
                    for oc in 0..g.out_c {
                        let mut acc = 0.0;
                        for ky in 0..g.k_h {
                            for kx in 0..g.k_w {
                                let iy = (oy * stride + ky) as isize - pad as isize;
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy >= g.in_h as isize
                                    || ix >= g.in_w as isize
                                {
                                    continue;
                                }
                                for c in 0..g.in_c {
                                    acc += x.at4(b, iy as usize, ix as usize, c)
                                        * w.data[((ky * g.k_w + kx) * g.in_c + c) * g.out_c
                                            + oc];
                                }
                            }
                        }
                        y.data[((b * oh + oy) * ow + ox) * g.out_c + oc] = acc;
                    }
                }
            }
        }
        y
    }

    /// Smoke version of the implicit-vs-materialized contract (the full
    /// stride/pad/strategy/tile sweep lives in `tests/conv_grads.rs`).
    #[test]
    fn implicit_route_matches_materialized_bitwise() {
        let mut rng = Pcg32::seeded(64);
        for (stride, pad) in [(1, 0), (2, 1)] {
            let x = rand_tensor(&[2, 7, 9, 3], &mut rng);
            let w = rand_tensor(&[3, 3, 3, 4], &mut rng);
            let mul = MulKernel::Native;
            let y = forward(&mul, &x, &w, stride, pad);
            let y_m = forward_materialized(&mul, &x, &w, stride, pad);
            assert_eq!(y.shape, y_m.shape);
            let dy = rand_tensor(&y.shape, &mut rng);
            let dw = weight_grad(&mul, &x, &dy, &w.shape, stride, pad);
            let dw_m = weight_grad_materialized(&mul, &x, &dy, &w.shape, stride, pad);
            let dx = input_grad(&mul, &dy, &w, &x.shape, stride, pad);
            let dx_m = input_grad_materialized(&mul, &dy, &w, &x.shape, stride, pad);
            for (got, want, what) in
                [(&y, &y_m, "fwd"), (&dw, &dw_m, "dw"), (&dx, &dx_m, "dx")]
            {
                for i in 0..want.len() {
                    assert_eq!(
                        got.data[i].to_bits(),
                        want.data[i].to_bits(),
                        "{what} s{stride}p{pad} idx {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_matches_definition() {
        let mut rng = Pcg32::seeded(61);
        for (stride, pad) in [(1, 0), (1, 1), (2, 1), (2, 0)] {
            let x = rand_tensor(&[2, 8, 8, 3], &mut rng);
            let w = rand_tensor(&[3, 3, 3, 5], &mut rng);
            let y = forward(&MulKernel::Native, &x, &w, stride, pad);
            let y_ref = conv_ref(&x, &w, stride, pad);
            assert_eq!(y.shape, y_ref.shape);
            assert!(
                y.max_abs_diff(&y_ref) < 1e-4,
                "stride {stride} pad {pad}: diff {}",
                y.max_abs_diff(&y_ref)
            );
        }
    }

    /// Finite-difference gradient check of both backward kernels.
    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Pcg32::seeded(62);
        for (stride, pad) in [(1, 1), (2, 1)] {
            let x = rand_tensor(&[1, 6, 6, 2], &mut rng);
            let w = rand_tensor(&[3, 3, 2, 3], &mut rng);
            let y = forward(&MulKernel::Native, &x, &w, stride, pad);
            let dy = rand_tensor(&y.shape, &mut rng);
            let dw = weight_grad(&MulKernel::Native, &x, &dy, &w.shape, stride, pad);
            let dx = input_grad(&MulKernel::Native, &dy, &w, &x.shape, stride, pad);

            let loss = |x: &Tensor, w: &Tensor| -> f32 {
                let y = forward(&MulKernel::Native, x, w, stride, pad);
                y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
            };
            let eps = 1e-2;
            for i in (0..w.len()).step_by(7) {
                let mut wp = w.clone();
                wp.data[i] += eps;
                let mut wm = w.clone();
                wm.data[i] -= eps;
                let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
                assert!(
                    (num - dw.data[i]).abs() < 2e-2,
                    "stride {stride}: dw[{i}] {num} vs {}",
                    dw.data[i]
                );
            }
            for i in (0..x.len()).step_by(5) {
                let mut xp = x.clone();
                xp.data[i] += eps;
                let mut xm = x.clone();
                xm.data[i] -= eps;
                let num = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
                assert!(
                    (num - dx.data[i]).abs() < 2e-2,
                    "stride {stride}: dx[{i}] {num} vs {}",
                    dx.data[i]
                );
            }
        }
    }

    /// LUT path and direct path must agree bit-for-bit through a whole conv
    /// layer (forward + both gradients).
    #[test]
    fn lut_equals_direct_through_layer() {
        use crate::amsim::AmSim;
        use crate::lut::MantissaLut;
        use crate::mult::fpbits::quantize_mantissa;
        use crate::mult::registry;
        let model = registry::by_name("mit16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let mut rng = Pcg32::seeded(63);
        let mut q = |shape: &[usize]| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(
                shape,
                (0..n).map(|_| quantize_mantissa(rng.range(-1.0, 1.0), 7)).collect(),
            )
        };
        let x = q(&[1, 6, 6, 2]);
        let w = q(&[3, 3, 2, 3]);
        let direct = MulKernel::Direct(model.as_ref());
        let lut_k = MulKernel::Lut(AmSim::new(&lut));
        let y_d = forward(&direct, &x, &w, 1, 1);
        let y_l = forward(&lut_k, &x, &w, 1, 1);
        for i in 0..y_d.len() {
            assert_eq!(y_d.data[i].to_bits(), y_l.data[i].to_bits(), "fwd idx {i}");
        }
        let dy = q(&y_d.shape);
        let dw_d = weight_grad(&direct, &x, &dy, &w.shape, 1, 1);
        let dw_l = weight_grad(&lut_k, &x, &dy, &w.shape, 1, 1);
        for i in 0..dw_d.len() {
            assert_eq!(dw_d.data[i].to_bits(), dw_l.data[i].to_bits(), "dw idx {i}");
        }
        let dx_d = input_grad(&direct, &dy, &w, &x.shape, 1, 1);
        let dx_l = input_grad(&lut_k, &dy, &w, &x.shape, 1, 1);
        for i in 0..dx_d.len() {
            assert_eq!(dx_d.data[i].to_bits(), dx_l.data[i].to_bits(), "dx idx {i}");
        }
    }
}
