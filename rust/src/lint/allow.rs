//! Parsers for the two checked-in allowlist files.
//!
//! Both formats are line-oriented: `#` comments and blank lines are
//! skipped, fields are `|`-separated. The atomics key field is the
//! scrubbed source line with **all whitespace removed**
//! ([`crate::lint::lexer::normalize_line`]) — whitespace-free keys make
//! the grammar unambiguous and let a reviewer regenerate an entry by
//! hand, without a toolchain, straight from the diff hunk.

/// One `rust/lint/atomics.allow` entry: a reviewed `Ordering::` site.
pub struct AtomicsEntry {
    /// Line number inside the allowlist file (for findings about it).
    pub line: usize,
    /// Repo-relative path of the source file.
    pub path: String,
    /// Whitespace-free normalized source line.
    pub key: String,
    /// One-line justification of the memory ordering.
    pub why: String,
}

/// One `rust/lint/accum.allow` entry: an audited accumulator module.
pub struct AccumEntry {
    pub line: usize,
    pub path: String,
    pub why: String,
}

/// Parse `atomics.allow`. Errors carry the offending line number.
pub fn parse_atomics(text: &str) -> Result<Vec<AtomicsEntry>, (usize, String)> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = i + 1;
        let t = raw.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut parts = t.splitn(3, '|').map(str::trim);
        let (path, key, why) = (parts.next(), parts.next(), parts.next());
        match (path, key, why) {
            (Some(p), Some(k), Some(w)) if !p.is_empty() && !k.is_empty() && !w.is_empty() => {
                if k.chars().any(char::is_whitespace) {
                    return Err((line, "key field must be whitespace-free".to_string()));
                }
                out.push(AtomicsEntry {
                    line,
                    path: p.to_string(),
                    key: k.to_string(),
                    why: w.to_string(),
                });
            }
            _ => {
                return Err((
                    line,
                    "expected `path | normalized-line | justification`".to_string(),
                ))
            }
        }
    }
    Ok(out)
}

/// Parse `accum.allow`.
pub fn parse_accum(text: &str) -> Result<Vec<AccumEntry>, (usize, String)> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = i + 1;
        let t = raw.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut parts = t.splitn(2, '|').map(str::trim);
        match (parts.next(), parts.next()) {
            (Some(p), Some(w)) if !p.is_empty() && !w.is_empty() => {
                out.push(AccumEntry { line, path: p.to_string(), why: w.to_string() });
            }
            _ => return Err((line, "expected `path | justification`".to_string())),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomics_roundtrip_and_errors() {
        let ok = "# header\n\nrust/src/a.rs | x.load(Ordering::Acquire); | pairs with store\n";
        let es = parse_atomics(ok).unwrap();
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].path, "rust/src/a.rs");
        assert_eq!(es[0].key, "x.load(Ordering::Acquire);");
        assert_eq!(es[0].line, 3);
        assert!(parse_atomics("rust/src/a.rs | only-two-fields\n").is_err());
        assert!(parse_atomics("p | has space | why\n").is_err());
    }

    #[test]
    fn accum_roundtrip() {
        let es = parse_accum("rust/src/kernels/gemm.rs | audited chains\n").unwrap();
        assert_eq!(es.len(), 1);
        assert!(parse_accum("no-pipe-line\n").is_err());
    }
}
