"""Layer-2 approximate layers: ``amdense`` and ``amconv2d`` with full custom
backward passes (paper §VI-B/C) — the JAX equivalents of the paper's custom
TF ops AMDENSE / AMCONV2D.

All multiplications — forward *and* both backward gradients — go through
the L1 Pallas GEMM kernel with the selected multiplication mode (Fig 4 of
the paper). Structural data movement mirrors the CUDA implementation:

* forward: im2col + GEMM (Alg. 3);
* weight gradient: patch matrix of the *activation* at stride-spaced
  positions (the fused dilation of §VI-B.1) x errors;
* preceding-layer gradient: pad+dilate the errors (lax.pad with interior
  padding = the fused IM2COL_PLG), im2col stride 1, GEMM against the
  transpose-reversed weights (§VI-B.2).

The ``mode``/``lut``/``m`` selection is threaded through a ``MulCfg`` so a
whole model lowers into one HLO module per configuration.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels.amsim_gemm import am_gemm


@dataclass(frozen=True)
class MulCfg:
    """Multiplication configuration baked into an artifact. ``lut`` is a
    traced operand (swappable at runtime); ``mode``/``m`` are static."""
    mode: str = "native"  # native | custom | lut | direct:<mult>
    m: int = 7

    def gemm(self, a, b, lut):
        # "custom" = the paper's ATnG: custom kernel path, native multiplier
        mode = "native" if self.mode == "custom" else self.mode
        if mode == "lut":
            assert lut is not None, "lut mode requires a LUT operand"
            return am_gemm(a, b, "lut", lut, self.m)
        return am_gemm(a, b, mode)

    @property
    def needs_lut(self) -> bool:
        return self.mode == "lut"

    @property
    def uses_pallas(self) -> bool:
        """False only for the pure-jnp TFnG baseline (`native` mode uses the
        Pallas kernel with jnp.dot; `tf` bypasses the custom kernels)."""
        return self.mode != "tf"


# ---------------------------------------------------------------------------
# AMDENSE
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def amdense(cfg: MulCfg, x, w, b, lut):
    """y[batch, out] = x[batch, in] @ w[in, out] + b (bias add exact)."""
    return _amdense_fwd(cfg, x, w, b, lut)[0]


def _amdense_fwd(cfg, x, w, b, lut):
    if cfg.mode == "tf":
        y = jnp.dot(x, w) + b
    else:
        y = cfg.gemm(x, w, lut) + b
    return y, (x, w, lut)


def _amdense_bwd(cfg, res, dy):
    x, w, lut = res
    if cfg.mode == "tf":
        dw = jnp.dot(x.T, dy)
        dx = jnp.dot(dy, w.T)
    else:
        dw = cfg.gemm(x.T, dy, lut)  # paper §VI-C.1
        dx = cfg.gemm(dy, w.T, lut)  # paper §VI-C.2
    db = jnp.sum(dy, axis=0)
    return dx, dw, db, None


amdense.defvjp(_amdense_fwd, _amdense_bwd)


# ---------------------------------------------------------------------------
# AMCONV2D
# ---------------------------------------------------------------------------

def im2col(x, kh: int, kw: int, stride: int, pad: int):
    """Patch extraction: NHWC ``x`` -> ``[b*oh*ow, kh*kw*c]`` with (ky, kx,
    c) minor ordering — identical to ``rust/src/kernels/im2col.rs``."""
    b, h, w, c = x.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    patches = []
    for ky in range(kh):
        for kx in range(kw):
            sl = xp[:, ky:ky + (oh - 1) * stride + 1:stride,
                    kx:kx + (ow - 1) * stride + 1:stride, :]
            patches.append(sl)
    cols = jnp.concatenate(patches, axis=-1)  # [b, oh, ow, kh*kw*c]
    return cols.reshape(b * oh * ow, kh * kw * c), (oh, ow)


def _dilate_pad(dy, stride: int, pad: int, kh: int, kw: int, out_pad_h: int,
                out_pad_w: int):
    """Fused pad+dilate of the errors (paper IM2COL_PLG): interior padding
    of ``stride - 1`` zeros plus full-correlation edge padding."""
    cfg = [
        (0, 0, 0),
        (kh - 1 - pad, kh - 1 - pad + out_pad_h, stride - 1),
        (kw - 1 - pad, kw - 1 - pad + out_pad_w, stride - 1),
        (0, 0, 0),
    ]
    return jax.lax.pad(dy, jnp.float32(0), cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4))
def amconv2d(cfg: MulCfg, x, w, stride: int, pad: int, lut):
    """NHWC conv: x[b,h,w,c] * w[kh,kw,c,oc] -> y[b,oh,ow,oc]."""
    return _amconv2d_fwd(cfg, x, w, stride, pad, lut)[0]


def _amconv2d_fwd(cfg, x, w, stride, pad, lut):
    # note: custom_vjp fwd rules take the *primal* argument order; only the
    # bwd rule gets the nondiff args prepended
    b = x.shape[0]
    kh, kw, c, oc = w.shape
    if cfg.mode == "tf":
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y, (x, w, lut)
    cols, (oh, ow) = im2col(x, kh, kw, stride, pad)
    y = cfg.gemm(cols, w.reshape(kh * kw * c, oc), lut)
    return y.reshape(b, oh, ow, oc), (x, w, lut)


def _amconv2d_bwd(cfg, stride, pad, res, dy):
    x, w, lut = res
    b, h, wd, c = x.shape
    kh, kw, _, oc = w.shape
    _, oh, ow, _ = dy.shape
    if cfg.mode == "tf":
        # stock XLA gradients (TFnG baseline)
        _, vjp = jax.vjp(
            lambda x_, w_: jax.lax.conv_general_dilated(
                x_, w_, (stride, stride), [(pad, pad), (pad, pad)],
                dimension_numbers=("NHWC", "HWIO", "NHWC")), x, w)
        dx, dw = vjp(dy)
        return dx, dw, None

    # -- weight gradient (paper §VI-B.1, fused dilation by strided reads) --
    # cols_wg[q, r]: activation patches at stride-spaced positions
    cols_wg, _ = im2col(x, kh, kw, stride, pad)  # [b*oh*ow, kh*kw*c]
    dy_mat = dy.reshape(b * oh * ow, oc)
    # dw[r, oc] = sum_q cols_wg[q, r] * dy[q, oc]
    dw = cfg.gemm(cols_wg.T, dy_mat, lut).reshape(kh, kw, c, oc)

    # -- preceding-layer gradient (paper §VI-B.2) --
    out_pad_h = (h + 2 * pad - kh) % stride
    out_pad_w = (wd + 2 * pad - kw) % stride
    pd = _dilate_pad(dy, stride, pad, kh, kw, out_pad_h, out_pad_w)
    cols_plg, (gh, gw) = im2col(pd, kh, kw, 1, 0)
    assert (gh, gw) == (h, wd), f"plg geometry {(gh, gw)} != {(h, wd)}"
    # transpose-and-reverse of the weights (separate pass, §VI-D)
    wrt = w[::-1, ::-1, :, :].transpose(0, 1, 3, 2).reshape(kh * kw * oc, c)
    dx = cfg.gemm(cols_plg, wrt, lut).reshape(b, h, wd, c)
    return dx, dw, None


amconv2d.defvjp(_amconv2d_fwd, _amconv2d_bwd)


# ---------------------------------------------------------------------------
# Exact helper layers (no multiplies approximated, paper Table I / §III-A)
# ---------------------------------------------------------------------------

def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool2x2(x):
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))


def batchnorm(x, gamma, beta, eps: float = 1e-5):
    """Batch-statistics BN over NHWC channels (see rust layers/batchnorm.rs
    for the rationale of using batch stats in both phases)."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta
