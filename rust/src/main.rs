//! ApproxTrain launcher — the Layer-3 entrypoint.
//!
//! ```text
//! approxtrain gen-lut --mult afm16 --out afm16.lut
//! approxtrain hwmodel
//! approxtrain train --model lenet5 --mode lut --mult afm16 --epochs 3
//! approxtrain infer --model lenet5 --mode lut --mult afm16
//! approxtrain serve --model lenet300 --requests 64
//! approxtrain bench-gemm --size 256
//! approxtrain bench-conv
//! approxtrain experiment fig6|fig10|table3|table4|table5|table6|fig11|fig12|all [--quick]
//! approxtrain list-artifacts
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use approxtrain::cli::Args;
use approxtrain::coordinator::experiments;
use approxtrain::coordinator::trainer::{TrainConfig, Trainer};
use approxtrain::lut::MantissaLut;
use approxtrain::mult::registry;
use approxtrain::runtime::executor::Engine;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("artifacts", "artifacts"))
}

fn results_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("results", "results"))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_str() {
        "gen-lut" => gen_lut(&args),
        "hwmodel" => {
            println!("{}", experiments::fig1(&results_dir(&args))?);
            Ok(())
        }
        "train" => train(&args),
        "infer" => infer(&args),
        "serve" => serve(&args),
        "bench-gemm" => {
            // pure CPU-kernel benchmark; needs no artifacts. Full-budget
            // runs refresh the committed BENCH_gemm.json at the repo root;
            // --quick keeps its low-budget numbers in results/ only.
            let quick = args.has_flag("quick");
            let out = experiments::bench_gemm(
                &results_dir(&args),
                args.opt_usize("size", 256),
                quick,
                !quick,
            )?;
            println!("{out}");
            Ok(())
        }
        "bench-conv" => {
            // implicit-GEMM vs materialized-im2col conv benchmark; pure
            // CPU path, same root-record policy as bench-gemm
            let quick = args.has_flag("quick");
            let out = experiments::bench_conv(&results_dir(&args), quick, !quick)?;
            println!("{out}");
            Ok(())
        }
        "experiment" => experiment(&args),
        "list-artifacts" => list_artifacts(&args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `approxtrain help`"),
    }
}

const HELP: &str = "\
ApproxTrain — fast simulation of approximate FP multipliers for DNN \
training and inference (Rust + JAX + Pallas reproduction).

commands:
  gen-lut --mult <name> [--out file.lut]   generate a mantissa-product LUT
  hwmodel                                  Fig 1 resource-efficiency model
  train --model <m> --mode <tf|custom|lut|direct:afm32> --mult <name>
        [--epochs N] [--lr F] [--samples N] [--seed N] [--ckpt out.ckpt]
  infer --model <m> --mode <...> --mult <name> [--samples N] [--ckpt f]
  serve --model <m> [--requests N] [--batch-wait-ms N]
  bench-gemm [--size N] [--quick]          CPU GEMM perf record (BENCH_gemm.json)
  bench-conv [--quick]                     implicit vs materialized conv (BENCH_conv.json)
  experiment <fig1|fig6|fig10|table3|table4|table5|table6|fig11|fig12|all>
        [--quick]
  list-artifacts
common options: --artifacts DIR (default artifacts) --results DIR
";

fn gen_lut(args: &Args) -> Result<()> {
    let name = args.opt("mult").context("--mult required")?;
    let model = registry::by_name(name).with_context(|| format!("unknown multiplier {name}"))?;
    let lut = MantissaLut::generate(model.as_ref());
    lut.validate()
        .map_err(|e| anyhow::anyhow!("generated {name} LUT failed validation: {e}"))?;
    let out = args.opt_or("out", &format!("{name}.lut"));
    lut.save(Path::new(&out)).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "wrote {out}: m={} entries={} payload={} bytes",
        lut.m,
        lut.len(),
        lut.payload_bytes()
    );
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut engine = Engine::new(&dir)?;
    let cfg = TrainConfig {
        model: args.opt_or("model", "lenet5"),
        mode: args.opt_or("mode", "lut"),
        mult: args.opt_or("mult", "afm16"),
        epochs: args.opt_usize("epochs", 3),
        lr: args.opt_f32("lr", 0.05),
        seed: args.opt_u64("seed", 42),
        eval_every: args.opt_usize("eval-every", 1),
    };
    let samples = args.opt_usize("samples", 512);
    let ds = experiments::dataset_for(experiments::dataset_of(&cfg.model), samples, cfg.seed);
    let (train_ds, test_ds) = ds.split(samples / 4);
    println!(
        "training {} mode={} mult={} epochs={} on {} ({} train / {} test)",
        cfg.model, cfg.mode, cfg.mult, cfg.epochs, train_ds.name, train_ds.n, test_ds.n
    );
    let mut tr = Trainer::new(&mut engine, cfg, &dir)?;
    let log = tr.fit(&train_ds, &test_ds)?;
    for e in &log.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  train acc {:.2}%  test acc {:.2}%  ({:.1}s)",
            e.epoch,
            e.train_loss,
            e.train_acc * 100.0,
            e.test_acc * 100.0,
            e.seconds
        );
    }
    if let Some(path) = args.opt("ckpt") {
        tr.checkpoint()?.save(Path::new(path))?;
        println!("checkpoint -> {path}");
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let mut engine = Engine::new(&dir)?;
    let cfg = TrainConfig {
        model: args.opt_or("model", "lenet5"),
        mode: args.opt_or("mode", "lut"),
        mult: args.opt_or("mult", "afm16"),
        epochs: 0,
        lr: 0.0,
        seed: args.opt_u64("seed", 42),
        eval_every: 1,
    };
    let samples = args.opt_usize("samples", 256);
    let ds = experiments::dataset_for(experiments::dataset_of(&cfg.model), samples, cfg.seed);
    let mut tr = Trainer::new(&mut engine, cfg, &dir)?;
    if let Some(path) = args.opt("ckpt") {
        tr.load_checkpoint(&approxtrain::nn::checkpoint::Checkpoint::load(Path::new(path))?)?;
    }
    let acc = tr.evaluate(&ds)?;
    println!("test accuracy (untrained unless --ckpt given): {:.2}%", acc * 100.0);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    use approxtrain::coordinator::server::with_server;
    use approxtrain::nn::init::init_params;
    use approxtrain::runtime::artifact::Role;
    use approxtrain::util::json::Json;
    use std::time::Duration;

    let dir = artifacts_dir(args);
    let mut engine = Engine::new(&dir)?;
    let model = args.opt_or("model", "lenet300");
    let art = engine
        .manifest()
        .find(&model, "fwd", "lut")
        .context("no lut fwd artifact")?
        .clone();
    // pre-compile before the timed serving loop
    engine.prepare(&art.name)?;
    let raw = Json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)?;
    let params = init_params(&art, 42, &raw)?;
    let lut = MantissaLut::load(&dir.join("luts/afm16.lut")).map_err(|e| anyhow::anyhow!("{e}"))?;
    lut.validate()
        .map_err(|e| anyhow::anyhow!("loaded afm16 LUT failed validation: {e}"))?;
    let x_spec = &art.inputs[art.input_indices(Role::Input)[0]];
    let batch = x_spec.shape[0];
    let image_elems = x_spec.elements() / batch;
    let classes = art.outputs[0].shape[1];
    let requests = args.opt_usize("requests", 64);
    let wait = Duration::from_millis(args.opt_u64("batch-wait-ms", 5));
    let ds = experiments::dataset_for(experiments::dataset_of(&model), requests, 7);
    let name = art.name.clone();
    let stats = with_server(
        engine,
        &name,
        params,
        Some(lut.entries),
        batch,
        image_elems,
        classes,
        wait,
        |client| {
            std::thread::scope(|s| {
                for t in 0..4 {
                    let client = client.clone();
                    let ds = &ds;
                    s.spawn(move || {
                        for i in (t..requests).step_by(4) {
                            let _ = client.infer(ds.image(i).to_vec());
                        }
                    });
                }
            });
        },
    )?;
    println!(
        "served {} requests in {} batches | p50 {:.1} ms p99 {:.1} ms | mean fill {:.1}/{batch}",
        stats.requests,
        stats.batches,
        stats.latency_percentile_s(50.0) * 1e3,
        stats.latency_percentile_s(99.0) * 1e3,
        stats.mean_fill(),
    );
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = args.has_flag("quick");
    let dir = artifacts_dir(args);
    let results = results_dir(args);
    let mut out = String::new();
    if which == "fig1" || which == "all" {
        out.push_str(&experiments::fig1(&results)?);
    }
    if which != "fig1" {
        let mut engine = Engine::new(&dir)?;
        match which {
            "fig6" => out.push_str(&experiments::fig6(
                &mut engine,
                &results,
                args.opt_usize("size", 256),
                quick,
            )?),
            "fig10" | "table3" => {
                out.push_str(&experiments::fig10_table3(&mut engine, &dir, &results, quick)?)
            }
            "table4" => out.push_str(&experiments::table4(&mut engine, &dir, &results, quick)?),
            "table5" => {
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, true, quick)?)
            }
            "table6" => {
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, false, quick)?)
            }
            "fig11" => out.push_str(&experiments::fig11(&mut engine, &dir, &results, quick)?),
            "fig12" => out.push_str(&experiments::fig12(&mut engine, &results, quick)?),
            "all" => {
                out.push_str(&experiments::fig6(&mut engine, &results, 256, quick)?);
                out.push_str(&experiments::fig10_table3(&mut engine, &dir, &results, quick)?);
                out.push_str(&experiments::table4(&mut engine, &dir, &results, quick)?);
                out.push_str(&experiments::fig11(&mut engine, &dir, &results, quick)?);
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, true, quick)?);
                out.push_str(&experiments::table5_6(&mut engine, &dir, &results, false, quick)?);
                out.push_str(&experiments::fig12(&mut engine, &results, quick)?);
            }
            other => bail!("unknown experiment {other:?}"),
        }
    }
    println!("{out}");
    approxtrain::coordinator::report::write_result(&results, "report.md", &out)?;
    Ok(())
}

fn list_artifacts(args: &Args) -> Result<()> {
    let engine = Engine::new(&artifacts_dir(args))?;
    for art in engine.manifest().artifacts.values() {
        println!(
            "{:<28} model={:<10} phase={:<6} mode={:<13} inputs={} outputs={}",
            art.name,
            art.model,
            art.phase,
            art.mode,
            art.inputs.len(),
            art.outputs.len()
        );
    }
    Ok(())
}
