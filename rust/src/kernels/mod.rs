//! CPU compute kernels mirroring the paper's custom CUDA kernels (§VI-D):
//! GEMM, three IM2COL variants, transpose-and-reverse, matrix-vector and
//! pooling. Every multiplication goes through a [`MulKernel`], which selects
//! between the native hardware `*`, a direct functional-model call (the
//! paper's "direct C simulation"), or the LUT-based AMSim — the three
//! simulation strategies compared in Fig 6 and Tables V/VI.
//!
//! The kernels are written against the **batched** [`MulBackend`] panel
//! operations rather than per-element [`MulKernel::mul`] calls: strategy
//! dispatch happens once per contiguous panel, so the AMSim path is a
//! tight LUT-gather loop with its shift/mask hoisted into registers
//! (AdaPT, arXiv 2203.04071, makes the same observation: per-multiply
//! dispatch must be amortized by vectorized LUT lookups for emulation to
//! be usable at training scale) and the native path is a plain FMA loop
//! the compiler can treat as the cuBLAS stand-in baseline. Each panel op
//! is bit-identical to its scalar per-element reference; see
//! `tests/batched_vs_scalar.rs`.
pub mod gemm;
pub mod im2col;
pub mod matvec;
pub mod pool;
pub mod transpose_reverse;

use crate::amsim::AmSim;
use crate::mult::ApproxMul;

/// Multiplication strategy threaded through every kernel.
pub enum MulKernel<'a> {
    /// Native hardware multiplier (`*` operator) — the ATnG configuration.
    Native,
    /// Direct call into the multiplier functional model (bit manipulation
    /// per multiply) — the ATxC configuration / Fig 6 "C simulation".
    Direct(&'a dyn ApproxMul),
    /// LUT-based AMSim — the ATxG configuration.
    Lut(AmSim<'a>),
}

impl<'a> MulKernel<'a> {
    /// Scalar multiply — the *reference semantics* every batched panel op
    /// must reproduce bit-for-bit. Kernel inner loops should use
    /// [`MulBackend`] instead; this stays for scalar call sites, oracles
    /// and tests.
    #[inline(always)]
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        match self {
            MulKernel::Native => a * b,
            MulKernel::Direct(m) => m.mul(a, b),
            MulKernel::Lut(sim) => sim.mul(a, b),
        }
    }

    pub fn describe(&self) -> String {
        match self {
            MulKernel::Native => "native".into(),
            MulKernel::Direct(m) => format!("direct:{}", m.name()),
            MulKernel::Lut(sim) => format!("lut:m{}", sim.mantissa_bits()),
        }
    }
}

/// Batched panel operations over contiguous slices — the interface the
/// GEMM / matvec / im2col-fed convolution inner loops are written against.
///
/// Contract: each operation is **bit-identical** to the corresponding
/// per-element scalar sequence using [`MulKernel::mul`] with strictly
/// sequential FP32 accumulation (`tests/batched_vs_scalar.rs` enforces
/// this for all three strategies). What batching buys is *dispatch
/// amortization*: the strategy `match` runs once per panel, not once per
/// multiply.
pub trait MulBackend {
    /// `out[i] = mul(a[i], b[i])` over a contiguous panel.
    fn mul_panel(&self, a: &[f32], b: &[f32], out: &mut [f32]);

    /// `sum_i mul(a[i], b[i])` with sequential FP32 accumulation.
    fn dot_panel(&self, a: &[f32], b: &[f32]) -> f32;

    /// `acc[j] += mul(x, row[j])` — the rank-1-update inner loop, with the
    /// broadcast operand's decomposition hoisted out of the loop.
    fn fma_row(&self, acc: &mut [f32], x: f32, row: &[f32]);
}

impl MulBackend for MulKernel<'_> {
    fn mul_panel(&self, a: &[f32], b: &[f32], out: &mut [f32]) {
        assert!(a.len() == b.len() && a.len() == out.len());
        match self {
            MulKernel::Native => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = x * y;
                }
            }
            MulKernel::Direct(m) => {
                for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                    *o = m.mul(x, y);
                }
            }
            MulKernel::Lut(sim) => sim.mul_slice(a, b, out),
        }
    }

    fn dot_panel(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            // native: plain sequential FMA loop — the baseline every
            // slowdown ratio is measured against
            MulKernel::Native => {
                let mut acc = 0.0;
                for i in 0..a.len() {
                    acc += a[i] * b[i];
                }
                acc
            }
            // direct: the virtual call per multiply is inherent to the
            // black-box model (the paper's "direct C simulation" cost);
            // unroll 4-wide so the calls pipeline, keep the adds ordered
            MulKernel::Direct(m) => {
                let n = a.len();
                let mut acc = 0.0f32;
                let mut i = 0;
                while i + 4 <= n {
                    let p0 = m.mul(a[i], b[i]);
                    let p1 = m.mul(a[i + 1], b[i + 1]);
                    let p2 = m.mul(a[i + 2], b[i + 2]);
                    let p3 = m.mul(a[i + 3], b[i + 3]);
                    acc += p0;
                    acc += p1;
                    acc += p2;
                    acc += p3;
                    i += 4;
                }
                while i < n {
                    acc += m.mul(a[i], b[i]);
                    i += 1;
                }
                acc
            }
            MulKernel::Lut(sim) => sim.dot(a, b),
        }
    }

    fn fma_row(&self, acc: &mut [f32], x: f32, row: &[f32]) {
        assert_eq!(acc.len(), row.len());
        match self {
            MulKernel::Native => {
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += x * r;
                }
            }
            MulKernel::Direct(m) => {
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += m.mul(x, r);
                }
            }
            MulKernel::Lut(sim) => sim.fma_row(acc, x, row),
        }
    }
}

/// Convolution geometry shared by the kernels: `same`-style explicit
/// padding, square stride.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dGeom {
    pub batch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub k_h: usize,
    pub k_w: usize,
    pub out_c: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k_h) / self.stride + 1
    }
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k_w) / self.stride + 1
    }
    /// rows of the forward im2col matrix
    pub fn col_rows(&self) -> usize {
        self.batch * self.out_h() * self.out_w()
    }
    /// columns of the forward im2col matrix (= GEMM contraction dim)
    pub fn col_cols(&self) -> usize {
        self.k_h * self.k_w * self.in_c
    }
    /// MACs of one forward pass (for roofline estimates)
    pub fn forward_macs(&self) -> usize {
        self.col_rows() * self.col_cols() * self.out_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::MantissaLut;
    use crate::mult::registry;

    #[test]
    fn geometry() {
        let g = Conv2dGeom {
            batch: 2,
            in_h: 28,
            in_w: 28,
            in_c: 1,
            k_h: 5,
            k_w: 5,
            out_c: 6,
            stride: 1,
            pad: 0,
        };
        assert_eq!((g.out_h(), g.out_w()), (24, 24));
        assert_eq!(g.col_rows(), 2 * 24 * 24);
        assert_eq!(g.col_cols(), 25);
        assert_eq!(g.forward_macs(), 2 * 24 * 24 * 25 * 6);
    }

    #[test]
    fn strided_geometry() {
        let g = Conv2dGeom {
            batch: 1,
            in_h: 8,
            in_w: 8,
            in_c: 3,
            k_h: 3,
            k_w: 3,
            out_c: 4,
            stride: 2,
            pad: 1,
        };
        assert_eq!((g.out_h(), g.out_w()), (4, 4));
    }

    #[test]
    fn mul_kernel_dispatch() {
        let model = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(model.as_ref());
        let native = MulKernel::Native;
        let direct = MulKernel::Direct(model.as_ref());
        let lut_k = MulKernel::Lut(crate::amsim::AmSim::new(&lut));
        assert_eq!(native.mul(1.5, 2.0), 3.0);
        assert_eq!(direct.mul(1.5, 2.0), 3.0);
        assert_eq!(lut_k.mul(1.5, 2.0), 3.0);
        assert_eq!(native.describe(), "native");
        assert_eq!(direct.describe(), "direct:bfloat16");
        assert_eq!(lut_k.describe(), "lut:m7");
    }
}
