//! The multiplier functional models themselves.
//!
//! Every model implements [`ApproxMul::mantissa_product`] over 23-bit
//! mantissa fields and gets its full `mul` via [`mul_via_mantissa`], which
//! performs the sign/exponent computation that all paper-relevant designs
//! keep exact (§V observation (1): mantissa multiplication is 91%/93% of
//! the area/power of an FP multiplier, so that is what gets approximated).

use super::fpbits::{compose, decompose, FpParts, EXP_BIAS, MANT_BITS, MANT_MASK};
use super::ApproxMul;

/// Shared sign/exponent scaffolding: decompose, delegate the mantissa
/// product to the model, re-assemble with flush-to-zero and overflow-to-inf
/// semantics (matching AMSim, paper Alg. 2 lines 12-19, with the exp+carry
/// overflow check applied *after* the carry — see `amsim` module docs).
///
/// Special-case ordering is governed by the model's
/// [`ApproxMul::zero_identity`] flag. Models *without* the flag (the exact
/// IEEE baselines) delegate any NaN/inf operand to hardware semantics
/// first, so `0 × inf == NaN` exactly as `f32` multiplication would.
/// Models *with* the flag are zero-dominant, like AMSim's Algorithm-2
/// datapath and real approximate-hardware designs that gate on a zero
/// operand: a zero (or flushed-subnormal) operand yields the signed zero
/// of the XOR sign before NaN/inf handling, which is what licenses the
/// sparse GEMM drain to elide the product entirely.
pub fn mul_via_mantissa(model: &dyn ApproxMul, a: f32, b: f32) -> f32 {
    let specials = a.is_nan() || b.is_nan() || a.is_infinite() || b.is_infinite();
    if specials && !model.zero_identity() {
        return a * b; // delegate IEEE special cases to hardware semantics
    }
    let pa = decompose(a);
    let pb = decompose(b);
    let sign = pa.sign ^ pb.sign;
    if pa.exp == 0 || pb.exp == 0 {
        // zero or subnormal operand -> (signed) zero, AMSim line 13.
        // For zero-identity models this dominates NaN/inf on the other
        // operand; for the rest it is unreachable with specials present.
        return compose(FpParts { sign, exp: 0, mant: 0 });
    }
    if specials {
        // nonzero × NaN/inf under a zero-dominant model: the zero gate did
        // not fire, so hardware semantics apply (inf stays inf, NaN NaN).
        return a * b;
    }
    let (carry, mant) = model.mantissa_product(pa.mant, pb.mant);
    // flush-to-zero checked on the *pre-carry* exponent (paper Alg. 2
    // lines 12-13), overflow on the post-carry exponent
    let exp = pa.exp as i32 + pb.exp as i32 - EXP_BIAS;
    if exp <= 0 {
        return compose(FpParts { sign, exp: 0, mant: 0 });
    }
    let exp = exp + carry as i32;
    if exp >= 255 {
        return compose(FpParts { sign, exp: 255, mant: 0 }); // +-inf
    }
    compose(FpParts { sign, exp: exp as u32, mant })
}

/// Truncate a 23-bit mantissa field to its top `m` bits.
#[inline]
fn trunc_m(mant: u32, m: u32) -> u32 {
    mant & (MANT_MASK << (MANT_BITS - m)) & MANT_MASK
}

// ---------------------------------------------------------------------------
// Exact multiplier at m-bit mantissa (FP32 when m=23, bfloat16 when m=7)
// ---------------------------------------------------------------------------

/// IEEE-style exact multiplier with round-to-nearest-even at `m` mantissa
/// bits. The FP32 / bfloat16 baselines of the paper (Table II).
pub struct ExactFp {
    name: String,
    m: u32,
    /// round-to-nearest-even if true, round-toward-zero if false
    /// (round-toward-zero gives the DRUM-style `trunc16` design)
    rne: bool,
    /// zero-dominant special handling (see [`ApproxMul::zero_identity`]).
    /// Off for the IEEE baselines (fp32/bfloat16/fp16 must keep hardware
    /// `0 × inf == NaN` semantics), on for approximate-hardware designs
    /// modeled through `ExactFp` (the DRUM-style `trunc16`).
    zero_id: bool,
}

impl ExactFp {
    pub fn new(name: &str, m: u32, rne: bool) -> Self {
        assert!((1..=MANT_BITS).contains(&m));
        ExactFp { name: name.to_string(), m, rne, zero_id: false }
    }

    /// Builder: declare the zero-identity capability (zero-dominant
    /// specials). Audited against brute force in `tests/golden_mults.rs`.
    pub fn with_zero_identity(mut self) -> Self {
        self.zero_id = true;
        self
    }
}

impl ApproxMul for ExactFp {
    fn name(&self) -> &str {
        &self.name
    }
    fn mantissa_bits(&self) -> u32 {
        self.m
    }
    fn mul(&self, a: f32, b: f32) -> f32 {
        mul_via_mantissa(self, a, b)
    }
    fn zero_identity(&self) -> bool {
        self.zero_id
    }

    fn mantissa_product(&self, ma: u32, mb: u32) -> (u32, u32) {
        let ma = trunc_m(ma, self.m);
        let mb = trunc_m(mb, self.m);
        // significands in [2^23, 2^24)
        let sa = (1u64 << MANT_BITS) | ma as u64;
        let sb = (1u64 << MANT_BITS) | mb as u64;
        let p = sa * sb; // in [2^46, 2^48)
        let carry = (p >> 47) as u32; // product >= 2.0 ?
        // normalized significand in [2^46, 2^47): fraction field is low 46 bits
        let s = if carry == 1 { p >> 1 } else { p };
        let frac46 = s & ((1u64 << 46) - 1);
        // keep top m bits of the 46-bit fraction
        let drop = 46 - self.m;
        let mut kept = (frac46 >> drop) as u32;
        if self.rne {
            let half = 1u64 << (drop - 1);
            let low = frac46 & ((1u64 << drop) - 1);
            if low > half || (low == half && kept & 1 == 1) {
                kept += 1;
            }
        }
        let mut carry = carry;
        if kept >> self.m != 0 {
            // rounding overflowed the mantissa: renormalize.
            // (cannot cascade: (2-ulp)^2 < 4, see fpbits tests)
            kept = 0;
            carry += 1;
            debug_assert!(carry <= 1, "double carry in exact mantissa product");
        }
        (carry, (kept << (MANT_BITS - self.m)) & MANT_MASK)
    }
}

// ---------------------------------------------------------------------------
// Mitchell's log multiplier (MIT16, [25])
// ---------------------------------------------------------------------------

/// Mitchell's logarithm-based multiplier: `log2(1+x) ~= x`, so the mantissa
/// product is a single addition. Worst-case relative error ~ -11.1%.
pub struct Mitchell {
    name: String,
    m: u32,
}

impl Mitchell {
    pub fn new(name: &str, m: u32) -> Self {
        assert!((1..=MANT_BITS).contains(&m));
        Mitchell { name: name.to_string(), m }
    }
}

impl ApproxMul for Mitchell {
    fn name(&self) -> &str {
        &self.name
    }
    fn mantissa_bits(&self) -> u32 {
        self.m
    }
    fn mul(&self, a: f32, b: f32) -> f32 {
        mul_via_mantissa(self, a, b)
    }

    // Log-domain datapath: a zero operand gates the whole product to a
    // signed zero before special handling, matching AMSim's Algorithm 2.
    fn zero_identity(&self) -> bool {
        true
    }

    fn mantissa_product(&self, ma: u32, mb: u32) -> (u32, u32) {
        let s = trunc_m(ma, self.m) + trunc_m(mb, self.m); // x + y, 24 bits
        if s >= 1 << MANT_BITS {
            // x+y >= 1: product ~= 2 * (1 + (x+y-1))  (log-domain renorm)
            (1, trunc_m(s - (1 << MANT_BITS), self.m))
        } else {
            (0, trunc_m(s, self.m))
        }
    }
}

// ---------------------------------------------------------------------------
// AFM — minimally-biased approximate FP multiplier (AFM16/AFM32, [29])
// ---------------------------------------------------------------------------

/// Minimally-biased approximate multiplier in the style of Saadat et
/// al. [29]: the mantissa product `(1+x)(1+y) = 1 + x + y + x*y` keeps the
/// cheap `x + y` part exact and approximates the expensive `x*y` partial
/// products with a narrow `k x k`-bit multiplier over the operands' top
/// bits, plus a constant-shift bias-compensation term `(x + y) >> (k+1)`
/// that cancels the expected value of the dropped partial products
/// (`E[x*y_low + x_low*y] = (x+y) * 2^-(k+1)` for uniform low bits) —
/// making the design *minimally biased*.
///
/// The published RTL is not available; this functional model reproduces the
/// documented design class and error profile (near-zero mean error, MRED
/// ~1% for k=4). See DESIGN.md §Substitutions #5.
pub struct Afm {
    name: String,
    m: u32,
    k: u32,
}

impl Afm {
    pub fn new(name: &str, m: u32, k: u32) -> Self {
        assert!((1..=MANT_BITS).contains(&m) && k <= m);
        Afm { name: name.to_string(), m, k }
    }
}

impl ApproxMul for Afm {
    fn name(&self) -> &str {
        &self.name
    }
    fn mantissa_bits(&self) -> u32 {
        self.m
    }
    fn mul(&self, a: f32, b: f32) -> f32 {
        mul_via_mantissa(self, a, b)
    }

    // Zero-dominant like the other approximate designs (see Mitchell).
    fn zero_identity(&self) -> bool {
        true
    }

    fn mantissa_product(&self, ma: u32, mb: u32) -> (u32, u32) {
        let ma = trunc_m(ma, self.m) as u64;
        let mb = trunc_m(mb, self.m) as u64;
        // top-k-bit partial product of x*y (a k x k hardware multiplier)
        let ha = ma >> (MANT_BITS - self.k) << (MANT_BITS - self.k);
        let hb = mb >> (MANT_BITS - self.k) << (MANT_BITS - self.k);
        let xy = (ha * hb) >> MANT_BITS;
        // bias compensation: expected value of the dropped partial products
        let comp = (ma + mb) >> (self.k + 1);
        let t = ma + mb + xy + comp; // x + y + x*y  (approx), < 3 * 2^23
        if t >= 1 << MANT_BITS {
            // product >= 2: mantissa (v - 2) / 2  (true product renorm)
            let frac = (t - (1 << MANT_BITS)) >> 1;
            (1, trunc_m(frac.min(MANT_MASK as u64) as u32, self.m))
        } else {
            (0, trunc_m(t as u32, self.m))
        }
    }
}

// ---------------------------------------------------------------------------
// REALM — reduced-error approximate log multiplier (REALM16, [30])
// ---------------------------------------------------------------------------

/// Piecewise log-domain correction constants, 8 segments, midpoint values
/// of `log2(1+x) - x` scaled by 2^23. Identical constants are hard-coded in
/// `python/compile/mults.py`; bit-exactness between the two is tested via
/// the LUT golden files.
pub const REALM_LOG_CORR: [i64; 8] =
    [209403, 506903, 669557, 721940, 682465, 565287, 381522, 140059];
/// Midpoint values of `2^f - 1 - f` (the antilog correction), times 2^23.
pub const REALM_ANTILOG_CORR: [i64; 8] =
    [-152893, -408621, -592590, -698305, -718684, -646004, -471841, -187011];

/// REALM-style reduced-error log multiplier: Mitchell's single-addition
/// core plus piecewise-constant correction of both the log approximation
/// (per operand) and the antilog approximation (on the result), with 8
/// segments indexed by the top-3 mantissa bits.
pub struct Realm {
    name: String,
    m: u32,
}

impl Realm {
    pub fn new(name: &str, m: u32) -> Self {
        assert!((3..=MANT_BITS).contains(&m));
        Realm { name: name.to_string(), m }
    }
}

impl ApproxMul for Realm {
    fn name(&self) -> &str {
        &self.name
    }
    fn mantissa_bits(&self) -> u32 {
        self.m
    }
    fn mul(&self, a: f32, b: f32) -> f32 {
        mul_via_mantissa(self, a, b)
    }

    // Zero-dominant like the other approximate designs (see Mitchell).
    fn zero_identity(&self) -> bool {
        true
    }

    fn mantissa_product(&self, ma: u32, mb: u32) -> (u32, u32) {
        let ma = trunc_m(ma, self.m);
        let mb = trunc_m(mb, self.m);
        let seg = |m: u32| (m >> (MANT_BITS - 3)) as usize;
        // corrected log-domain sum: x + y + c(x) + c(y) ~= log2((1+x)(1+y))
        let mut s = ma as i64 + mb as i64 + REALM_LOG_CORR[seg(ma)] + REALM_LOG_CORR[seg(mb)];
        let carry = if s >= 1 << MANT_BITS { 1 } else { 0 };
        if carry == 1 {
            s -= 1 << MANT_BITS;
        }
        // antilog: mantissa = f + d(f), d <= 0
        let f = s.clamp(0, MANT_MASK as i64) as u32;
        let g = (f as i64 + REALM_ANTILOG_CORR[seg(f)]).clamp(0, MANT_MASK as i64) as u32;
        (carry, trunc_m(g, self.m))
    }
}

// ---------------------------------------------------------------------------
// COMP — bitwise-AND compensated log multiplier (stand-in for Kim [18])
// ---------------------------------------------------------------------------

/// Compensated design: Mitchell's `1 + x + y` core plus a zero-cost
/// compensation of the dropped `x*y` term by the bitwise AND of the
/// operands' mantissas (a single gate row). Stand-in for the low-cost
/// compensated bfloat16 multiplier of Kim [18].
pub struct AndCompensated {
    name: String,
    m: u32,
}

impl AndCompensated {
    pub fn new(name: &str, m: u32) -> Self {
        AndCompensated { name: name.to_string(), m }
    }
}

impl ApproxMul for AndCompensated {
    fn name(&self) -> &str {
        &self.name
    }
    fn mantissa_bits(&self) -> u32 {
        self.m
    }
    fn mul(&self, a: f32, b: f32) -> f32 {
        mul_via_mantissa(self, a, b)
    }

    // Zero-dominant like the other approximate designs (see Mitchell).
    fn zero_identity(&self) -> bool {
        true
    }

    fn mantissa_product(&self, ma: u32, mb: u32) -> (u32, u32) {
        let ma = trunc_m(ma, self.m) as u64;
        let mb = trunc_m(mb, self.m) as u64;
        let t = ma + mb + (ma & mb); // 1 + x + y + (x AND y)
        if t >= 1 << MANT_BITS {
            let frac = (t - (1 << MANT_BITS)) >> 1;
            (1, trunc_m(frac.min(MANT_MASK as u64) as u32, self.m))
        } else {
            (0, trunc_m(t as u32, self.m))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::fpbits::quantize_mantissa;
    use crate::util::rng::Pcg32;
    use crate::util::stats::relative_error_stats;

    #[test]
    fn fp32_is_exact() {
        let fp32 = ExactFp::new("fp32", 23, true);
        let mut rng = Pcg32::seeded(5);
        for _ in 0..5000 {
            let a = rng.range(-1e10, 1e10);
            let b = rng.range(-1e3, 1e3);
            assert_eq!(fp32.mul(a, b).to_bits(), (a * b).to_bits(), "{a} * {b}");
        }
    }

    #[test]
    fn bfloat16_matches_quantized_product() {
        let bf16 = ExactFp::new("bfloat16", 7, true);
        let mut rng = Pcg32::seeded(6);
        for _ in 0..5000 {
            let a = quantize_mantissa(rng.range(-100.0, 100.0), 7);
            let b = quantize_mantissa(rng.range(-100.0, 100.0), 7);
            let got = bf16.mul(a, b);
            let want = quantize_mantissa(a * b, 7);
            assert_eq!(got.to_bits(), want.to_bits(), "{a} * {b}: got {got} want {want}");
        }
    }

    #[test]
    fn zero_and_sign_handling() {
        for m in [
            &ExactFp::new("fp32", 23, true) as &dyn ApproxMul,
            &Mitchell::new("mit16", 7),
            &Afm::new("afm16", 7, 4),
            &Realm::new("realm16", 7),
            &AndCompensated::new("comp16", 7),
        ] {
            assert_eq!(m.mul(0.0, 3.5), 0.0);
            assert_eq!(m.mul(-2.0, 0.0), -0.0);
            assert!(m.mul(-2.0, 3.0) < 0.0, "{}", m.name());
            assert!(m.mul(-2.0, -3.0) > 0.0, "{}", m.name());
        }
    }

    /// Special-case ordering follows the declared zero-identity flag:
    /// IEEE baselines keep hardware `0 × inf == NaN`; zero-dominant models
    /// return the signed zero of the XOR sign even against NaN/inf, and
    /// still propagate NaN/inf when no zero operand gates them.
    #[test]
    fn zero_dominance_follows_the_declared_flag() {
        let fp32 = ExactFp::new("fp32", 23, true);
        assert!(!fp32.zero_identity());
        assert!(fp32.mul(0.0, f32::INFINITY).is_nan());
        assert!(fp32.mul(f32::NAN, 0.0).is_nan());

        let tr = ExactFp::new("trunc16", 7, false).with_zero_identity();
        assert!(tr.zero_identity());
        assert_eq!(tr.mul(0.0, f32::INFINITY).to_bits(), 0.0f32.to_bits());
        assert_eq!(tr.mul(f32::INFINITY, -0.0).to_bits(), (-0.0f32).to_bits());

        let mit = Mitchell::new("mit16", 7);
        assert!(mit.zero_identity());
        assert_eq!(mit.mul(f32::NAN, 0.0).to_bits(), 0.0f32.to_bits());
        // no zero gate -> hardware semantics still apply
        assert!(mit.mul(f32::NAN, 1.0).is_nan());
        assert_eq!(mit.mul(f32::INFINITY, 2.0), f32::INFINITY);
        assert_eq!(mit.mul(-2.0, f32::INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn overflow_and_underflow() {
        let afm = Afm::new("afm16", 7, 4);
        assert_eq!(afm.mul(1e30, 1e30), f32::INFINITY);
        assert_eq!(afm.mul(-1e30, 1e30), f32::NEG_INFINITY);
        assert_eq!(afm.mul(1e-30, 1e-30), 0.0);
    }

    /// Error-profile ordering the paper's Fig 6 designs rely on:
    /// AFM and REALM both reduce Mitchell's error; AFM is near-unbiased.
    #[test]
    fn error_profiles_ordered() {
        let mut rng = Pcg32::seeded(7);
        let mit = Mitchell::new("mit16", 7);
        let afm = Afm::new("afm16", 7, 4);
        let realm = Realm::new("realm16", 7);
        let mut exact = Vec::new();
        let mut vm = Vec::new();
        let mut va = Vec::new();
        let mut vr = Vec::new();
        for _ in 0..20000 {
            let a = quantize_mantissa(rng.range(1.0, 2.0), 7);
            let b = quantize_mantissa(rng.range(1.0, 2.0), 7);
            exact.push((a as f64) * (b as f64));
            vm.push(mit.mul(a, b) as f64);
            va.push(afm.mul(a, b) as f64);
            vr.push(realm.mul(a, b) as f64);
        }
        let sm = relative_error_stats(&exact, &vm);
        let sa = relative_error_stats(&exact, &va);
        let sr = relative_error_stats(&exact, &vr);
        assert!(sa.mred < sm.mred, "AFM mred {} !< Mitchell {}", sa.mred, sm.mred);
        assert!(sr.mred < sm.mred, "REALM mred {} !< Mitchell {}", sr.mred, sm.mred);
        assert!(sa.bias.abs() < 0.01, "AFM bias {}", sa.bias);
        assert!(sm.bias < -0.02, "Mitchell must under-estimate, bias {}", sm.bias);
        assert!(sm.max_re < 0.12, "Mitchell max err {}", sm.max_re);
        assert!(sa.max_re < 0.04, "AFM max err {}", sa.max_re);
    }

    /// AFM with k == m degenerates to a (slightly biased-up) near-exact
    /// multiplier: its x*y partial product term is complete.
    #[test]
    fn afm_full_k_is_near_exact() {
        let afm = Afm::new("afm_full", 7, 7);
        let mut rng = Pcg32::seeded(8);
        for _ in 0..2000 {
            let a = quantize_mantissa(rng.range(1.0, 2.0), 7);
            let b = quantize_mantissa(rng.range(1.0, 2.0), 7);
            let re = ((afm.mul(a, b) - a * b) / (a * b)).abs();
            assert!(re < 0.02, "{a}*{b}: re {re}");
        }
    }

    #[test]
    fn mantissa_product_invariants() {
        // carry bit and 23-bit range for all models across the full m=7 grid
        let models: Vec<Box<dyn ApproxMul>> = vec![
            Box::new(ExactFp::new("bf", 7, true)),
            Box::new(ExactFp::new("tr", 7, false)),
            Box::new(Mitchell::new("mit", 7)),
            Box::new(Afm::new("afm", 7, 4)),
            Box::new(Realm::new("realm", 7)),
            Box::new(AndCompensated::new("comp", 7)),
        ];
        for model in &models {
            for k in 0..128u32 {
                for j in 0..128u32 {
                    let (carry, mant) = model.mantissa_product(k << 16, j << 16);
                    assert!(carry <= 1, "{}: carry {}", model.name(), carry);
                    assert!(mant <= MANT_MASK, "{}: mant {:#x}", model.name(), mant);
                    assert_eq!(mant & 0xFFFF, 0, "{}: low bits set for m=7", model.name());
                }
            }
        }
    }
}
