//! Binary on-disk format for mantissa-product LUTs.
//!
//! The paper writes LUTs "into binary files; thus, multiplier designers
//! could load LUT binary files during run-time" (§V). Layout (little
//! endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"AMLUT\x01\0\0"
//! 8       4     m      mantissa bit-width (u32)
//! 12      4     name_len (u32)
//! 16      n     multiplier name (utf-8)
//! 16+n    4*2^(2m)  entries (u32 little-endian)
//! end     4     crc32 of the entries payload
//! ```
//!
//! The same format is written by `python/compile/lutgen.py`; golden-file
//! tests assert bit-identical output between the two implementations.

use std::io::{Read, Write};
use std::path::Path;

use super::MantissaLut;

pub const MAGIC: &[u8; 8] = b"AMLUT\x01\0\0";

/// Longest multiplier name a LUT file may declare.
pub const MAX_NAME_LEN: usize = 256;

/// Largest byte size any well-formed LUT file can have (header + max name
/// + `4 * 4^MAX_LUT_M` entries + crc). [`MantissaLut::load`] checks file
/// metadata against this BEFORE reading, so a hostile or mislabeled path
/// cannot force an unbounded allocation.
pub const MAX_LUT_FILE_BYTES: u64 =
    16 + MAX_NAME_LEN as u64 + 4 * (1u64 << (2 * super::MAX_LUT_M)) + 4;

/// CRC-32 (IEEE) — implemented locally; the offline dep set has no crc crate.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[derive(Debug)]
pub enum LutIoError {
    Io(std::io::Error),
    BadMagic,
    BadHeader(String),
    CrcMismatch { want: u32, got: u32 },
}

impl std::fmt::Display for LutIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LutIoError::Io(e) => write!(f, "lut io: {e}"),
            LutIoError::BadMagic => write!(f, "not a LUT file (bad magic)"),
            LutIoError::BadHeader(m) => write!(f, "bad LUT header: {m}"),
            LutIoError::CrcMismatch { want, got } => {
                write!(f, "LUT payload corrupt: crc {got:#x} != {want:#x}")
            }
        }
    }
}
impl std::error::Error for LutIoError {}
impl From<std::io::Error> for LutIoError {
    fn from(e: std::io::Error) -> Self {
        LutIoError::Io(e)
    }
}

impl MantissaLut {
    /// Serialize to the binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let name = self.mult_name.as_bytes();
        let mut out = Vec::with_capacity(16 + name.len() + self.entries.len() * 4 + 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&self.m.to_le_bytes());
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name);
        let payload_start = out.len();
        for &e in &self.entries {
            out.extend_from_slice(&e.to_le_bytes());
        }
        let crc = crc32(&out[payload_start..]);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<MantissaLut, LutIoError> {
        if data.len() < 16 || &data[0..8] != MAGIC {
            return Err(LutIoError::BadMagic);
        }
        let m = u32::from_le_bytes(data[8..12].try_into().unwrap());
        if !(1..=super::MAX_LUT_M).contains(&m) {
            return Err(LutIoError::BadHeader(format!("mantissa width {m}")));
        }
        let name_len = u32::from_le_bytes(data[12..16].try_into().unwrap()) as usize;
        if name_len > MAX_NAME_LEN {
            return Err(LutIoError::BadHeader(format!(
                "name length {name_len} exceeds max {MAX_NAME_LEN}"
            )));
        }
        if data.len() < 16 + name_len {
            return Err(LutIoError::BadHeader("truncated name".into()));
        }
        let name = std::str::from_utf8(&data[16..16 + name_len])
            .map_err(|_| LutIoError::BadHeader("name not utf-8".into()))?
            .to_string();
        let n_entries = 1usize << (2 * m);
        let payload_start = 16 + name_len;
        let payload_end = payload_start + n_entries * 4;
        if data.len() != payload_end + 4 {
            return Err(LutIoError::BadHeader(format!(
                "file size {} != expected {}",
                data.len(),
                payload_end + 4
            )));
        }
        let payload = &data[payload_start..payload_end];
        let want = u32::from_le_bytes(data[payload_end..].try_into().unwrap());
        let got = crc32(payload);
        if want != got {
            return Err(LutIoError::CrcMismatch { want, got });
        }
        let entries = payload
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(MantissaLut { mult_name: name, m, entries })
    }

    pub fn save(&self, path: &Path) -> Result<(), LutIoError> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<MantissaLut, LutIoError> {
        let mut f = std::fs::File::open(path)?;
        // size gate from metadata, BEFORE reading: no well-formed LUT can
        // exceed this, so an oversized path is rejected without buffering
        // (or even touching) its contents
        let size = f.metadata()?.len();
        if size > MAX_LUT_FILE_BYTES {
            return Err(LutIoError::BadHeader(format!(
                "file is {size} bytes, larger than any valid LUT ({MAX_LUT_FILE_BYTES})"
            )));
        }
        let mut data = Vec::with_capacity(size as usize);
        f.read_to_end(&mut data)?;
        Self::from_bytes(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mult::registry;

    #[test]
    fn crc32_known_vector() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_bytes() {
        let m = registry::by_name("afm16").unwrap();
        let lut = MantissaLut::generate(m.as_ref());
        let bytes = lut.to_bytes();
        let back = MantissaLut::from_bytes(&bytes).unwrap();
        assert_eq!(back, lut);
    }

    #[test]
    fn roundtrip_file() {
        let m = registry::by_name("mit16").unwrap();
        let lut = MantissaLut::generate(m.as_ref());
        let dir = std::env::temp_dir().join("approxtrain_test_luts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mit16.lut");
        lut.save(&path).unwrap();
        let back = MantissaLut::load(&path).unwrap();
        assert_eq!(back, lut);
        assert_eq!(back.mult_name, "mit16");
    }

    #[test]
    fn corruption_detected() {
        let m = registry::by_name("bfloat16").unwrap();
        let lut = MantissaLut::generate(m.as_ref());
        let mut bytes = lut.to_bytes();
        // flip a payload bit
        let idx = bytes.len() / 2;
        bytes[idx] ^= 0x10;
        match MantissaLut::from_bytes(&bytes) {
            Err(LutIoError::CrcMismatch { .. }) => {}
            other => panic!("expected crc mismatch, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_and_truncation() {
        assert!(matches!(MantissaLut::from_bytes(b"short"), Err(LutIoError::BadMagic)));
        let m = registry::by_name("bfloat16").unwrap();
        let bytes = MantissaLut::generate(m.as_ref()).to_bytes();
        assert!(MantissaLut::from_bytes(&bytes[..bytes.len() - 8]).is_err());
    }

    /// Truncation at every byte and every single-bit header corruption
    /// must produce a typed error or a valid parse — never a panic.
    #[test]
    fn truncation_sweep_and_header_bit_flips_never_panic() {
        let m = registry::by_name("bfloat16").unwrap();
        let bytes = MantissaLut::generate(m.as_ref()).to_bytes();
        for keep in 0..bytes.len() {
            assert!(MantissaLut::from_bytes(&bytes[..keep]).is_err(), "prefix {keep}");
        }
        // header + name region is where hostile sizes live; payload flips
        // are covered by the crc test
        for byte in 0..32.min(bytes.len()) {
            for bit in 0..8 {
                let mut flipped = bytes.clone();
                flipped[byte] ^= 1 << bit;
                let _ = MantissaLut::from_bytes(&flipped);
            }
        }
    }

    #[test]
    fn hostile_name_length_rejected() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&4u32.to_le_bytes()); // m = 4 (valid)
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd name_len
        match MantissaLut::from_bytes(&bytes) {
            Err(LutIoError::BadHeader(msg)) => assert!(msg.contains("name length"), "{msg}"),
            other => panic!("expected BadHeader, got {other:?}"),
        }
    }

    /// `load` must reject a file larger than any valid LUT from its
    /// metadata alone — a sparse file probes this without writing the
    /// bytes (reading it would materialize them).
    #[test]
    fn oversized_file_rejected_before_reading() {
        let dir = std::env::temp_dir().join("approxtrain_test_luts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("huge.lut");
        let f = std::fs::File::create(&path).unwrap();
        f.set_len(MAX_LUT_FILE_BYTES + 1).unwrap();
        drop(f);
        match MantissaLut::load(&path) {
            Err(LutIoError::BadHeader(msg)) => assert!(msg.contains("larger"), "{msg}"),
            other => panic!("expected size rejection, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
