//! AMDENSE — the approximate Dense (fully-connected) op (paper §VI-C),
//! built on the matrix-vector kernel for small mini-batches. Above
//! [`crate::kernels::matvec::DENSE_GEMM_MIN_MACS`] the matvec entry
//! points route to the tiled cache-blocked GEMM
//! ([`crate::kernels::gemm::gemm_tiled`]) with 2D-parallel tiling over
//! the persistent pool — bit-identical to the matvec regime by the
//! crate-wide accumulation contract.

use crate::kernels::matvec::{dense_forward, dense_input_grad, dense_weight_grad};
use crate::kernels::MulKernel;
use crate::tensor::Tensor;

/// Forward: `y[b, o] = x[b, :] . w[:, o] + bias[o]` (bias addition is
/// exact — only multiplies are approximated).
pub fn forward(mul: &MulKernel, x: &Tensor, w: &Tensor, bias: Option<&Tensor>) -> Tensor {
    assert_eq!(x.rank(), 2);
    assert_eq!(w.rank(), 2);
    let (batch, n_in) = (x.shape[0], x.shape[1]);
    let n_out = w.shape[1];
    assert_eq!(w.shape[0], n_in);
    let mut y = Tensor::zeros(&[batch, n_out]);
    dense_forward(mul, &x.data, &w.data, &mut y.data, batch, n_in, n_out);
    if let Some(b) = bias {
        assert_eq!(b.len(), n_out);
        for r in 0..batch {
            for o in 0..n_out {
                y.data[r * n_out + o] += b.data[o];
            }
        }
    }
    y
}

/// Weight gradient `dw = x^T dy` (paper §VI-C.1).
pub fn weight_grad(mul: &MulKernel, x: &Tensor, dy: &Tensor) -> Tensor {
    let (batch, n_in) = (x.shape[0], x.shape[1]);
    let n_out = dy.shape[1];
    assert_eq!(dy.shape[0], batch);
    let mut dw = Tensor::zeros(&[n_in, n_out]);
    dense_weight_grad(mul, &x.data, &dy.data, &mut dw.data, batch, n_in, n_out);
    dw
}

/// Bias gradient: column sums of `dy` (exact — additions only).
pub fn bias_grad(dy: &Tensor) -> Tensor {
    let (batch, n_out) = (dy.shape[0], dy.shape[1]);
    let mut db = Tensor::zeros(&[n_out]);
    for b in 0..batch {
        for o in 0..n_out {
            db.data[o] += dy.data[b * n_out + o];
        }
    }
    db
}

/// Input gradient `dx = dy w^T` (paper §VI-C.2; transposition implicit).
pub fn input_grad(mul: &MulKernel, dy: &Tensor, w: &Tensor) -> Tensor {
    let (batch, n_out) = (dy.shape[0], dy.shape[1]);
    let n_in = w.shape[0];
    assert_eq!(w.shape[1], n_out);
    let mut dx = Tensor::zeros(&[batch, n_in]);
    dense_input_grad(mul, &dy.data, &w.data, &mut dx.data, batch, n_in, n_out);
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_tensor(shape: &[usize], rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.range(-1.0, 1.0)).collect())
    }

    #[test]
    fn forward_with_bias() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let b = Tensor::from_vec(&[2], vec![10.0, 20.0]);
        let y = forward(&MulKernel::Native, &x, &w, Some(&b));
        assert_eq!(y.data, vec![11.0, 22.0]);
    }

    #[test]
    fn grads_match_finite_differences() {
        let mut rng = Pcg32::seeded(71);
        let x = rand_tensor(&[3, 4], &mut rng);
        let w = rand_tensor(&[4, 5], &mut rng);
        let dy = rand_tensor(&[3, 5], &mut rng);
        let dw = weight_grad(&MulKernel::Native, &x, &dy);
        let dx = input_grad(&MulKernel::Native, &dy, &w);
        let db = bias_grad(&dy);
        let bias = Tensor::zeros(&[5]);
        let loss = |x: &Tensor, w: &Tensor, b: &Tensor| -> f32 {
            let y = forward(&MulKernel::Native, x, w, Some(b));
            y.data.iter().zip(&dy.data).map(|(a, g)| a * g).sum()
        };
        let eps = 1e-2;
        for i in 0..w.len() {
            let mut wp = w.clone();
            wp.data[i] += eps;
            let mut wm = w.clone();
            wm.data[i] -= eps;
            let num = (loss(&x, &wp, &bias) - loss(&x, &wm, &bias)) / (2.0 * eps);
            assert!((num - dw.data[i]).abs() < 1e-2, "dw[{i}]");
        }
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp, &w, &bias) - loss(&xm, &w, &bias)) / (2.0 * eps);
            assert!((num - dx.data[i]).abs() < 1e-2, "dx[{i}]");
        }
        for o in 0..5 {
            let mut bp = bias.clone();
            bp.data[o] += eps;
            let mut bm = bias.clone();
            bm.data[o] -= eps;
            let num = (loss(&x, &w, &bp) - loss(&x, &w, &bm)) / (2.0 * eps);
            assert!((num - db.data[o]).abs() < 1e-2, "db[{o}]");
        }
    }
}
